"""The repro.obs layer: spans + counters, replay decision traces, diffing
and exporters.

Acceptance matrix (ISSUE 6):

  * per-event open-bin / usage series from the traced scan match the host
    oracle engine event-for-event, for at least one policy per family
    (score, CBD, RCP, LA, adaptive),
  * ``trace_level=0`` results are bit-identical to ``trace_level=1`` (the
    trace is an extra scan *output*, never an input),
  * ``diff_traces`` pinpoints an injected single-event divergence exactly,
  * a Perfetto export of an Experiment run covers >= 5 span categories,
  * the serving scheduler's select span/counter names the backend that
    actually served the decision,
  * JSONL run logs round-trip and ``python -m repro obs`` summarizes them,
  * the trace module's event-kind constants stay in sync with the kernel's.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro import obs
from repro.core import Instance, run as oracle_run
from repro.core.jaxsim import event_sequence, host_algorithm
from repro.obs.trace import (ARRIVAL_KIND, DEPARTURE_KIND, PAD_KIND,
                             TraceDivergence)
from repro.sweep import pack_instances, pad_predictions, run_batch

# one representative per scan-policy family
FAMILY_POLICIES = ("best_fit_linf", "cbd", "reduced_hybrid", "rcp",
                   "la_binary", "adaptive")


def quantized_instance(seed, n, d):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 24, (n, d)) / 64.0
    arr = np.sort(rng.integers(0, 50000, n)).astype(float)
    dur = rng.integers(10, 5000, n).astype(float)
    return Instance(sizes, arr, arr + dur, f"q{seed}").sorted_by_arrival()


@pytest.fixture(scope="module")
def traced_batch():
    """Mixed sizes/dims, two prediction rows (clairvoyant + power-of-two
    noise) - every lane has pad events and a distinct event tensor row."""
    insts = [quantized_instance(1, 40, 2), quantized_instance(2, 60, 4),
             quantized_instance(3, 30, 3)]
    batch = pack_instances(insts)
    preds = []
    for i in insts:
        rng = np.random.default_rng(100)
        noisy = i.durations * rng.choice([0.25, 0.5, 1.0, 2.0, 4.0],
                                         i.n_items)
        preds.append(np.stack([i.durations, noisy]))
    return insts, preds, batch, pad_predictions(batch, preds)


# --------------------------------------------------------- spans + counters

def test_counters_always_on():
    c0 = obs.counter_get("test.obs.x")
    obs.counter_add("test.obs.x")
    obs.counter_add("test.obs.x", 2.5)
    assert obs.counter_get("test.obs.x") == c0 + 3.5
    before = obs.counters()
    obs.counter_add("test.obs.y", 7)
    assert obs.counter_deltas(before) == {"test.obs.y": 7}


def test_disabled_span_is_shared_noop():
    prev = obs.enabled()
    obs.enable(False)
    try:
        n0 = len(obs.events())
        s1 = obs.span("test.noop", foo=1)
        s2 = obs.span("test.other")
        assert s1 is s2            # the shared null object, zero alloc
        with s1:
            obs.annotate(bar=2)    # no open span: must not raise
        assert len(obs.events()) == n0
    finally:
        obs.enable(prev)


def test_recording_spans_nesting_and_annotate():
    with obs.recording():
        with obs.span("test.outer", a=1):
            with obs.span("test.inner"):
                obs.annotate(hit=True)   # innermost span gets the attr
        evs = [e for e in obs.events() if e["name"].startswith("test.")]
    assert [e["name"] for e in evs] == ["test.inner", "test.outer"]
    inner, outer = evs
    assert inner["cat"] == "test" and inner["args"] == {"hit": True}
    assert outer["args"] == {"a": 1}
    assert outer["dur"] >= inner["dur"] >= 0
    assert outer["ts"] <= inner["ts"]
    assert not obs.enabled() or obs.enabled()  # state restored by context

    @obs.traced("test.deco")
    def f(x):
        return x + 1

    with obs.recording():
        assert f(1) == 2
        assert any(e["name"] == "test.deco" for e in obs.events())


def test_timeit_stats_and_row():
    import os
    import sys
    st = obs.timeit(lambda: sum(range(100)), n=4, warmup=1)
    assert st.n == 4 and st.best <= st.median <= max(st.reps)
    assert st.stdev >= 0 and st.mean > 0
    row = st.row("perf/x", "1.23", scale=0.5)
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.run import _parse_row
    parsed = _parse_row(row)
    assert parsed["name"] == "perf/x" and parsed["derived"] == 1.23
    assert parsed["reps"] == 4
    assert parsed["us_per_call"] == pytest.approx(st.best * 0.5e6, abs=0.1)
    assert parsed["median_us"] == pytest.approx(st.median * 0.5e6, abs=0.1)
    # one-shot rows (no spread comment) normalize to the same schema:
    # median_us == us_per_call, stdev 0, one rep
    plain = _parse_row("perf/y,12,0.5")
    assert plain["median_us"] == 12.0
    assert plain["stdev_us"] == 0.0 and plain["reps"] == 1
    # CPU-interpret Pallas rows carry the mode tag off the comment token
    assert _parse_row("perf/z,3,1  # mode=interpret")["mode"] == "interpret"
    assert "mode" not in plain


def test_kind_constants_match_kernel():
    from repro.kernels import fitscore
    assert ARRIVAL_KIND == fitscore.ARRIVAL_KIND
    assert DEPARTURE_KIND == fitscore.DEPARTURE_KIND
    assert PAD_KIND == fitscore.PAD_KIND


# ----------------------------------------------------------- replay traces

def _oracle_open_bins(inst, policy, pred):
    """Host-oracle reconstruction of the per-event open-bin series (bin
    indices are absolute in the oracle and reused slots in the scan, so
    the comparable series is the open-bin *count* after each event)."""
    r = oracle_run(inst, host_algorithm(policy), predicted_durations=pred)
    t, k, j = event_sequence(inst)
    counts, series = {}, []
    for kind, item in zip(k, j):
        b = r.placements[item]
        if kind == ARRIVAL_KIND:
            counts[b] = counts.get(b, 0) + 1
        else:
            counts[b] -= 1
            if counts[b] == 0:
                del counts[b]
        series.append(len(counts))
    return r, np.array(series)


@pytest.mark.parametrize("policy", FAMILY_POLICIES)
def test_trace_series_matches_host_oracle(policy, traced_batch):
    """Every lane's traced open-bin series equals the oracle engine's,
    event-for-event, and the running usage series ends at the result."""
    insts, preds, batch, pdeps = traced_batch
    res = run_batch(batch, policy, pdeps, max_bins=32, trace_level=1)
    tr = res.trace
    assert tr is not None and tr.policy == policy
    S = 2
    assert tr.L == len(insts) * S
    for bi, inst in enumerate(insts):
        for si in range(S):
            r, oracle_series = _oracle_open_bins(inst, policy,
                                                 preds[bi][si])
            s = tr.series(bi * S + si)
            assert len(s["open_bins"]) == 2 * inst.n_items
            assert (s["open_bins"] == oracle_series).all(), \
                (policy, inst.name, si)
            assert s["usage"][-1] == res.usage_time[bi, si] == r.usage_time
            # arrivals place into a slot; pad events never leak through
            assert (s["slot"][s["kind"] == ARRIVAL_KIND] >= 0).all()
            assert (s["kind"] != PAD_KIND).all()


def test_trace_level0_bit_identical(traced_batch):
    insts, preds, batch, pdeps = traced_batch
    a = run_batch(batch, "best_fit_linf", pdeps, max_bins=32)
    b = run_batch(batch, "best_fit_linf", pdeps, max_bins=32, trace_level=1)
    assert a.trace is None and b.trace is not None
    assert (a.usage_time == b.usage_time).all()
    assert (a.n_bins_opened == b.n_bins_opened).all()
    assert (a.max_bins == b.max_bins).all()


def test_trace_backend_parity(traced_batch):
    """The blocked-kernel path is bypassed under tracing, but the per-event
    kernel backend still traces - and must agree with jnp event-for-event
    (diff_traces returns None)."""
    insts, preds, batch, pdeps = traced_batch
    a = run_batch(batch, "cbd", pdeps, max_bins=32, backend="jnp",
                  trace_level=1)
    b = run_batch(batch, "cbd", pdeps, max_bins=32,
                  backend="pallas_interpret", trace_level=1)
    assert obs.diff_traces(a.trace, b.trace) is None


def test_diff_traces_pinpoints_injected_divergence(traced_batch):
    insts, preds, batch, pdeps = traced_batch
    tr = run_batch(batch, "best_fit_linf", pdeps, max_bins=32,
                   trace_level=1).trace
    assert obs.diff_traces(tr, tr) is None
    # flip one arrival's chosen slot in one lane
    lane = 3
    ev = int(np.where(tr.kinds[lane] == ARRIVAL_KIND)[0][5])
    slot = tr.slot.copy()
    slot[lane, ev] += 1
    mutated = dataclasses.replace(tr, slot=slot)
    d = obs.diff_traces(tr, mutated)
    assert isinstance(d, TraceDivergence)
    assert (d.lane, d.event, d.field) == (lane, ev, "slot")
    assert d.b_value == d.a_value + 1 and d.kind == ARRIVAL_KIND
    assert "slot" in str(d) and f"lane {lane}" in str(d)
    # an earlier structural difference wins over a later decision one
    kinds = tr.kinds.copy()
    kinds[0, 0] = PAD_KIND if kinds[0, 0] != PAD_KIND else ARRIVAL_KIND
    d2 = obs.diff_traces(tr, dataclasses.replace(mutated, kinds=kinds))
    assert (d2.lane, d2.event, d2.field) == (0, 0, "kind")


def test_trace_lane_view(traced_batch):
    insts, preds, batch, pdeps = traced_batch
    tr = run_batch(batch, "rcp", pdeps, max_bins=32, trace_level=1).trace
    one = tr.lane(2)
    assert one.L == 1 and one.E == tr.E and one.S == 1
    assert (one.slot[0] == tr.slot[2]).all()
    assert (one.usage[0] == tr.usage[2]).all()


# --------------------------------------------------- experiment + exporters

def test_experiment_metrics_traces_and_perfetto(tmp_path):
    from repro import api
    from repro.sweep.grid import result_key
    insts = [quantized_instance(81, 12, 2), quantized_instance(82, 15, 2)]
    wl = api.instances(insts, name="obs-exp")
    exp = api.Experiment(wl, policies=("first_fit", "greedy"))
    store = str(tmp_path / "sweeps")
    with obs.recording():
        res = exp.run(store=store)
        events = obs.events()
    # counter deltas of the producing run ride the Results
    assert res.metrics["experiment.cache_miss"] == 2
    assert res.metrics["sweep.scan_calls"] >= 2
    assert res.metrics["sweep.jit_trace"] >= 1
    assert res.metrics["sweep.device_transfer_bytes"] > 0
    assert res.metrics.get("store.save", 0) >= 1
    # second run: fully cached, no scans
    res2 = exp.run(store=store)
    assert res2.metrics["experiment.cache_hit"] == 2
    assert "sweep.scan_calls" not in res2.metrics
    assert res2.records.keys() == res.records.keys()
    # traced run recomputes every cell and returns one trace per record
    res3 = exp.run(store=store, trace_level=1)
    assert set(res3.traces) == set(res3.records)
    key = result_key(wl.suite(), insts[0].name, "greedy",
                     wl.pred_model(api.Setting.clairvoyant()), 0)
    t = res3.traces[key]
    assert t.L == 1
    assert t.usage[0, -1] == res3.records[key]["usage_time"]
    # the recorded spans cover >= 5 categories and export to Perfetto
    cats = {e["cat"] for e in events}
    assert {"experiment", "suite", "sweep", "store", "pack"} <= cats
    out = tmp_path / "trace.json"
    obs.export_perfetto(str(out), events)
    doc = json.loads(out.read_text())
    assert len({e["cat"] for e in doc["traceEvents"]}) >= 5
    assert all({"name", "ph", "ts", "dur", "pid", "tid"} <= e.keys()
               for e in doc["traceEvents"])


def test_jsonl_roundtrip_and_cli(tmp_path, capsys):
    with obs.recording():
        with obs.span("test.io", k="v"):
            pass
        events = obs.events()
    events = [e for e in events if e["name"] == "test.io"]
    obs.counter_add("test.io.counter", 3)
    log = str(tmp_path / "run.obs.jsonl")
    obs.export_jsonl(log, events, {"test.io.counter": 3},
                     meta={"suite": "unit"})
    evs, counters, meta = obs.read_jsonl(log)
    assert [e["name"] for e in evs] == ["test.io"]
    assert evs[0]["args"] == {"k": "v"}
    assert counters == {"test.io.counter": 3}
    assert meta["suite"] == "unit" and meta["schema"] == 1

    from repro.obs.cli import main as obs_cli
    perfetto = str(tmp_path / "t.json")
    assert obs_cli([log, "--perfetto", perfetto]) == 0
    out = capsys.readouterr().out
    assert "test.io" in out and "test.io.counter" in out
    assert "suite=unit" in out
    assert json.loads(open(perfetto).read())["traceEvents"]


# ----------------------------------------------------------------- serving

def _req(rid, decode=800):
    from repro.serving.scheduler import Request
    return Request(rid=rid, arrival=0.0, prompt_len=256, decode_len=decode,
                   predicted_decode_len=decode)


def test_serving_select_reports_backend():
    from repro.serving.scheduler import DVBPScheduler
    host = DVBPScheduler(policy="first_fit", select_backend="host")
    c0 = obs.counter_get("serving.select_host")
    with obs.recording():
        host.place(_req(0), now=0.0)
        evs = [e for e in obs.events() if e["name"] == "serving.select"]
    assert host.last_select_backend == "host"
    assert obs.counter_get("serving.select_host") == c0 + 1
    assert evs[-1]["args"]["backend"] == "host"
    assert evs[-1]["args"]["policy"] == "first_fit"

    dev = DVBPScheduler(policy="first_fit",
                        select_backend="pallas_interpret")
    c0 = obs.counter_get("serving.select_pallas_interpret")
    with obs.recording():
        dev.place(_req(1), now=0.0)
        evs = [e for e in obs.events() if e["name"] == "serving.select"]
    assert dev.last_select_backend == "pallas_interpret"
    assert obs.counter_get("serving.select_pallas_interpret") == c0 + 1
    assert evs[-1]["args"]["backend"] == "pallas_interpret"
    # "auto" off-TPU resolves (and reports) the jnp twin, not "auto"
    import jax
    if jax.default_backend() != "tpu":
        auto = DVBPScheduler(policy="first_fit", select_backend="auto")
        auto.place(_req(2), now=0.0)
        assert auto.last_select_backend == "jnp"
