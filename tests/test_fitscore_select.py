"""The fused Pallas placement kernel vs the inline jnp scan step.

Parity matrix: every jaxsim policy replayed through ``run_batch`` on the
"jnp" and "pallas_interpret" backends over a mixed-size / mixed-dimension
padded batch (the dmask path: zero-padded dims would poison l_inf residuals
if unmasked) with noisy prediction rows - results must be bit-identical,
because the kernel implements the exact same fp32 score/tie-break/free-slot
semantics (instances are fp32-exact: 1/64-grid sizes, integer times).

Plus the tie-break regression: score ties must fall to the earliest-*opened*
bin, not the smallest slot index - the two diverge as soon as a closed slot
is reused.
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.core import Instance, get_algorithm, run
from repro.core.jaxsim import POLICIES, simulate
from repro.sweep import pack_instances, pad_predictions, run_batch


def quantized_instance(seed, n, d):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 24, (n, d)) / 64.0
    arr = np.sort(rng.integers(0, 50000, n)).astype(float)
    dur = rng.integers(10, 5000, n).astype(float)
    return Instance(sizes, arr, arr + dur, f"q{seed}").sorted_by_arrival()


@pytest.fixture(scope="module")
def mixed():
    """Mixed item counts AND dimensionality (exercises pad events + dmask),
    with one fp32-exact noisy prediction row per lane."""
    insts = [quantized_instance(1, 60, 2), quantized_instance(2, 100, 4),
             quantized_instance(3, 40, 3)]
    batch = pack_instances(insts)
    preds = []
    for i in insts:
        rng = np.random.default_rng(7)
        noisy = i.durations * rng.choice([0.5, 1.0, 2.0], i.n_items)
        preds.append(np.stack([i.durations, noisy]))
    return insts, batch, pad_predictions(batch, preds)


@pytest.mark.parametrize("policy", POLICIES)
def test_kernel_backend_bit_identical(policy, mixed):
    insts, batch, pdeps = mixed
    a = run_batch(batch, policy, pdeps, max_bins=16, backend="jnp")
    b = run_batch(batch, policy, pdeps, max_bins=16,
                  backend="pallas_interpret")
    assert not a.overflowed.any() and not b.overflowed.any()
    assert (a.usage_time == b.usage_time).all(), policy
    assert (a.n_bins_opened == b.n_bins_opened).all(), policy
    assert (a.max_bins == b.max_bins).all(), policy


def test_kernel_backend_matches_oracle(mixed):
    """Transitivity anchor: the kernel path equals the Python oracle, not
    just the jnp twin (one policy per score structure)."""
    insts, batch, pdeps = mixed
    for policy in ("best_fit_linf", "nrt_prioritized"):
        res = run_batch(batch, policy, pdeps, max_bins=16,
                        backend="pallas_interpret")
        alg = (get_algorithm("best_fit", norm="linf")
               if policy == "best_fit_linf" else get_algorithm(policy))
        for i, inst in enumerate(insts):
            r = run(inst, alg, predicted_durations=inst.durations)
            assert res.n_bins_opened[i, 0] == r.n_bins_opened, policy
            assert res.usage_time[i, 0] == pytest.approx(r.usage_time,
                                                         abs=1e-3), policy


def test_simulate_kernel_backend_placements(mixed):
    """Single-instance simulate() through the kernel: identical placements
    (the strongest decision-for-decision check)."""
    insts, _, _ = mixed
    for policy in ("first_fit", "best_fit_l2", "greedy"):
        a = simulate(insts[1], policy, max_bins=16, backend="jnp")
        b = simulate(insts[1], policy, max_bins=16,
                     backend="pallas_interpret")
        assert (a.placements == b.placements).all(), policy
        assert a.usage_time == b.usage_time


def tie_break_instance():
    """Engineered so a closed slot is reused before a best-fit tie: slot 0
    (reused by C, opening order 2) vs slot 1 (B, opening order 1) tie on the
    residual for D - opening order must win, giving D to B's bin."""
    sizes = np.array([[0.5], [0.625], [0.625], [0.25]])
    arrivals = np.array([0.0, 1.0, 11.0, 12.0])
    departures = np.array([10.0, 100.0, 100.0, 200.0])
    return Instance(sizes, arrivals, departures, "tie")


@pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("norm", ["l1", "l2", "linf"])
def test_tie_breaks_by_opening_order_not_slot_index(backend, norm):
    inst = tie_break_instance()
    res = simulate(inst, f"best_fit_{norm}", max_bins=4, backend=backend)
    # A->slot0, B->slot1, A departs (slot0 closes), C reuses slot0; D ties
    # between slot0 (open_seq 2) and slot1 (open_seq 1) -> slot1.
    assert list(res.placements) == [0, 1, 0, 1], (backend, norm)
    r = run(inst, get_algorithm("best_fit", norm=norm))
    assert res.usage_time == pytest.approx(r.usage_time, abs=1e-3)
    assert res.n_bins_opened == r.n_bins_opened == 3


def test_zero_padded_dims_dont_poison_linf():
    """A d=1 lane padded into a d=4 batch must replay exactly like its solo
    run: without dmask the padded dims' residual (1.0) would dominate every
    l_inf score and break ties/ordering."""
    lane = tie_break_instance()                      # d=1, tie-sensitive
    wide = quantized_instance(9, 50, 4)              # forces d_max=4
    batch = pack_instances([lane, wide])
    for backend in ("jnp", "pallas_interpret"):
        res = run_batch(batch, "best_fit_linf", max_bins=16, backend=backend)
        solo = run_batch(pack_instances([lane]), "best_fit_linf",
                         max_bins=16, backend=backend)
        assert res.usage_time[0, 0] == solo.usage_time[0, 0], backend
        assert res.n_bins_opened[0, 0] == solo.n_bins_opened[0, 0], backend


_SHARD_SCRIPT = """
import jax, numpy as np
assert jax.local_device_count() == 4, jax.local_device_count()
from repro.core import Instance
from repro.sweep import pack_instances, pad_predictions, run_batch
rng = np.random.default_rng(0)
insts = []
for s in range(6):   # 6 lanes over 4 devices -> pads to 8
    n = 40 + 10 * s
    sizes = rng.integers(1, 24, (n, 3)) / 64.0
    arr = np.sort(rng.integers(0, 5000, n)).astype(float)
    dur = rng.integers(10, 500, n).astype(float)
    insts.append(Instance(sizes, arr, arr + dur, f"s{s}").sorted_by_arrival())
batch = pack_instances(insts)
a = run_batch(batch, "best_fit_linf", max_bins=2, shard="never")
b = run_batch(batch, "best_fit_linf", max_bins=2, shard="always")
assert (a.usage_time == b.usage_time).all()
assert (a.n_bins_opened == b.n_bins_opened).all()
assert (a.max_bins == b.max_bins).all()      # escalation ladder composes
assert not b.overflowed.any() and (b.max_bins > 2).any()
# S>1 prediction rows through the sharded scan (regression: a nested jit in
# the shard_map body used to fail HLO sharding verification)
pdeps = pad_predictions(batch, [np.stack([i.durations, 2.0 * i.durations])
                                for i in insts])
a = run_batch(batch, "greedy", pdeps, max_bins=32, shard="never")
b = run_batch(batch, "greedy", pdeps, max_bins=32, shard="always")
assert a.S == 2 and (a.usage_time == b.usage_time).all()
# B < ndev (regression: lane padding must wrap when pad > B)
solo = pack_instances(insts[:1])
a = run_batch(solo, "first_fit", max_bins=32, shard="never")
b = run_batch(solo, "first_fit", max_bins=32, shard="always")
assert (a.usage_time == b.usage_time).all()
print("SHARD-OK")
"""


def test_sharded_lanes_match_single_device():
    """run_batch sharded over 4 (forced host) devices == single device,
    including the lane-escalation ladder.  Runs in a subprocess because
    device count is fixed at jax init."""
    import os
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARD-OK" in proc.stdout
