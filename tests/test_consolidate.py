"""repro.consolidate acceptance: MIGRATE events as a scenario axis.

The contract under test (ISSUE 9):

  * the chunked consolidating driver replays MIGRATE streams
    decision-for-decision equal to the sequential consolidating host
    oracle for EVERY scan policy (all 21), on the jnp reference path and
    the event-blocked megakernel (T=1 and T>1) alike,
  * with the axis disabled the sweep is bitwise identical to a build
    without it: same records, same result keys, same spec hashes,
  * ConsolidationSpec parses/round-trips, budgets bound churn, and the
    churn counters (``consolidate.*``) surface through obs,
  * the api facade carries the axis (``Setting.with_consolidation``) and
    ``Experiment.run`` names the failing cell in ``CapacityError``,
  * the serving drain pass executes the same planner's decisions on the
    live carry.

Instances are fp32-exact (1/64-grid sizes, integer times) so the scan's
fp32 usage accumulation must equal the oracle's float64 bitwise.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.consolidate import (ConsolidationSpec, consolidated_replay,
                               plan_migrations, run_consolidating)
from repro.core import Instance
from repro.core.jaxsim import SCAN_POLICIES, host_algorithm
from repro.sweep import (PredModel, SuiteSpec, SweepSpec, SweepStore,
                         pack_instances, run_batch, run_sweep)
from repro.sweep.runner import _flatten_lanes, instances_pdeps

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# underload drain, 8-event planning cadence: dense enough that the
# 40-item streams below plan ~9 times and actually migrate
SPEC = ConsolidationSpec.parse("underload:t0.5:e8")


def qinst(seed, n=40, d=3):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 24, (n, d)) / 64.0
    arr = np.sort(rng.integers(0, 50000, n)).astype(float)
    dur = rng.integers(10, 5000, n).astype(float)
    return Instance(sizes, arr, arr + dur, f"q{seed}").sorted_by_arrival()


@pytest.fixture(scope="module")
def pair():
    insts = [qinst(1), qinst(2)]
    return insts, pack_instances(insts)


def _driver(batch, policy, *, backend="jnp", block_events=0, spec=SPEC,
            max_bins=32):
    arrays = (batch.sizes, batch.times, batch.kinds, batch.items,
              instances_pdeps(batch), batch.dmask, batch.arrivals,
              batch.pdeps, batch.n_items)
    flat = _flatten_lanes(*(jnp.asarray(a) for a in arrays))
    return consolidated_replay(*flat, policy=policy, max_bins=max_bins,
                               backend=backend, block_events=block_events,
                               spec=spec)


# ------------------------------------------------------------------- spec

def test_spec_parse_canonical_roundtrip():
    for s in ("none", "underload", "underload:0.4", "underload:0.4:16",
              "underload:t0.25:b64:e128:c0.5", "periodic:100",
              "periodic:dt100:t0.3:b8"):
        spec = ConsolidationSpec.parse(s)
        again = ConsolidationSpec.parse(spec.canonical())
        assert again == spec, s
        assert again.canonical() == spec.canonical() == str(spec)
    assert ConsolidationSpec().canonical() == "none"
    assert not ConsolidationSpec.parse("none").enabled
    p = ConsolidationSpec.parse("periodic:100:0.3:8")
    assert (p.dt, p.threshold, p.budget) == (100.0, 0.3, 8)


def test_spec_rejects_bad_knobs():
    with pytest.raises(AssertionError):
        ConsolidationSpec(kind="defrag")
    with pytest.raises(AssertionError):
        ConsolidationSpec(kind="underload", threshold=0.0)
    with pytest.raises(AssertionError):
        ConsolidationSpec(kind="periodic", dt=0.0)
    with pytest.raises(AssertionError):
        ConsolidationSpec(kind="underload", every=0)


def test_sweep_hash_stable_when_disabled():
    """A spec with the axis off hashes exactly as one predating the axis:
    canonical() must not even mention consolidations."""
    base = SweepSpec(policies=("first_fit",))
    off = SweepSpec(policies=("first_fit",),
                    consolidations=(ConsolidationSpec(),))
    on = SweepSpec(policies=("first_fit",),
                   consolidations=(ConsolidationSpec(), SPEC))
    assert base.spec_hash() == off.spec_hash()
    assert "consolidations" not in base.canonical()
    assert on.spec_hash() != base.spec_hash()
    assert on.canonical()["consolidations"] == [SPEC.canonical()]


# ------------------------------------------- driver vs oracle, all policies

@pytest.mark.parametrize("policy", SCAN_POLICIES)
def test_driver_matches_oracle_all_policies(policy, pair):
    """Every scan policy replays the MIGRATE stream decision-for-decision
    equal to the sequential consolidating host oracle: identical usage
    (fp32-exact instances -> bitwise), bins opened, migration events in
    emission order, and churn stats."""
    insts, batch = pair
    usage, opened, _, over, stats = _driver(batch, policy)
    assert not np.asarray(over).any()
    for lane, inst in enumerate(insts):
        res, ost = run_consolidating(inst, host_algorithm(policy), SPEC)
        assert float(usage[lane]) == res.usage_time, policy
        assert int(opened[lane]) == res.n_bins_opened, policy
        assert stats["events"][lane] == ost["events"], policy
        assert int(stats["migrations"][lane]) == ost["migrations"]
        assert int(stats["bins_closed"][lane]) == ost["bins_closed"]


def test_scenario_actually_migrates(pair):
    """Guard the fixture: the parity above is only meaningful while the
    scenario produces real churn for the score family."""
    _, batch = pair
    *_, stats = _driver(batch, "first_fit")
    assert stats["migrations"].sum() > 0


@pytest.mark.parametrize("policy", SCAN_POLICIES)
def test_driver_blocked_kernel_matches_jnp(policy, pair):
    """The megakernel path replays consolidating streams bit-identically
    to the jnp driver (itself oracle-equal above) at T=1 and T>1."""
    _, batch = pair
    u0, o0, _, _, s0 = _driver(batch, policy)
    for T in (1, 8):
        u, o, _, _, s = _driver(batch, policy, backend="pallas_interpret",
                                block_events=T)
        assert (np.asarray(u) == np.asarray(u0)).all(), (policy, T)
        assert (np.asarray(o) == np.asarray(o0)).all(), (policy, T)
        assert s["events"] == s0["events"], (policy, T)


def test_run_batch_wires_the_driver(pair):
    """run_batch(consolidate=...) surfaces the driver's churn per cell and
    its usage; migration_cost = cost x migrations."""
    _, batch = pair
    spec = ConsolidationSpec.parse("underload:t0.5:e8:c2.5")
    u, _, _, _, stats = _driver(batch, "first_fit", spec=spec)
    res = run_batch(batch, "first_fit", max_bins=32, consolidate=spec)
    assert (res.usage_time[:, 0] == np.asarray(u)).all()
    assert (res.migrations[:, 0] == stats["migrations"]).all()
    assert (res.migration_cost == 2.5 * res.migrations).all()
    base = run_batch(batch, "first_fit", max_bins=32)
    assert base.migrations is None and base.migration_cost is None
    # the drain only executes whole-bin moves that close a bin: usage
    # never increases
    assert (res.usage_time <= base.usage_time).all()
    assert (res.usage_time < base.usage_time).any()


# --------------------------------------------------------- budget + counters

def test_budget_bounds_churn(pair):
    _, batch = pair
    free = _driver(batch, "first_fit",
                   spec=ConsolidationSpec.parse("underload:t0.5:e8"))[4]
    capped = _driver(batch, "first_fit",
                     spec=ConsolidationSpec.parse("underload:t0.5:b1:e8"))[4]
    zero = _driver(batch, "first_fit",
                   spec=ConsolidationSpec.parse("underload:t0.5:b0:e8"))[4]
    assert free["migrations"].sum() > 1
    assert (capped["migrations"] <= 1).all()
    assert capped["budget_exhausted"].sum() > 0
    assert zero["migrations"].sum() == 0


def test_churn_counters_emitted(pair):
    _, batch = pair
    before = {k: obs.counter_get(k) for k in
              ("consolidate.migrations", "consolidate.bins_closed",
               "consolidate.budget_exhausted")}
    *_, stats = _driver(batch, "first_fit")
    assert obs.counter_get("consolidate.migrations") - \
        before["consolidate.migrations"] == stats["migrations"].sum()
    assert obs.counter_get("consolidate.bins_closed") - \
        before["consolidate.bins_closed"] == stats["bins_closed"].sum()
    assert obs.counter_get("consolidate.budget_exhausted") >= \
        before["consolidate.budget_exhausted"]


def test_planner_whole_bin_or_skip():
    """The planner only drains a bin when EVERY item fits somewhere else:
    a candidate with an unplaceable item stays put."""
    loads = np.array([[0.2], [0.9]])
    counts = np.array([1, 1])
    alive = np.array([True, True])
    oseq = np.array([0, 1])
    # bin 0 underloaded but its item (0.2) does not fit in bin 1 (0.9)
    plan = plan_migrations(loads, counts, alive, oseq, {0: [0], 1: [1]},
                           np.array([[0.2], [0.9]]), threshold=0.25)
    assert plan.items == [] and plan.bins_closed == 0
    # with headroom the same bin drains
    plan = plan_migrations(np.array([[0.2], [0.5]]), counts, alive, oseq,
                           {0: [0], 1: [1]}, np.array([[0.2], [0.5]]),
                           threshold=0.25)
    assert plan.items == [0] and plan.bins_closed == 1


# ------------------------------------------------------- sweep grid + store

def test_sweep_grid_consolidation_axis(tmp_path):
    """The grid crosses policies x consolidations; disabled cells write
    the exact legacy records (no ``consolidate`` field, legacy result
    keys), enabled cells append the spec segment and churn fields."""
    spec = SweepSpec(suites=(SuiteSpec("azure", 2, 60, 3),),
                     policies=("first_fit",),
                     predictions=(PredModel("clairvoyant"),),
                     max_bins=32,
                     consolidations=(ConsolidationSpec(),
                                     ConsolidationSpec.parse(
                                         "underload:t0.5:e8")))
    store = SweepStore(str(tmp_path))
    rec = run_sweep(spec, store=store)
    assert len(rec) == 4           # 2 instances x (off, on)
    off = {k: r for k, r in rec.items() if "underload" not in k}
    on = {k: r for k, r in rec.items() if "underload" in k}
    assert len(off) == 2 and len(on) == 2
    for r in off.values():
        assert "consolidate" not in r and "migrations" not in r
    for r in on.values():
        assert r["consolidate"] == "underload:t0.5:b-1:e8"
        assert r["migrations"] >= 0 and r["migration_cost"] == 0.0
        assert r["usage_time"] > 0
    # disabled-path identity: the off cells equal a consolidation-free run
    solo = run_sweep(dataclasses_replace_cons(spec), store=SweepStore(
        str(tmp_path / "solo")))
    assert solo == off


def dataclasses_replace_cons(spec):
    import dataclasses
    return dataclasses.replace(spec, consolidations=(ConsolidationSpec(),))


def test_cli_consolidate_flag(tmp_path):
    """``python -m repro sweep --consolidate`` runs the axis end-to-end and
    persists churn fields in the store."""
    store = str(tmp_path / "store")
    cmd = [sys.executable, "-m", "repro", "sweep", "--suites", "azure",
           "--n-instances", "1", "--n-items", "40",
           "--policies", "first_fit", "--preds", "clairvoyant",
           "--backend", "jnp", "--store", store,
           "--consolidate", "none", "underload:t0.5:e8"]
    env = {**os.environ, "PYTHONPATH": SRC}
    p = subprocess.run(cmd, env=env, capture_output=True, text=True)
    assert p.returncode == 0, p.stderr
    files = [f for f in os.listdir(store) if f.endswith(".json")
             and f.startswith("sweep_")]
    assert len(files) == 1
    results = json.load(open(os.path.join(store, files[0])))["results"]
    tags = {r.get("consolidate", "none") for r in results.values()}
    assert tags == {"none", "underload:t0.5:b-1:e8"}


# ---------------------------------------------------------------- api facade

def test_setting_consolidation_roundtrip():
    from repro.api import Setting
    s = Setting.clairvoyant().with_consolidation("underload:t0.25")
    assert s.label() == "clairvoyant+underload:t0.25:b-1:e256"
    assert Setting.parse(s.label()) == s
    assert Setting.parse("clairvoyant").consolidation.canonical() == "none"


def test_experiment_consolidation_axis(pair):
    from repro.api import Experiment, Setting, instances
    insts, _ = pair
    base = Setting.clairvoyant()
    cons = base.with_consolidation("underload:t0.5:e8")
    exp = Experiment(instances(insts, name="cons-test"),
                     policies=("first_fit",), settings=(base, cons),
                     max_bins=32)
    res = exp.run()
    settings = {r["setting"] for r in res.rows()}
    assert settings == {base.label(), cons.label()}
    u_base = res.usage_total(setting=base.label())
    u_cons = res.usage_total(setting=cons.label())
    assert 0 < u_cons < u_base


def test_capacity_error_names_failing_cell(pair):
    """Overflow at the escalation cap surfaces as CapacityError naming the
    exact (workload, instance, policy, setting) cell."""
    from repro.api import Experiment, Setting, instances
    from repro.core.jaxsim import CapacityError
    insts, _ = pair
    exp = Experiment(instances(insts, name="tiny-cap"),
                     policies=("first_fit",),
                     settings=(Setting.clairvoyant(),),
                     max_bins=1, max_bins_cap=1)
    with pytest.raises(CapacityError) as ei:
        exp.run()
    msg = str(ei.value)
    for needle in ("tiny-cap", "first_fit", "clairvoyant", "q1"):
        assert needle in msg, (needle, msg)
    assert ei.value.policy == "first_fit"
    assert ei.value.max_bins == 1


# ------------------------------------------------------------------- serving

def test_serving_drain_pass_moves_migrant():
    """BlockDispatcher.consolidate: the planner's drain executes on the
    live carry - the lone occupant of an underloaded replica moves to the
    lowest-open_seq replica with headroom (source excluded), the source
    closes, and the churn stats say exactly that."""
    from repro.serving.dispatch import BlockDispatcher
    from repro.serving.scheduler import ReplicaCapacity, Request
    caps = ReplicaCapacity(slots=4, kv_tokens=1 << 20,
                           prefill_budget=1 << 20)
    disp = BlockDispatcher("first_fit", caps, tps=50.0, max_bins=8,
                           max_items=16, impl="jnp")
    for rid in range(4):           # fill replica 0 (4 x 0.25 slots)
        disp.enqueue_arrival(Request(rid, float(rid), 64, 64), float(rid))
    disp.enqueue_arrival(Request(4, 4.0, 64, 64), 4.0)   # opens replica 1
    disp.sync()
    assert disp.placements[4] == 1
    for rid in (0, 1):             # replica 0 down to 0.5 slots load
        disp.enqueue_departure(rid, 5.0 + rid)
    disp.sync()
    c0 = obs.counter_get("consolidate.migrations")
    stats = disp.consolidate(8.0, "underload:t0.3")
    assert stats == {"migrations": 1, "bins_closed": 1,
                     "budget_exhausted": 0}
    assert obs.counter_get("consolidate.migrations") == c0 + 1
    assert disp.placements[4] == 0          # drained into replica 0
    assert disp._rid_slot[4] == disp._rid_slot[2]
    assert disp._open_now == 1              # the source replica closed
    # a second pass finds nothing to drain
    assert disp.consolidate(9.0, "underload:t0.3")["migrations"] == 0


def test_serving_drain_respects_budget():
    from repro.serving.dispatch import BlockDispatcher
    from repro.serving.scheduler import ReplicaCapacity, Request
    caps = ReplicaCapacity(slots=4, kv_tokens=1 << 20,
                           prefill_budget=1 << 20)
    disp = BlockDispatcher("first_fit", caps, tps=50.0, max_bins=8,
                           max_items=16, impl="jnp")
    for rid in range(4):
        disp.enqueue_arrival(Request(rid, float(rid), 64, 64), float(rid))
    for rid in (4, 5):
        disp.enqueue_arrival(Request(rid, 4.0, 64, 64), 4.0)
    disp.sync()
    for rid in range(3):           # replica 0 down to one occupant
        disp.enqueue_departure(rid, 5.0 + rid)
    disp.sync()
    # replicas 0 (0.25) and 1 (0.5): no budget -> no drain, counted
    stats = disp.consolidate(8.0, "underload:t0.3:b0")
    assert stats["migrations"] == 0 and stats["budget_exhausted"] >= 1
