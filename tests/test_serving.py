"""Serving: continuous batching correctness, DVBP placement invariants,
fleet objective orderings."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import params as P_
from repro.serving.engine import ReplicaEngine
from repro.serving.fleet import (attach_predictions, simulate_fleet,
                                 synth_requests)
from repro.serving.scheduler import (DVBPScheduler, ReplicaCapacity, Request)


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_reduced_config("qwen2.5-14b"),
                              dtype="float32")
    params = P_.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _generate(cfg, params, rid, prompt, n, slots=4):
    eng = ReplicaEngine(cfg, params, slots=slots, max_len=64, eos_id=-1)
    eng.admit(rid, prompt, n)
    toks = None
    while eng.n_active:
        for r, s in eng.seqs.items():
            toks = list(s.tokens)
        eng.step()
        for r, s in eng.seqs.items():
            toks = list(s.tokens)
    return toks


def test_interleaved_batching_matches_isolated(small_model):
    cfg, params = small_model
    eng = ReplicaEngine(cfg, params, slots=4, max_len=64, eos_id=-1)
    eng.admit(1, [5, 6, 7, 8], 6)
    for _ in range(2):
        eng.step()
    eng.admit(2, [9, 10, 11], 6)
    record = {}
    for _ in range(15):
        if not eng.n_active:
            break
        for rid, s in eng.seqs.items():
            record[rid] = list(s.tokens)
        eng.step()
        for rid, s in eng.seqs.items():
            record[rid] = list(s.tokens)
    assert record[1] == _generate(cfg, params, 1, [5, 6, 7, 8], 6)
    assert record[2] == _generate(cfg, params, 2, [9, 10, 11], 6)


def test_scheduler_capacity_never_exceeded():
    caps = ReplicaCapacity(slots=4, kv_tokens=4096, prefill_budget=4096)
    sched = DVBPScheduler("first_fit", caps)
    rng = np.random.default_rng(0)
    live = []
    t = 0.0
    for rid in range(200):
        t += float(rng.exponential(0.3))
        while live and live[0][0] <= t:
            ft, r = live.pop(0)
            sched.finish(r, ft)
        req = Request(rid, t, int(rng.integers(16, 512)),
                      int(rng.integers(8, 1024)))
        sched.place(req, t)   # BinPool asserts capacity internally
        live.append((t + req.decode_len / 50.0, rid))
        live.sort()
    while live:
        ft, r = live.pop(0)
        sched.finish(r, ft)
    assert not sched.pool._open_list          # all replicas released
    assert sched.stats.replica_seconds > 0


def test_fleet_dvbp_beats_round_robin():
    reqs = attach_predictions(synth_requests(600, seed=3), sigma=0.3, seed=3)
    rr = simulate_fleet(reqs, "round_robin")
    best = min(simulate_fleet(reqs, p)["replica_seconds"]
               for p in ["first_fit", "greedy", "nrt_prioritized"])
    assert best <= rr["replica_seconds"] * 1.02, \
        "DVBP placement should not lose to round robin"


def test_pack_all_beats_round_robin():
    """pack_all (single unbounded replica) is the lower-bound-ish baseline:
    it can never pay more replica-seconds than spraying round robin."""
    reqs = synth_requests(800, seed=11)
    pa = simulate_fleet(reqs, "pack_all")
    rr = simulate_fleet(reqs, "round_robin")
    assert pa["replica_seconds"] <= rr["replica_seconds"]
    assert pa["peak_replicas"] <= rr["peak_replicas"]


@pytest.mark.parametrize("policy", ["first_fit", "best_fit", "greedy",
                                    "nrt_prioritized"])
def test_dvbp_policies_respect_replica_capacity(policy):
    """After every placement, no open replica may exceed its capacity
    vector in any dimension (checked externally, not just via the BinPool
    assertion)."""
    caps = ReplicaCapacity(slots=4, kv_tokens=8192, prefill_budget=8192)
    sched = DVBPScheduler(policy, caps)
    rng = np.random.default_rng(7)
    live = []
    t = 0.0
    for rid in range(300):
        t += float(rng.exponential(0.2))
        while live and live[0][0] <= t:
            ft, r = live.pop(0)
            sched.finish(r, ft)
        req = Request(rid, t, int(rng.integers(16, 512)),
                      int(rng.integers(8, 1024)),
                      predicted_decode_len=int(rng.integers(8, 1024)))
        sched.place(req, t)
        open_bins = list(sched.pool._open_list)
        assert open_bins, "placement must leave at least one open replica"
        assert np.all(sched.pool.used[open_bins] <= 1.0 + 1e-9), \
            f"{policy} violated replica capacity"
        live.append((t + req.decode_len / 50.0, rid))
        live.sort()
    while live:
        ft, r = live.pop(0)
        sched.finish(r, ft)
    assert not sched.pool._open_list
    assert sched.stats.replica_seconds > 0


@pytest.mark.parametrize("policy,kwargs", [
    ("first_fit", None), ("best_fit", {"norm": "linf"}), ("mru", None),
    ("greedy", None), ("nrt_standard", None), ("nrt_prioritized", None),
    ("cbd", {"beta": 2.0}), ("cbdt", {"rho": 10.0}),
])
def test_scheduler_device_select_matches_host(policy, kwargs):
    """The fused on-device placement decision (kernels.ops.fitscore_select)
    agrees with the host algorithm zoo decision-for-decision - including
    the opening-order tie-break - on fp32-exact request sizes.  CBD/CBDT
    run their class-restricted First Fit through the kernel's category
    mask (tag == request class)."""
    caps = ReplicaCapacity(slots=4, kv_tokens=65536, prefill_budget=262144)

    def drive(backend):
        sched = DVBPScheduler(policy, caps, kwargs, select_backend=backend)
        rng = np.random.default_rng(5)
        live, t, picks = [], 0.0, []
        for rid in range(150):
            t += float(rng.integers(1, 8))
            while live and live[0][0] <= t:
                ft, r = live.pop(0)
                sched.finish(r, ft)
            req = Request(rid, t, int(rng.integers(16, 512)),
                          int(rng.integers(8, 1024)),
                          predicted_decode_len=int(rng.integers(8, 1024)))
            picks.append(sched.place(req, t))
            live.append((t + req.decode_len / 50.0, rid))
            live.sort()
        return picks, sched.stats.replicas_opened

    host = drive("host")
    assert host == drive("jnp")
    assert host == drive("pallas_interpret")


@pytest.mark.parametrize("policy,kwargs", [
    ("best_fit", {"norm": "linf"}), ("nrt_prioritized", None),
    ("cbd", {"beta": 2.0}),
])
def test_scheduler_select_block_matches_host(policy, kwargs):
    """select_block=True routes the on-device decision through the
    event-blocked replay megakernel at T=1 (one arrival event replayed on
    a snapshot of the pool) - decision-for-decision equal to the host
    algorithm zoo, so the sweep hot loop and serving share one kernel."""
    caps = ReplicaCapacity(slots=4, kv_tokens=65536, prefill_budget=262144)

    def drive(backend, block):
        sched = DVBPScheduler(policy, caps, kwargs, select_backend=backend,
                              select_block=block)
        rng = np.random.default_rng(5)
        live, t, picks = [], 0.0, []
        for rid in range(60):
            t += float(rng.integers(1, 8))
            while live and live[0][0] <= t:
                ft, r = live.pop(0)
                sched.finish(r, ft)
            req = Request(rid, t, int(rng.integers(16, 512)),
                          int(rng.integers(8, 1024)),
                          predicted_decode_len=int(rng.integers(8, 1024)))
            picks.append(sched.place(req, t))
            live.append((t + req.decode_len / 50.0, rid))
            live.sort()
        return picks, sched.stats.replicas_opened

    assert drive("host", False) == drive("pallas_interpret", True)


def test_fleet_objective_accounting():
    # one request -> exactly its service time of replica-seconds
    reqs = [Request(0, 0.0, 64, 500)]
    r = simulate_fleet(reqs, "first_fit", tps=50.0)
    assert r["replica_seconds"] == pytest.approx(10.0)
    assert r["replicas_opened"] == 1
