"""core.jaxsim (TPU-native scan replay) vs the Python oracle engine."""
import numpy as np
import pytest

from repro.core import Instance, get_algorithm, run
from repro.core.jaxsim import POLICIES, CapacityError, simulate
from repro.data import make_azure_like_suite


def quantized_instance(seed=7, n=600, d=4):
    """Sizes on a 1/64 grid + integer times: fp32-exact, so the jax replay
    must match the f64 oracle decision-for-decision."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 24, (n, d)) / 64.0
    arr = np.sort(rng.integers(0, 50000, n)).astype(float)
    dur = rng.integers(10, 5000, n).astype(float)
    return Instance(sizes, arr, arr + dur, "quantized").sorted_by_arrival()


def _alg(pol):
    if pol.startswith("best_fit"):
        return get_algorithm("best_fit", norm=pol.split("_")[-1])
    return get_algorithm(pol)


@pytest.mark.parametrize("policy", POLICIES)
def test_exact_match_on_fp32_exact_instance(policy):
    inst = quantized_instance()
    r = run(inst, _alg(policy))
    j = simulate(inst, policy, max_bins=r.peak_open_bins + 16)
    assert not j.overflowed
    assert j.n_bins_opened == r.n_bins_opened
    assert j.usage_time == pytest.approx(r.usage_time, abs=1e-3)


@pytest.mark.parametrize("policy", ["first_fit", "greedy"])
def test_close_on_general_instance(policy):
    inst = make_azure_like_suite(n_instances=1, n_items=800)[0]
    r = run(inst, _alg(policy))
    j = simulate(inst, policy, max_bins=r.peak_open_bins + 16)
    # fp32 near-ties may flip individual decisions; quality must agree
    assert j.usage_time == pytest.approx(r.usage_time, rel=0.05)


def test_overflow_flag():
    inst = quantized_instance(n=100)
    j = simulate(inst, "first_fit", max_bins=2, auto_grow=False)
    assert j.overflowed
    assert j.max_bins == 2


def test_overflow_auto_grow():
    """simulate() must escalate max_bins instead of returning garbage."""
    inst = quantized_instance(n=200)
    r = run(inst, _alg("first_fit"))
    j = simulate(inst, "first_fit", max_bins=1)   # guaranteed overflow
    assert not j.overflowed
    assert j.max_bins > 1                         # escalation happened
    assert j.n_bins_opened == r.n_bins_opened
    assert j.usage_time == pytest.approx(r.usage_time, abs=1e-3)


def test_overflow_cap_respected():
    """Exhausting the escalation ladder is a structured failure carrying
    the offending policy/instance, not a silently-garbage result."""
    inst = quantized_instance(n=100)
    with pytest.raises(CapacityError) as e:
        simulate(inst, "first_fit", max_bins=1, max_bins_cap=2)
    assert e.value.max_bins == 2
    assert e.value.policy == "first_fit"
    assert e.value.instance == inst.name
    # auto_grow=False keeps the overflow-flag contract: no raise
    j = simulate(inst, "first_fit", max_bins=2, max_bins_cap=2,
                 auto_grow=False)
    assert j.overflowed
    assert j.max_bins == 2
