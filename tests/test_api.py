"""repro.api: the one experiment API.

Covers (1) Policy parse/str round-trips + parse-time parameter-range
validation, (2) Experiment == legacy run_sweep record-for-record with
legacy result_key strings resolving against a pre-populated store, and
(3) the serving<->sweep unification: an Experiment over a
``serving_requests`` workload reproduces ``simulate_fleet`` usage/bins
decision-for-decision on both backends.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro import api
from repro.serving.fleet import simulate_fleet
from repro.serving.scheduler import ReplicaCapacity, Request
from repro.sweep import (PredModel, SuiteSpec, SweepSpec, SweepStore,
                         run_sweep)

# fp32-exact serving geometry: power-of-two capacities and token rate,
# integer arrivals and lengths, so the f32 batched replay must match the
# f64 host fleet decision-for-decision.
CAPS = ReplicaCapacity(slots=4, kv_tokens=65536, prefill_budget=262144)
TPS = 64.0


def synth_exact_requests(n=150, seed=3, predicted=True):
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for rid in range(n):
        t += float(rng.integers(1, 8))
        reqs.append(Request(
            rid, t, int(rng.integers(16, 512)), int(rng.integers(8, 1024)),
            predicted_decode_len=int(rng.integers(8, 1024))
            if predicted else None))
    return reqs


# ---------------------------------------------------------------- Policy

def test_policy_parse_str_roundtrip():
    for name in ("first_fit", "best_fit_l2", "cbd_beta4", "cbdt_rho3600",
                 "adaptive_2_8", "la_geometric", "ppe_modified"):
        p = api.Policy.parse(name)
        assert str(p) == name
        assert api.Policy.parse(str(p)) == p
    p = api.Policy.parse("cbd_beta4")
    assert p.beta == 4.0 and p.family == "cbd"
    assert p.category and p.scan and p.device_select and p.needs_predictions
    a = api.Policy.parse("adaptive_2_8")
    assert (a.low, a.high) == (2.0, 8.0)
    bf = api.Policy.parse("best_fit_l1")
    assert bf.norm == "l1" and not bf.category and bf.device_select
    assert api.Policy.parse(p) is p          # idempotent on Policy values


def test_policy_registry_introspection():
    ps = api.policies()
    names = [p.name for p in ps]
    assert set(api.SCAN_POLICIES) <= set(names)
    assert "next_fit" in names               # host-only, flagged
    nf = {p.name: p for p in ps}["next_fit"]
    assert not nf.scan and nf.family == "host"
    assert all(p.category == (p.name in api.CATEGORY_POLICIES)
               for p in ps if p.scan)


@pytest.mark.parametrize("bad,frag", [
    ("cbd_beta-1", "must be > 1"),
    ("cbd_beta1", "must be > 1"),
    ("cbd_beta0.25", "must be > 1"),
    ("cbdt_rho0", "must be > 0"),
    ("cbdt_rho-3600", "must be > 0"),
    ("adaptive_8_2", "1 <= low <= high"),
    ("adaptive_0.5_4", "1 <= low <= high"),
])
def test_parametric_policy_range_validated_at_parse(bad, frag):
    """Out-of-range parameters fail at parse time with the valid range in
    the message - not deep inside the scan."""
    with pytest.raises(ValueError, match="got"):
        api.Policy.parse(bad)
    with pytest.raises(ValueError) as ei:
        api.Policy.parse(bad)
    assert frag in str(ei.value)
    # the engine-level entry points surface the same error
    from repro.core.jaxsim import known_policy, policy_spec
    with pytest.raises(ValueError):
        policy_spec(bad)
    with pytest.raises(ValueError):
        known_policy(bad)
    with pytest.raises(ValueError):
        SweepSpec(policies=(bad,))


def test_unknown_and_malformed_policies_are_keyerrors():
    for name in ("no_such_policy", "cbd_betax", "adaptive_1_2_3"):
        with pytest.raises(KeyError):
            api.Policy.parse(name)


def test_policy_from_registry_matches_scan_lanes():
    assert api.Policy.from_registry("best_fit", norm="l2").name == \
        "best_fit_l2"
    assert api.Policy.from_registry("cbd", beta=4.0).name == "cbd_beta4"
    assert api.Policy.from_registry("cbdt", rho=3600.0).name == \
        "cbdt_rho3600"
    assert api.Policy.from_registry(
        "lifetime_alignment", mode="geometric").name == "la_geometric"
    assert api.Policy.from_registry("next_fit") is None or \
        not api.Policy.from_registry("next_fit").scan
    assert api.Policy.from_registry("best_fit", norm="l2", exotic=1) is None
    # round trip back to the host oracle registry
    name, kw = api.Policy.parse("cbd_beta4").registry_args()
    assert (name, kw) == ("cbd", {"beta": 4.0})


# ---------------------------------------------------- Experiment == sweep

def test_experiment_matches_legacy_run_sweep_and_store(tmp_path):
    """The facade produces record-identical results to run_sweep, and
    legacy result_key strings written by run_sweep resolve as cache hits
    for the Experiment."""
    suite = SuiteSpec("azure", 2, 120, seed=5)
    spec = SweepSpec(suites=(suite,), policies=("first_fit", "greedy"),
                     predictions=(PredModel("clairvoyant"),
                                  PredModel("lognormal", 1.0)),
                     seeds=(0, 1), max_bins=32)
    store = SweepStore(str(tmp_path))
    legacy = run_sweep(spec, store=store)          # pre-populate the store

    exp = api.Experiment(
        api.synthetic("azure", 2, 120, seed=5),
        policies=("first_fit", api.Policy.parse("greedy")),
        settings=(api.Setting.clairvoyant(),
                  api.Setting.predicted("lognormal", 1.0)),
        seeds=(0, 1), max_bins=32)
    log = []
    res = exp.run(store=str(tmp_path), progress=log.append)
    assert res.records == legacy
    assert log and all(m.startswith("skip") for m in log)   # all cached
    # tidy rows carry the explicit vocabulary columns
    rows = res.rows()
    assert {r["setting"] for r in rows} == \
        {"clairvoyant", "predicted:lognormal1"}
    assert all(r["workload"] == suite.label() for r in rows)
    st = res.summary()[(suite.label(), "greedy", "clairvoyant")]
    assert st.n == 2 and st.mean >= 1.0 - 1e-6
    assert res.ratios(policy="first_fit", setting="clairvoyant")


def test_experiment_rejects_host_only_policies():
    with pytest.raises(AssertionError, match="host-only"):
        api.Experiment(api.synthetic("azure", 1, 50),
                       policies=("next_fit",))


def test_nonclairvoyant_suite_rejects_prediction_reading_policies():
    """On suite workloads the engine cannot hide durations from greedy /
    nrt / category policies (they would silently see real departures), so
    the combination is an error; true non-clairvoyant policies run."""
    wl = api.synthetic("azure", 1, 60)
    with pytest.raises(ValueError, match="predicted-departure"):
        api.Experiment(wl, policies=("first_fit", "greedy"),
                       settings=(api.Setting.nonclairvoyant(),))
    res = api.Experiment(wl, policies=("first_fit", "mru"),
                         settings=(api.Setting.nonclairvoyant(),)).run()
    assert {r["policy"] for r in res.rows()} == {"first_fit", "mru"}


def test_instances_workload_digest_is_content_addressed():
    from repro.data import make_azure_like_suite
    insts = make_azure_like_suite(n_instances=2, n_items=60, seed=9)
    w1 = api.instances(insts, name="a")
    w2 = api.instances(list(insts), name="b")
    assert w1.digest == w2.digest                 # same content
    other = make_azure_like_suite(n_instances=2, n_items=60, seed=10)
    assert api.instances(other).digest != w1.digest
    # instance names are part of the content: records are keyed by them
    renamed = [dataclasses.replace(i, name=i.name + "-v2") for i in insts]
    assert api.instances(renamed).digest != w1.digest


def test_results_scoped_to_the_experiment_cells(tmp_path):
    """A shared store file accumulates records across experiments;
    Results must only report the cells the experiment asked for."""
    wl = api.synthetic("azure", 2, 100, seed=4)
    api.Experiment(wl, policies=("first_fit",)).run(store=str(tmp_path))
    res = api.Experiment(wl, policies=("greedy",)).run(store=str(tmp_path))
    assert {r["policy"] for r in res.rows()} == {"greedy"}
    assert len(res.records) == 2
    assert set(res.summary()) == {(wl.label(), "greedy", "clairvoyant")}


def test_experiment_backend_identity_on_exact_instances():
    """Experiment passes ``backend`` through to the replay engine: jnp and
    interpret-mode Pallas produce bit-identical records on fp32-exact
    instances (the engine-level guarantee, surfaced at the facade)."""
    rng = np.random.default_rng(2)
    insts = []
    for k, n in enumerate((40, 80)):
        sizes = rng.integers(1, 24, (n, 3)) / 64.0
        arr = np.sort(rng.integers(0, 5000, n)).astype(float)
        dur = rng.integers(10, 500, n).astype(float)
        from repro.core import Instance
        insts.append(Instance(sizes, arr, arr + dur, f"x{k}"))
    wl = api.instances(insts, name="exact")
    exp = api.Experiment(wl, policies=("best_fit_linf", "cbd"),
                         settings=(api.Setting.clairvoyant(),))
    a = exp.run(backend="jnp")
    b = exp.run(backend="pallas_interpret")
    assert a.records == b.records


# -------------------------------------------------- serving <-> sweep

@pytest.mark.parametrize("policy,kwargs,backend", [
    ("first_fit", None, "jnp"),
    ("best_fit", {"norm": "linf"}, "jnp"),
    ("greedy", None, "jnp"),
    ("nrt_prioritized", None, "jnp"),
    ("cbd", {"beta": 2.0}, "jnp"),
    ("greedy", None, "pallas_interpret"),
    ("cbd", {"beta": 2.0}, "pallas_interpret"),
])
def test_serving_requests_reproduces_simulate_fleet(policy, kwargs, backend):
    """Fleet capacity planning through the batched replay: usage totals
    and opened-replica counts match the host fleet simulation
    decision-for-decision."""
    reqs = synth_exact_requests()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        fleet = simulate_fleet(reqs, policy, CAPS, TPS,
                               policy_kwargs=kwargs)
    pol = api.Policy.from_registry(policy, **(kwargs or {}))
    wl = api.serving_requests(reqs, caps=CAPS, tps=TPS, name="parity")
    res = api.Experiment(wl, policies=(pol,),
                         settings=(api.Setting.predicted(),)).run(
        backend=backend)
    (rec,) = res.rows()
    assert rec["usage_time"] == pytest.approx(fleet["replica_seconds"],
                                              abs=1e-3)
    assert rec["n_bins_opened"] == fleet["replicas_opened"]
    assert rec["setting"] == "predicted:attached"


@pytest.mark.parametrize("policy", ["first_fit", "mru", "greedy"])
def test_serving_nonclairvoyant_matches_fleet(policy):
    """No predictions attached: the scheduler feeds `now` into the
    indicated-close clock; the workload replays with pdep == arrival."""
    reqs = synth_exact_requests(n=120, seed=9, predicted=False)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        fleet = simulate_fleet(reqs, policy, CAPS, TPS)
    wl = api.serving_requests(reqs, caps=CAPS, tps=TPS, name="noncl")
    res = api.Experiment(wl, policies=(policy,),
                         settings=(api.Setting.nonclairvoyant(),)).run(
        backend="jnp")
    (rec,) = res.rows()
    assert rec["usage_time"] == pytest.approx(fleet["replica_seconds"],
                                              abs=1e-3)
    assert rec["n_bins_opened"] == fleet["replicas_opened"]


def test_serving_records_land_in_the_sweep_store(tmp_path):
    """Serving workloads share the sweep store: second run is a pure
    cache hit and the persisted result_key strings parse the same
    suite/instance/policy/pred/seed shape as grid records."""
    reqs = synth_exact_requests(n=80, seed=1)
    wl = api.serving_requests(reqs, caps=CAPS, tps=TPS, name="stored")
    exp = api.Experiment(wl, policies=("first_fit", "greedy"),
                         settings=(api.Setting.predicted(),))
    r1 = exp.run(store=str(tmp_path))
    log = []
    r2 = exp.run(store=str(tmp_path), progress=log.append)
    assert r2.records == r1.records
    assert log and all(m.startswith("skip") for m in log)
    for key, rec in r1.records.items():
        suite, instance, policy, pred, seed = key.rsplit("/", 4)
        assert suite == wl.label() and instance == "stored"
        assert rec["policy"] == policy and rec["pred"] == pred == "attached"
        assert rec["lower_bound"] > 0 and rec["ratio"] >= 1.0 - 1e-6


def test_serving_requires_attached_predictions_when_asked():
    reqs = synth_exact_requests(n=20, predicted=False)
    wl = api.serving_requests(reqs, caps=CAPS, tps=TPS, name="nopred")
    with pytest.raises(AssertionError, match="attached"):
        api.Experiment(wl, policies=("greedy",),
                       settings=(api.Setting.predicted(),)).run()


# ----------------------------------------------------------- Setting

def test_setting_parse_and_validation():
    assert api.Setting.parse("clairvoyant").kind == "clairvoyant"
    assert api.Setting.parse("nonclairvoyant").label() == "nonclairvoyant"
    s = api.Setting.predicted("uniform", 4.0)
    assert s.model == PredModel("uniform", 4.0)
    assert s.label() == "predicted:uniform4"
    assert api.Setting.predicted().label() == "predicted:attached"
    with pytest.raises(AssertionError):
        api.Setting("clairvoyant", PredModel("lognormal", 1.0))
    with pytest.raises(AssertionError):   # exact models are not "predicted"
        api.Setting.predicted(PredModel("clairvoyant"))
    with pytest.raises(KeyError):
        api.Setting.parse("oracle")
    # synthetic workloads refuse attached predictions (they have none)
    with pytest.raises(AssertionError, match="attached"):
        api.synthetic("azure", 1, 50).pred_model(api.Setting.predicted())


# ------------------------------------------------------- migration shims

def test_legacy_entry_points_warn_once_with_migration_tag():
    from repro.api import _migration
    _migration._WARNED.clear()
    reqs = synth_exact_requests(n=5)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        simulate_fleet(reqs, "first_fit", CAPS, TPS)
        simulate_fleet(reqs, "first_fit", CAPS, TPS)
    tagged = [x for x in w if "REPRO_API_MIGRATION" in str(x.message)]
    assert len(tagged) == 1                       # once per process
    assert issubclass(tagged[0].category, DeprecationWarning)
    assert "repro.api" in str(tagged[0].message)
    # the host-only baselines have no api replacement: no migration nag
    _migration._WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        simulate_fleet(reqs, "round_robin", CAPS, TPS)
        simulate_fleet(reqs, "pack_all", CAPS, TPS)
    assert not [x for x in w if "REPRO_API_MIGRATION" in str(x.message)]


def test_scheduler_accepts_policy_objects():
    from repro.serving.scheduler import DVBPScheduler
    sched = DVBPScheduler(api.Policy.parse("cbd_beta4"), CAPS)
    assert sched.alg.name == "cbd_beta4"
    sched2 = DVBPScheduler(api.Policy.parse("best_fit_l2"), CAPS)
    assert sched2._device_policy == "best_fit_l2"
