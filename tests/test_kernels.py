"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,Sq,Skv,H,KV,hd", [
    (2, 128, 128, 4, 2, 64), (1, 256, 256, 4, 4, 64),
    (2, 100, 100, 2, 1, 32), (1, 64, 192, 4, 2, 128),
    (1, 96, 96, 8, 8, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32),
                                           (False, 0)])
def test_flash_attention(B, Sq, Skv, H, KV, hd, dtype, causal, window):
    if not causal and Sq != Skv:
        q = jax.random.normal(KEY, (B, Sq, H, hd), dtype)
    q = jax.random.normal(KEY, (B, Sq, H, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Skv, KV, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Skv, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="pallas_interpret")
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol(dtype),
                               rtol=tol(dtype))


@pytest.mark.parametrize("B,H,KV,hd,S", [
    (2, 8, 2, 64, 512), (1, 4, 4, 128, 300), (3, 5, 1, 32, 64),
    (2, 16, 8, 64, 1024),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, KV, hd, S, dtype):
    q = jax.random.normal(KEY, (B, H, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd), dtype)
    kl = jax.random.randint(jax.random.PRNGKey(3), (B,), 1, S + 1)
    out = ops.decode_attention(q, k, v, kl, impl="pallas_interpret")
    want = ref.decode_attention_ref(q, k, v, kl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol(dtype),
                               rtol=tol(dtype))


@pytest.mark.parametrize("B,S,H,K,V", [
    (2, 64, 2, 16, 16), (1, 48, 4, 32, 64), (2, 16, 1, 8, 8),
    (1, 128, 2, 64, 64),
])
def test_rwkv6(B, S, H, K, V):
    r = jax.random.normal(KEY, (B, S, H, K))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, K)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, V))
    lw = -jnp.exp(jax.random.normal(jax.random.PRNGKey(3), (B, S, H, K)))
    u = jax.random.normal(jax.random.PRNGKey(4), (H, K)) * 0.1
    y1, s1 = ops.rwkv6(r, k, v, lw, u, impl="pallas_interpret")
    y2, s2 = ref.rwkv6_ref(r, k, v, jnp.clip(lw, -4.0, 0.0), u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-4)


def test_rwkv6_chunked_matches_sequential_model_path():
    """models/linear_scan (the XLA path) == kernel == ref on one input."""
    from repro.models.linear_scan import chunked_linear_attention
    B, S, H, K, V = 2, 64, 2, 16, 16
    r = jax.random.normal(KEY, (B, S, H, K))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, K)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, V))
    lw = -jnp.exp(jax.random.normal(jax.random.PRNGKey(3), (B, S, H, K)))
    u = jax.random.normal(jax.random.PRNGKey(4), (H, K)) * 0.1
    y_model, s_model = chunked_linear_attention(r, k, v, lw, u=u, chunk=16)
    y_ref, s_ref = ref.rwkv6_ref(r, k, v, jnp.clip(lw, -4.0, 0.0), u)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_model), np.asarray(s_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("N,d,norm", [
    (100, 4, "linf"), (1000, 5, "l1"), (37, 2, "l2"), (300, 4, "first_fit"),
    (8, 4, "linf"), (256, 1, "linf"),
])
def test_fitscore(N, d, norm):
    rng = np.random.default_rng(0)
    rem = jnp.array(rng.random((N, d)))
    alive = jnp.array(rng.random(N) > 0.3)
    item = jnp.array(rng.random(d) * 0.5)
    s1, b1 = ops.fitscore(rem, alive, item, norm=norm,
                          impl="pallas_interpret")
    s2, b2 = ops.fitscore(rem, alive, item, norm=norm, impl="ref")
    np.testing.assert_allclose(np.nan_to_num(np.asarray(s1), posinf=1e9),
                               np.nan_to_num(np.asarray(s2), posinf=1e9),
                               atol=1e-5, rtol=1e-5)
    assert int(b1) == int(b2) or float(s2[b1]) == pytest.approx(
        float(s2[b2]))


def test_fitscore_no_feasible():
    rem = jnp.zeros((10, 3))
    alive = jnp.ones(10, bool)
    item = jnp.ones(3) * 0.5
    _, b = ops.fitscore(rem, alive, item, impl="pallas_interpret")
    assert int(b) == -1


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_fitscore_ties_break_by_open_seq(impl):
    """Score ties fall to the earliest-*opened* bin (the oracle's rule), not
    the smallest slot index: slots 0/2 tie but slot 2 opened first."""
    rem = jnp.array([[0.5, 0.5], [0.125, 0.75], [0.5, 0.5]])
    alive = jnp.ones(3, bool)
    item = jnp.array([0.25, 0.25])
    open_seq = jnp.array([7, 3, 1], jnp.int32)
    for norm in ("l1", "l2", "linf"):
        _, b = ops.fitscore(rem, alive, item, open_seq, norm=norm, impl=impl)
        assert int(b) == 2, (impl, norm)
    # without open_seq the slot index is the opening order: slot 0 wins
    _, b = ops.fitscore(rem, alive, item, norm="linf", impl=impl)
    assert int(b) == 0, impl
    # first_fit scores ARE the opening order
    _, b = ops.fitscore(rem, alive, item, open_seq, norm="first_fit",
                        impl=impl)
    assert int(b) == 2, impl
