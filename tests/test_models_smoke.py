"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, shape + finiteness + decode-vs-train consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced_config
from repro.models import params as P_
from repro.models.transformer import Runtime, forward, init_cache
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

RT = Runtime(mesh=None)
KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=32):
    kw = {}
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    if cfg.frontend == "audio_stub":
        kw["enc_embeds"] = 0.1 * jax.random.normal(
            KEY, (B, 48, cfg.d_model), jnp.float32)
        toks = toks[:, :16]
    if cfg.frontend == "vision_stub":
        kw["frontend_embeds"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    return toks, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = dataclasses.replace(get_reduced_config(arch), dtype="float32")
    p = P_.init_params(KEY, cfg, dtype=jnp.float32)
    toks, kw = _inputs(cfg)
    logits, _, aux = forward(p, cfg, RT, toks, mode="train", **kw)
    S_out = toks.shape[1] + (cfg.n_frontend_tokens
                             if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (2, S_out, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg = dataclasses.replace(get_reduced_config(arch), dtype="float32")
    p = P_.init_params(KEY, cfg, dtype=jnp.float32)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_opt_state(p, opt)
    step = make_train_step(cfg, RT, opt, microbatches=2)
    toks, kw = _inputs(cfg, B=4)
    batch = {"tokens": toks, "labels": toks, **kw}
    p2, state2, metrics = jax.jit(step)(p, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(not np.allclose(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_train_forward(arch):
    cfg = dataclasses.replace(get_reduced_config(arch), dtype="float32",
                              remat=False)
    p = P_.init_params(KEY, cfg, dtype=jnp.float32)
    B, S = 2, 24
    toks, kw = _inputs(cfg, B=B, S=S)
    S_eff = toks.shape[1]
    ref, _, _ = forward(p, cfg, RT, toks, mode="train", **kw)
    cache = init_cache(cfg, B, S_eff + (cfg.n_frontend_tokens
                       if cfg.frontend == "vision_stub" else 0),
                       dtype=jnp.float32)
    lp, cache, _ = forward(p, cfg, RT, toks[:, :-1], mode="prefill",
                           cache=cache, cache_pos=0, **kw)
    pos = S_eff - 1 + (cfg.n_frontend_tokens
                       if cfg.frontend == "vision_stub" else 0)
    ld, _, _ = forward(p, cfg, RT, toks[:, -1:], mode="decode", cache=cache,
                       cache_pos=pos)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    err = float(jnp.max(jnp.abs(ld[:, 0] - ref[:, -1]))) / scale
    assert err < 1e-4, f"decode diverges from train path: {err}"


def test_full_configs_param_counts():
    """The assigned full configs match their nameplate sizes."""
    expect = {"gemma3-12b": (10, 14), "qwen2.5-14b": (13, 16),
              "minitron-8b": (7, 9), "nemotron-4-340b": (320, 360),
              "granite-moe-3b-a800m": (3, 3.7),
              "deepseek-v2-lite-16b": (14, 17), "whisper-medium": (0.6, 1.0),
              "pixtral-12b": (11, 13.5), "rwkv6-1.6b": (1.3, 1.8),
              "hymba-1.5b": (1.1, 1.8)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_dense_vs_dropping_agree():
    """With ample capacity the sort-based dispatch == dense reference."""
    import numpy as np
    from repro.models.moe import moe_block
    cfg = dataclasses.replace(get_reduced_config("granite-moe-3b-a800m"),
                              dtype="float32", capacity_factor=8.0)
    p = P_.init_params(KEY, cfg, dtype=jnp.float32)
    blk = jax.tree.map(lambda x: x[0], p["layers"])
    moe_params = {k: v for k, v in blk.items()
                  if k.startswith(("router", "we_", "shared_"))}
    x = 0.5 * jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    dense, _ = moe_block(moe_params, x, cfg, mesh=None, impl="dense")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    drop, _ = moe_block(moe_params, x, cfg, mesh=mesh)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(drop),
                               atol=1e-5, rtol=1e-5)
