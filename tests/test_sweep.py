"""repro.sweep (batched vmapped replay) vs the Python oracle engine.

The parity matrix: every jaxsim policy x three prediction settings
(non-clairvoyant, clairvoyant, noisy) x six mixed-size instances packed into
one padded batch (varied n -> heavily padded lanes; varied d -> the dmask
path).  Instances are fp32-exact (sizes on a 1/64 grid, integer times,
power-of-two prediction noise) so the batched replay must match the f64
oracle decision-for-decision.
"""
import numpy as np
import pytest

from repro.core import Instance, get_algorithm, run
from repro.core.jaxsim import POLICIES
from repro.sweep import (PredModel, SuiteSpec, SweepSpec, SweepStore,
                         pack_instances, pad_predictions, run_batch,
                         run_sweep)

SETTINGS = ("nonclairvoyant", "clairvoyant", "noisy0", "noisy1")


def quantized_instance(seed, n, d):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 24, (n, d)) / 64.0
    arr = np.sort(rng.integers(0, 50000, n)).astype(float)
    dur = rng.integers(10, 5000, n).astype(float)
    return Instance(sizes, arr, arr + dur,
                    f"q{seed}").sorted_by_arrival()


def pow2_noise(inst, seed):
    """fp32-exact 'noisy predictions': power-of-two duration multipliers."""
    rng = np.random.default_rng(seed)
    delta = rng.choice([0.25, 0.5, 1.0, 2.0, 4.0], inst.n_items)
    return inst.durations * delta


@pytest.fixture(scope="module")
def mixed():
    """6 instances with mixed item counts AND mixed dimensionality."""
    shapes = [(1, 120, 3), (2, 300, 4), (3, 600, 5), (4, 50, 4),
              (5, 450, 3), (6, 220, 5)]
    insts = [quantized_instance(*s) for s in shapes]
    batch = pack_instances(insts)
    # per-lane (4, n) predicted durations, one row per setting: rows 0/1 are
    # the real durations (non-clairvoyant / clairvoyant replay both see real
    # departures on-device), rows 2/3 are two seeds of exact pow2 noise
    preds = [np.stack([i.durations, i.durations,
                       pow2_noise(i, 100), pow2_noise(i, 101)])
             for i in insts]
    return insts, batch, preds


def _alg(pol):
    if pol.startswith("best_fit"):
        return get_algorithm("best_fit", norm=pol.split("_")[-1])
    return get_algorithm(pol)


def _oracle_pdur(inst, pred_rows, setting):
    if setting == "nonclairvoyant":
        return None                      # engine: pdep = real departures
    if setting == "clairvoyant":
        return inst.durations
    return pred_rows[int(setting[-1]) + 2]


@pytest.mark.parametrize("policy", POLICIES)
def test_batched_matches_oracle(policy, mixed):
    insts, batch, preds = mixed
    pdeps = pad_predictions(batch, preds)
    res = run_batch(batch, policy, pdeps, max_bins=64)
    assert not res.overflowed.any()
    for i, inst in enumerate(insts):
        for si, setting in enumerate(SETTINGS):
            r = run(inst, _alg(policy),
                    predicted_durations=_oracle_pdur(inst, preds[i],
                                                     setting))
            assert res.n_bins_opened[i, si] == r.n_bins_opened, \
                (policy, inst.name, setting)
            assert res.usage_time[i, si] == pytest.approx(
                r.usage_time, abs=1e-3), (policy, inst.name, setting)


def test_padded_lane_equals_solo_run(mixed):
    """A short lane padded into a big batch must equal its solo replay."""
    insts, batch, _ = mixed
    idx = 3                              # n=50, heavily padded (n_max=600)
    solo = run_batch(pack_instances([insts[idx]]), "best_fit_linf",
                     max_bins=64)
    res = run_batch(batch, "best_fit_linf", max_bins=64)
    assert res.usage_time[idx, 0] == solo.usage_time[0, 0]
    assert res.n_bins_opened[idx, 0] == solo.n_bins_opened[0, 0]


def test_lanewise_overflow_escalation(mixed):
    """Starting from a tiny slot pool, overflowed lanes are re-run with a
    doubled pool until they fit - results still match the oracle."""
    insts, batch, _ = mixed
    res = run_batch(batch, "first_fit", max_bins=2)
    assert not res.overflowed.any()
    assert (res.max_bins > 2).any()      # escalation actually happened
    for i, inst in enumerate(insts):
        r = run(inst, _alg("first_fit"))
        assert res.usage_time[i, 0] == pytest.approx(r.usage_time, abs=1e-3)


def test_escalation_cap(mixed):
    insts, batch, _ = mixed
    res = run_batch(batch, "first_fit", max_bins=1, max_bins_cap=2)
    assert res.overflowed.any()          # cap too small: flagged, not hidden
    assert res.max_bins.max() == 2


def test_run_sweep_incremental(tmp_path):
    spec = SweepSpec(suites=(SuiteSpec("azure", 2, 120, 5),),
                     policies=("first_fit", "greedy"),
                     predictions=(PredModel("clairvoyant"),
                                  PredModel("lognormal", 1.0)),
                     seeds=(0, 1), max_bins=32)
    store = SweepStore(str(tmp_path))
    log1, log2 = [], []
    rec1 = run_sweep(spec, store=store, progress=log1.append)
    # 2 policies x (clairvoyant 1 seed + lognormal 2 seeds) x 2 instances
    assert len(rec1) == 2 * 3 * 2
    assert all(r["ratio"] >= 1.0 - 1e-6 for r in rec1.values())
    assert not any(r["overflowed"] for r in rec1.values())
    rec2 = run_sweep(spec, store=store, progress=log2.append)
    assert rec2 == rec1
    assert all(m.startswith("skip") for m in log2)       # fully cached
    assert store.load(spec) == rec1
    # extending the grid over the same suites reuses every cached group
    wider = SweepSpec(suites=spec.suites,
                      policies=("first_fit", "greedy", "mru"),
                      predictions=spec.predictions, seeds=spec.seeds,
                      max_bins=32)
    assert wider.suites_hash() == spec.suites_hash()
    log3 = []
    rec3 = run_sweep(wider, store=store, progress=log3.append)
    ran = [m for m in log3 if m.startswith("run")]
    assert len(ran) == 2 and all("/mru/" in m for m in ran)
    assert {k: v for k, v in rec3.items() if "/mru/" not in k} == rec1


def test_sweep_spec_hash_is_canonical():
    a = SweepSpec(policies=("first_fit",))
    b = SweepSpec(policies=("first_fit",))
    c = SweepSpec(policies=("greedy",))
    assert a.spec_hash() == b.spec_hash()
    assert a.spec_hash() != c.spec_hash()
