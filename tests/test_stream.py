"""Streamed chunked replay (repro.stream) vs the in-memory engine.

The contract under test: cutting a request stream into fixed-geometry
chunks, replaying them through the carried scan state over a recycled item
row pool, is *bit-identical* to ``jaxsim.simulate`` on the materialized
instance - usage, opened bins, placements, escalation ladder - for every
policy family, across chunk boundaries that land on MIGRATE events and on
overflow escalations, through pool growth, checkpoint/resume and the
multi-process sweep launcher's store merge.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import obs
from repro.api.workload import Setting, stream_source, synthetic
from repro.core import jaxsim
from repro.core.jaxsim import _replay_batch, simulate
from repro.data.traces import _one_instance, load_azure_csv
from repro.kernels.fitscore import (ARRIVAL_KIND, DEPARTURE_KIND,
                                    MIGRATE_KIND)
from repro.resilience.checkpoint import StreamCheckpointer
from repro.stream import (ChunkedWorkload, CsvSource, InstanceSource,
                          chunk_instance_events, replay_chunked_events,
                          replay_stream, synthetic_source)
from repro.sweep import SuiteSpec, SweepSpec, SweepStore, run_sweep

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "azure_packing2020")

# one policy per carry family (score / cbd / hybrid / rcp / la / adaptive)
FAMILY_POLICIES = ("best_fit_l2", "cbd", "hybrid", "rcp", "la_binary",
                   "adaptive")


def _stream_instance(seed=3, n=120, d=4):
    """azure-like synthetic instance, small enough for per-test replay."""
    return _one_instance(seed, n, d, 8, 1800.0, 1.6, f"stream_t{seed}")


def _assert_matches(res, ref, policy):
    assert res.usage == pytest.approx(float(ref.usage_time), rel=0,
                                      abs=0), policy
    assert res.opened == int(ref.n_bins_opened), policy
    assert res.overflow == bool(ref.overflowed), policy
    assert res.max_bins == int(ref.max_bins), policy
    if res.placements is not None:
        assert np.array_equal(res.placements,
                              np.asarray(ref.placements)), policy


# ---------------------------------------------------------------- equality

@pytest.mark.parametrize("policy", FAMILY_POLICIES)
def test_streamed_equals_in_memory_per_family(policy):
    """Chunked streamed replay == simulate, including placements, with a
    pool a fraction of the item count (recycling) for non-hybrid."""
    inst = _stream_instance()
    ref = simulate(inst, policy=policy, max_bins=64)
    res = replay_stream(InstanceSource(inst), policy, chunk_events=32,
                        item_rows=24, max_bins=64,
                        collect_placements=True)
    _assert_matches(res, ref, policy)
    if policy != "hybrid":          # hybrid pins the full table (identity)
        assert res.item_rows < inst.n_items


@pytest.mark.parametrize("chunk_events", (7, 32, 1024))
def test_chunk_geometry_never_changes_results(chunk_events):
    """Any chunk size - smaller than, dividing, or dwarfing the event
    count - produces the same decisions (PAD no-ops + carried state)."""
    inst = _stream_instance(seed=9, n=60)
    ref = simulate(inst, policy="mru", max_bins=64)
    res = replay_stream(InstanceSource(inst), "mru",
                        chunk_events=chunk_events, item_rows=16,
                        max_bins=64, collect_placements=True)
    _assert_matches(res, ref, f"C={chunk_events}")


def test_pool_growth_mid_stream():
    """A pool that starts too small doubles on demand and still replays
    bit-identically (fresh rows are virgin until assigned)."""
    inst = _stream_instance(seed=11, n=200)
    ref = simulate(inst, policy="first_fit", max_bins=64)
    c0 = obs.counter_get("stream.pool_growths")
    res = replay_stream(InstanceSource(inst), "first_fit",
                        chunk_events=64, item_rows=4, max_bins=64,
                        collect_placements=True)
    _assert_matches(res, ref, "grown")
    assert res.item_rows > 4
    assert obs.counter_get("stream.pool_growths") > c0


def test_prefetch_depth_is_execution_only():
    """prefetch=0 (synchronous) and prefetch=3 replay identically."""
    inst = _stream_instance(seed=4, n=80)
    a = replay_stream(InstanceSource(inst), "best_fit_linf",
                      chunk_events=32, item_rows=32, prefetch=0)
    b = replay_stream(InstanceSource(inst), "best_fit_linf",
                      chunk_events=32, item_rows=32, prefetch=3)
    assert (a.usage, a.opened, a.max_bins) == (b.usage, b.opened,
                                               b.max_bins)


def test_kernel_backend_chunked():
    """The event-blocked kernel path (pallas_interpret) streams too:
    chunk_events is a multiple of block_events, carry packed."""
    inst = _stream_instance(seed=6, n=40)
    for policy in ("first_fit", "rcp"):
        ref = simulate(inst, policy=policy, max_bins=32,
                       backend="pallas_interpret", block_events=16)
        res = replay_stream(InstanceSource(inst), policy, chunk_events=16,
                            item_rows=48, max_bins=32,
                            backend="pallas_interpret", block_events=16)
        _assert_matches(res, ref, policy)


# ------------------------------------------------- boundary corner cases

@pytest.mark.parametrize("chunk_events", (8, 9, 10))
def test_migrate_event_across_chunk_boundary(chunk_events):
    """A MIGRATE event adjacent to / exactly on a chunk boundary replays
    like the unchunked migrate-enabled scan (18 events; C=9 puts the
    second MIGRATE as a chunk's last event, C=8 as a chunk's first)."""
    n, d = 8, 3
    rng = np.random.default_rng(0)
    sizes = (rng.integers(1, 24, (n, d)) / 64.0).astype(np.float32)
    arrivals = np.arange(n, dtype=np.float32)
    rdeps = arrivals + np.float32(100.0) + np.arange(n, dtype=np.float32)
    # 8 arrivals, then 2 MIGRATEs at t=10 (items 0, 1 - alive), then deps
    times = np.concatenate([arrivals, [10.0, 10.0], rdeps]).astype(
        np.float32)
    kinds = np.concatenate([np.full(n, ARRIVAL_KIND),
                            [MIGRATE_KIND, MIGRATE_KIND],
                            np.full(n, DEPARTURE_KIND)]).astype(np.int32)
    items = np.concatenate([np.arange(n), [0, 1],
                            np.arange(n)]).astype(np.int32)
    n1 = np.full(1, n, np.int32)
    ref = _replay_batch(sizes[None], times[None], kinds[None], items[None],
                        rdeps[None], None, arrivals[None], rdeps[None], n1,
                        policy="best_fit_l2", max_bins=8, backend="jnp",
                        migrate=True)
    usage, opened, placements, overflow = replay_chunked_events(
        sizes, times, kinds, items, rdeps, arrivals, rdeps,
        policy="best_fit_l2", chunk_events=chunk_events, max_bins=8,
        migrate=True)
    assert usage == np.asarray(ref[0])[0]
    assert opened == np.asarray(ref[1])[0]
    assert np.array_equal(placements, np.asarray(ref[2])[0])
    assert overflow == np.asarray(ref[3])[0]


def test_overflow_rung_on_chunk_boundary():
    """chunk_events=1 puts a boundary after EVERY event - including the
    one that overflows the slot pool - and the escalation ladder restarts
    the stream with a doubled pool, landing on simulate's exact result."""
    inst = _one_instance(3, 40, 4, 8, 860000.0, 0.4, "dense")
    ref = simulate(inst, policy="first_fit", max_bins=4, auto_grow=True)
    assert int(ref.max_bins) > 4    # the instance must actually escalate
    c0 = obs.counter_get("stream.overflow_rungs")
    res = replay_stream(InstanceSource(inst), "first_fit",
                        chunk_events=1, item_rows=64, max_bins=4,
                        collect_placements=True)
    _assert_matches(res, ref, "ladder")
    assert obs.counter_get("stream.overflow_rungs") > c0


def test_capacity_error_at_cap():
    inst = _one_instance(3, 40, 4, 8, 860000.0, 0.4, "dense")
    with pytest.raises(jaxsim.CapacityError):
        replay_stream(InstanceSource(inst), "first_fit", chunk_events=64,
                      item_rows=64, max_bins=2, max_bins_cap=2)


def test_chunk_builder_validates_order_and_pool():
    src = InstanceSource(_stream_instance(seed=2, n=30))

    class Shuffled:
        def meta(self):
            return src.meta()

        def records(self):
            recs = list(src.records())
            return iter(recs[::-1])

    with pytest.raises(ValueError, match="arrival-sorted"):
        list(ChunkedWorkload(Shuffled(), "first_fit",
                             chunk_events=16, item_rows=8).chunks())
    with pytest.raises(RuntimeError, match="pool exhausted"):
        list(ChunkedWorkload(src, "first_fit", chunk_events=16,
                             item_rows=2, grow=False).chunks())


def test_chunk_instance_events_padding():
    times = np.arange(10, dtype=np.float32)
    kinds = np.ones(10, np.int32)
    items = np.arange(10, dtype=np.int32)
    extra = np.arange(10, dtype=np.int32) * 2
    out = list(chunk_instance_events(times, kinds, items, 4, (extra,)))
    assert len(out) == 3 and out[-1][-1] and not out[0][-1]
    t, k, i, (x,), _ = out[-1]
    assert (t.shape, k.shape, i.shape, x.shape) == ((4,),) * 4
    assert list(k) == [1, 1, -1, -1]        # PAD tail
    assert list(x) == [16, 18, 18, 18]      # PADs carry the running extra


# -------------------------------------------------------- sources / API

def test_csv_source_matches_loader():
    """Line-by-line CSV streaming == the materializing loader, and the
    streamed replay of it == simulate on the loaded instance."""
    insts = {i.name: i for i in load_azure_csv(FIXTURE)}
    for pm in (0, 1):
        inst = insts[f"azure_pm{pm}"]       # already arrival-sorted
        src = CsvSource(FIXTURE, machine_id=pm)
        recs = list(src.records())
        assert len(recs) == inst.n_items
        for j, (size, arr, dep, pdep) in enumerate(recs):
            assert np.array_equal(size, inst.sizes[j])
            assert (arr, dep, pdep) == (inst.arrivals[j],
                                        inst.departures[j],
                                        inst.departures[j])
        ref = simulate(inst, policy="best_fit_l2", max_bins=16)
        res = replay_stream(src, "best_fit_l2", chunk_events=4,
                            item_rows=8, max_bins=16)
        assert (res.usage, res.opened) == (float(ref.usage_time),
                                           int(ref.n_bins_opened))


def test_stream_source_settings():
    """api.stream_source bridges workloads: clairvoyant == simulate,
    noisy models thread predicted departures into the stream."""
    wl = synthetic("azure", n_instances=1, n_items=50, seed=7)
    inst = wl.suite().build()[0]
    res = replay_stream(stream_source(wl), "greedy", chunk_events=32,
                        item_rows=16, max_bins=64)
    ref = simulate(inst, policy="greedy", max_bins=64)
    assert (res.usage, res.opened) == (float(ref.usage_time),
                                       int(ref.n_bins_opened))
    noisy = stream_source(wl, 0, Setting.predicted("lognormal", 0.5),
                          seed=3)
    assert any(abs(p - dep) > 1e-9 for (_, _, dep, p) in noisy.records())
    exact = stream_source(wl, inst.name, "nonclairvoyant")
    assert all(p == dep for (_, _, dep, p) in exact.records())


def test_checkpoint_resume_bit_identical(tmp_path):
    """A killed streamed replay resumes from the snapshot and finishes on
    the uninterrupted result (fast-forwarding the host builder)."""
    inst = _stream_instance(seed=13, n=100)
    src = InstanceSource(inst)
    ref = replay_stream(src, "rcp", chunk_events=16, item_rows=32,
                        max_bins=64)

    ck = StreamCheckpointer(str(tmp_path), every_chunks=3, keep=True)
    full = replay_stream(src, "rcp", chunk_events=16, item_rows=32,
                         max_bins=64, checkpointer=ck)
    assert (full.usage, full.opened) == (ref.usage, ref.opened)
    snaps = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert snaps, "keep=True must leave the last periodic snapshot"

    c0 = obs.counter_get("resilience.stream_ckpt_resume")
    res = replay_stream(src, "rcp", chunk_events=16, item_rows=32,
                        max_bins=64,
                        checkpointer=StreamCheckpointer(
                            str(tmp_path), every_chunks=3))
    assert obs.counter_get("resilience.stream_ckpt_resume") == c0 + 1
    assert res.n_chunks == full.n_chunks    # resumed count includes skips
    assert (res.usage, res.opened, res.max_bins) == (
        ref.usage, ref.opened, ref.max_bins)


# ----------------------------------------------- multi-host sweep launcher

def test_two_host_sweep_merges_to_single_process(tmp_path):
    """Two host slices against one store == the single-process sweep:
    identical records AND identical on-disk checksum."""
    spec = SweepSpec(suites=(SuiteSpec("azure", 2, 60, 5),),
                     policies=("first_fit", "greedy", "cbd", "rcp"),
                     seeds=(0,))
    solo_store = SweepStore(str(tmp_path / "solo"))
    solo = run_sweep(spec, store=solo_store)

    multi_store = SweepStore(str(tmp_path / "multi"))
    for host in (0, 1):
        run_sweep(spec, store=multi_store, host_index=host, host_count=2)
    merged = multi_store.load(spec)
    assert merged == solo
    import json
    with open(solo_store.path(spec)) as f:
        a = json.load(f)
    with open(multi_store.path(spec)) as f:
        b = json.load(f)
    assert a["checksum"] == b["checksum"]
    assert a["results"] == b["results"]


def test_host_slices_are_disjoint_and_complete(tmp_path):
    """Each host computes a strict subset; the union covers the grid."""
    spec = SweepSpec(suites=(SuiteSpec("azure", 1, 40, 5),),
                     policies=("first_fit", "greedy", "mru"), seeds=(0,))
    parts = []
    for host in (0, 1, 2):
        store = SweepStore(str(tmp_path / f"h{host}"))
        parts.append(run_sweep(spec, store=store, host_index=host,
                               host_count=3))
    keys = [set(p) for p in parts]
    assert sum(len(k) for k in keys) == len(set().union(*keys))
    full = run_sweep(spec, store=None)
    assert set().union(*keys) == set(full)
    union = {}
    for p in parts:
        union.update(p)
    assert union == full


# ------------------------------------------- sharded-lane padding (pad > L)

_PAD_SCRIPT = """
import jax, numpy as np
assert jax.local_device_count() == 5, jax.local_device_count()
from repro.core import Instance
from repro.sweep import pack_instances, run_batch
rng = np.random.default_rng(1)
insts = []
for s in range(2):    # L=2 lanes over 5 devices -> pad=3 > L (wrap twice)
    n = 30 + 10 * s
    sizes = rng.integers(1, 24, (n, 3)) / 64.0
    arr = np.sort(rng.integers(0, 5000, n)).astype(float)
    dur = rng.integers(10, 500, n).astype(float)
    insts.append(Instance(sizes, arr, arr + dur, f"p{s}").sorted_by_arrival())
batch = pack_instances(insts)
a = run_batch(batch, "best_fit_l1", max_bins=16, shard="never")
b = run_batch(batch, "best_fit_l1", max_bins=16, shard="always")
assert (a.usage_time == b.usage_time).all()
assert (a.n_bins_opened == b.n_bins_opened).all()
# ndev > 2L: padding must tile ceil(total/L) = 3 copies, not assume 2
solo = pack_instances(insts[:1])
a = run_batch(solo, "first_fit", max_bins=16, shard="never")
b = run_batch(solo, "first_fit", max_bins=16, shard="always")
assert (a.usage_time == b.usage_time).all()
print("PAD-OK")
"""


def test_lane_padding_when_devices_dwarf_lanes():
    """Regression for ``_run_arrays``: 5 forced host devices over 1-2
    lanes (pad > L) must wrap-replicate, not truncate.  Subprocess because
    device count is fixed at jax init."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=5")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _PAD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "PAD-OK" in proc.stdout


# ------------------------------------------------------------ bench gate

def test_stream_smoke_matches_simulate():
    """The CI smoke lane's gate: a 3k-item (6k-event) stream replays
    bit-identically with a bounded pool (the perf/stream_replay_6k row
    asserts exactly this before timing)."""
    src = synthetic_source(3000, seed=17)
    inst = src.inst
    ref = simulate(inst, policy="first_fit", max_bins=128)
    res = replay_stream(src, "first_fit", chunk_events=1024, item_rows=256,
                        max_bins=128)
    assert res.usage == float(ref.usage_time)
    assert res.opened == int(ref.n_bins_opened)
    assert res.item_rows < inst.n_items     # bounded pool actually bounded
    assert res.n_events == 6000
