"""Hypothesis property tests: the system's invariants hold for EVERY
algorithm on randomized instances.

  * capacity never exceeded in any dimension at any event time
  * usage time >= span and >= LB/d' sanity; performance ratio >= 1 - eps
  * Any Fit algorithms never open a new bin when some open bin fits
  * all bins close; every item placed exactly once
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import (ANY_FIT, EPS, Instance, get_algorithm, lower_bound,
                        run, span)
from repro.core.algorithms import REGISTRY

ALGO_CASES = [
    ("first_fit", {}), ("mru", {}), ("next_fit", {}), ("rr_next_fit", {}),
    ("best_fit", {"norm": "l1"}), ("best_fit", {"norm": "l2"}),
    ("best_fit", {"norm": "linf"}), ("cbdt", {"rho": 16.0}),
    ("nrt_standard", {}), ("nrt_prioritized", {}), ("greedy", {}),
    ("cbd", {"beta": 2.0}), ("hybrid", {}), ("reduced_hybrid", {}),
    ("hybrid_direct_sum", {}), ("reduced_hybrid_direct_sum", {}),
    ("rcp", {}), ("ppe", {}), ("rcp_modified", {}), ("ppe_modified", {}),
    ("lifetime_alignment", {"mode": "binary"}),
    ("lifetime_alignment", {"mode": "geometric"}),
]


@st.composite
def instances(draw):
    n = draw(st.integers(3, 40))
    d = draw(st.integers(1, 4))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    sizes = rng.integers(1, 16, (n, d)) / 16.0
    arr = np.sort(rng.integers(0, 200, n)).astype(float)
    dur = rng.integers(1, 100, n).astype(float)
    return Instance(sizes, arr, arr + dur, "hyp").sorted_by_arrival()


class Verifier:
    """Wraps an algorithm, checking the Any Fit property on every arrival."""

    def __init__(self, algo):
        self.algo = algo
        self.any_fit_violations = 0

    def __getattr__(self, name):
        return getattr(self.algo, name)

    def select_bin(self, arr):
        pool = self.algo.pool
        open_idx = pool.open_indices()
        could_fit = bool(pool.fits_mask(open_idx, arr.size).any())
        idx = self.algo.select_bin(arr)
        if idx < 0 and could_fit:
            self.any_fit_violations += 1
        return idx


@pytest.mark.parametrize("name,kw", ALGO_CASES,
                         ids=[f"{n}-{'-'.join(map(str, k.values()))}"
                              if k else n for n, k in ALGO_CASES])
@settings(max_examples=25, deadline=None)
@given(inst=instances())
def test_invariants(name, kw, inst):
    algo = get_algorithm(name, **kw)
    is_any_fit = algo.name in ANY_FIT
    v = Verifier(algo)
    # engine.place itself asserts the capacity invariant on every placement
    r = run(inst, v)
    assert np.all(r.placements >= 0), "every item must be placed"
    lb = lower_bound(inst)
    assert r.usage_time >= span(inst) - 1e-6
    assert r.usage_time >= lb - 1e-6
    assert r.ratio(lb) >= 1.0 - 1e-9
    if is_any_fit:
        assert v.any_fit_violations == 0, \
            f"{algo.name} claims Any Fit but opened a bin avoidably"


@settings(max_examples=15, deadline=None)
@given(inst=instances(), sigma=st.floats(0.0, 3.0))
def test_learning_augmented_invariants(inst, sigma):
    from repro.core import lognormal_predictions
    pdur = lognormal_predictions(inst, sigma, seed=1)
    for name in ["ppe_modified", "lifetime_alignment"]:
        algo = get_algorithm(name) if name != "lifetime_alignment" else \
            get_algorithm(name, mode="geometric")
        r = run(inst, algo, predicted_durations=pdur)
        assert np.all(r.placements >= 0)
        assert r.usage_time >= span(inst) - 1e-6


@settings(max_examples=20, deadline=None)
@given(inst=instances())
def test_clairvoyant_equals_perfect_prediction(inst):
    """sigma=0 predictions must reproduce the clairvoyant run exactly."""
    from repro.core import lognormal_predictions
    for name in ["greedy", "nrt_prioritized"]:
        r1 = run(inst, get_algorithm(name))
        r2 = run(inst, get_algorithm(name),
                 predicted_durations=lognormal_predictions(inst, 0.0))
        assert np.array_equal(r1.placements, r2.placements)
        assert r1.usage_time == pytest.approx(r2.usage_time)


@settings(max_examples=20, deadline=None)
@given(inst=instances())
def test_lower_bound_monotone_under_subset(inst):
    lb_all = lower_bound(inst)
    half = inst.subset(np.arange(inst.n_items) % 2 == 0)
    assert lower_bound(half) <= lb_all + 1e-9
