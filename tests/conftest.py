import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# jax caches every compiled executable for the life of the process, and on
# CPU each one pins mmapped code + constant buffers.  A full tier-1 run now
# compiles enough distinct traces to cross the kernel's vm.max_map_count
# ceiling (65530 by default), at which point the NEXT XLA compile fails an
# mmap and segfaults.  Dropping the caches between modules once map
# pressure builds keeps the process far from the cliff while preserving
# cross-module cache reuse on the common path.
_MAPS_RELIEF_THRESHOLD = 20_000


def _n_maps():
    try:
        with open(f"/proc/{os.getpid()}/maps") as fh:
            return sum(1 for _ in fh)
    except OSError:  # non-linux: no /proc, no known map ceiling either
        return 0


@pytest.fixture(autouse=True, scope="module")
def _jax_map_pressure_relief():
    yield
    if _n_maps() > _MAPS_RELIEF_THRESHOLD:
        import jax

        jax.clear_caches()
