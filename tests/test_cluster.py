"""Cluster scheduler: placement, failure recovery, work conservation."""
import numpy as np
import pytest

from repro.cluster.placement import Job, ClusterScheduler, simulate_cluster


def _jobs(n=40, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for j in range(n):
        demand = np.array([rng.choice([0.25, 0.5, 1.0]),
                           rng.uniform(0.1, 0.8), rng.uniform(0.05, 0.5),
                           rng.uniform(0.05, 0.3)])
        runtime = float(rng.integers(600, 7200))
        out.append(Job(j, float(rng.integers(0, 36000)), runtime,
                       np.minimum(demand, 1.0),
                       predicted_runtime=runtime,
                       checkpoint_period=300.0))
    return out


def test_no_failures_work_conserved():
    r = simulate_cluster(_jobs(), "first_fit")
    assert r["failures_recovered"] == 0
    assert r["lost_work"] == 0
    assert r["host_seconds"] > 0


def test_failures_recovered_and_bounded_loss():
    jobs = _jobs()
    r = simulate_cluster(jobs, "first_fit", mtbf=4000.0, seed=1)
    assert r["failures_recovered"] > 0
    # lost work per failure is bounded by the checkpoint period
    assert r["lost_work"] <= r["failures_recovered"] * 300.0 + 1e-6


def test_placement_policies_all_run():
    jobs = _jobs(25)
    usages = {}
    for pol in ["first_fit", "greedy", "nrt_prioritized"]:
        usages[pol] = simulate_cluster(jobs, pol)["host_seconds"]
    # clairvoyant policies should not be wildly worse than first fit
    assert usages["greedy"] <= usages["first_fit"] * 1.5


def test_scheduler_gang_release():
    s = ClusterScheduler("first_fit")
    j = Job(0, 0.0, 100.0, np.array([1.0, 0.5, 0.5, 0.5]))
    s.place(j, 0.0)
    assert s.stats.hosts_opened == 1
    s.release(0, 100.0)
    assert s.stats.host_seconds == pytest.approx(100.0)
    assert not s.pool._open_list
