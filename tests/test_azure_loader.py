"""The real-trace path end-to-end: a checked-in Azure-Packing2020-format
fixture through ``data.load_azure_csv`` (cleaning rules: valid interval,
finite 14-day horizon, per-machine dimension pruning) and into sweeps via
``SuiteSpec(family="azure_trace")``."""
import os

import numpy as np
import pytest

from repro.core import lower_bound, run
from repro.core.jaxsim import host_algorithm, simulate
from repro.data import load_azure_csv
from repro.data.traces import DAY
from repro.sweep import PredModel, SuiteSpec, SweepSpec, SweepStore, run_sweep

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "azure_packing2020")


@pytest.fixture(scope="module")
def trace():
    insts = load_azure_csv(FIXTURE)
    assert insts is not None, "fixture dump not found"
    return insts


def test_loader_parses_and_cleans_the_dump(trace):
    assert [i.name for i in trace] == ["azure_pm0", "azure_pm1"]
    pm0, pm1 = trace
    # machine 0 has no hdd demand on any type: the dim is pruned (d=4);
    # machine 1 uses all five dims
    assert pm0.d == 4 and pm1.d == 5
    # cleaning: negative starttime, missing endtime, endtime past the
    # 14-day horizon, and empty intervals are dropped
    assert pm0.n_items == 5 and pm1.n_items == 3
    # times are scaled from days to seconds and sorted by arrival
    assert pm0.arrivals[0] == 0.0 and pm0.arrivals[-1] == 2.0 * DAY
    assert np.all(np.diff(pm1.arrivals) >= 0)
    assert np.all(pm1.departures <= 14.0 * DAY)
    for inst in trace:
        assert np.all(inst.sizes > 0) and np.all(inst.sizes <= 1.0)
        assert np.all(inst.departures > inst.arrivals)


def test_loader_returns_none_when_absent(tmp_path):
    assert load_azure_csv(str(tmp_path)) is None


def test_loaded_instances_replay_on_both_engines(trace):
    """The dump drives the oracle engine and the batched scan identically -
    category policy included (real traces are not fp32-exact in general,
    but this fixture is)."""
    for policy in ("first_fit", "cbd"):
        for inst in trace:
            r = run(inst, host_algorithm(policy))
            j = simulate(inst, policy, max_bins=16)
            assert j.n_bins_opened == r.n_bins_opened, (policy, inst.name)
            assert j.usage_time == pytest.approx(r.usage_time, abs=1e-3)
            assert r.usage_time >= lower_bound(inst) - 1e-6


def test_trace_suite_enters_sweeps(tmp_path, trace):
    suite = SuiteSpec("azure_trace", n_instances=2, n_items=0,
                      trace_root=FIXTURE)
    assert [i.name for i in suite.build()] == ["azure_pm0", "azure_pm1"]
    spec = SweepSpec(suites=(suite,), policies=("first_fit", "cbd"),
                     predictions=(PredModel("clairvoyant"),), max_bins=16)
    records = run_sweep(spec, store=SweepStore(str(tmp_path)))
    assert len(records) == 2 * 2
    assert all(r["ratio"] >= 1.0 - 1e-6 for r in records.values())
    # incremental: a second run is fully cached
    log = []
    again = run_sweep(spec, store=SweepStore(str(tmp_path)),
                      progress=log.append)
    assert again == records and all(m.startswith("skip") for m in log)


def test_trace_suite_item_cap(trace):
    capped = SuiteSpec("azure_trace", n_instances=1, n_items=3,
                       trace_root=FIXTURE).build()
    assert len(capped) == 1 and capped[0].n_items == 3


def test_trace_suite_raises_when_dump_missing(tmp_path):
    with pytest.raises(FileNotFoundError):
        SuiteSpec("azure_trace", trace_root=str(tmp_path)).build()
