"""Data substrate: trace generator stats, token determinism, hedged
prefetch, document packing."""
import numpy as np
import pytest

from repro.data import make_azure_like_suite, make_huawei_like_suite
from repro.data.packing import pack_documents
from repro.data.tokens import PrefetchLoader, TokenStream


def test_azure_like_suite_shape():
    suite = make_azure_like_suite(n_instances=6, n_items=500)
    assert len(suite) == 6
    for inst in suite:
        assert inst.d in (4, 5)
        assert np.all(inst.sizes > 0) and np.all(inst.sizes <= 1)
        assert np.all(inst.departures <= 14 * 86400 + 1)
        # lifetimes roughly log-normal: log std within sane band
        ls = np.log(inst.durations)
        assert 0.5 < ls.std() < 3.5


def test_huawei_like_suite_d2():
    for inst in make_huawei_like_suite(n_instances=3, n_items=300):
        assert inst.d == 2


def test_token_stream_deterministic_and_seekable():
    s = TokenStream(1024, 64, 4, seed=7)
    b1, b2 = s.batch(13), s.batch(13)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s.batch(13)["tokens"], s.batch(14)["tokens"])
    # labels are next-token shifted
    s2 = TokenStream(1024, 8, 1, seed=0, doc_len=4)
    b = s2.batch(0)
    assert b["tokens"].shape == (1, 8) and b["labels"].shape == (1, 8)


def test_hedged_prefetch_fires_backup():
    s = TokenStream(256, 16, 2)
    slow_primary = lambda step, tag: 0.4 if tag == "primary" else 0.0
    loader = PrefetchLoader(s, deadline_s=0.1, delay_fn=slow_primary)
    b = loader(3)
    assert loader.hedged == 1
    assert np.array_equal(b["tokens"], s.batch(3)["tokens"])


def test_pack_documents_efficiency():
    rng = np.random.default_rng(0)
    lengths = list(rng.integers(32, 1024, 500))
    bins, eff = pack_documents(lengths, 2048, "first_fit_decreasing")
    assert eff > 0.9
    # every doc appears exactly once
    flat = sorted(i for b in bins for i in b)
    assert flat == sorted(set(flat))
    # capacity respected
    for b in bins:
        assert sum(lengths[i] for i in b) <= 2048
    _, eff_ff = pack_documents(lengths, 2048, "first_fit")
    assert eff >= eff_ff - 1e-9   # FFD at least as good as FF here
