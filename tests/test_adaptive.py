"""AdaptiveSwitch (the paper's future-work item 1, implemented):
invariants + regime behaviour + does-no-harm across the error spectrum."""
import numpy as np
import pytest

from repro.core import (get_algorithm, lognormal_predictions, lower_bound,
                        run)
from repro.data import make_azure_like_suite


@pytest.fixture(scope="module")
def suite():
    return make_azure_like_suite(n_instances=4, n_items=1200)


def _mean_ratio(name, suite, sigma, **kw):
    out = []
    for inst in suite:
        pd = lognormal_predictions(inst, sigma, seed=3)
        r = run(inst, get_algorithm(name, **kw), predicted_durations=pd)
        out.append(r.ratio(lower_bound(inst)))
    return float(np.mean(out))


def test_matches_nrt_under_perfect_predictions(suite):
    for inst in suite[:2]:
        pd = lognormal_predictions(inst, 0.0)
        a = run(inst, get_algorithm("adaptive"), predicted_durations=pd)
        n = run(inst, get_algorithm("nrt_prioritized"),
                predicted_durations=pd)
        # with zero error the switch never leaves NRT
        assert a.usage_time == pytest.approx(n.usage_time)


def test_switches_regimes_under_error(suite):
    inst = suite[0]
    pd = lognormal_predictions(inst, 3.0, seed=1)
    alg = get_algorithm("adaptive")
    run(inst, alg, predicted_durations=pd)
    assert alg.regime_switches >= 1
    assert alg._err > alg.low


def test_does_no_harm_across_spectrum(suite):
    """Adaptive should track the best of its constituents within a margin
    at every error level (the whole point of the future-work item)."""
    for sigma in (0.0, 1.0, 4.0):
        adaptive = _mean_ratio("adaptive", suite, sigma)
        best_fixed = min(_mean_ratio(n, suite, sigma)
                         for n in ("nrt_prioritized", "greedy", "first_fit"))
        assert adaptive <= best_fixed * 1.10, (sigma, adaptive, best_fixed)


def test_capacity_invariants_hold(suite):
    inst = suite[1]
    pd = lognormal_predictions(inst, 2.0, seed=2)
    r = run(inst, get_algorithm("adaptive"), predicted_durations=pd)
    assert np.all(r.placements >= 0)
    assert r.usage_time >= lower_bound(inst) - 1e-6
