"""AdaptiveSwitch (the paper's future-work item 1, implemented):
invariants + regime behaviour + does-no-harm across the error spectrum,
plus the shared departure-error estimator (one running-max signal feeding
both the switch and PPE's guess-and-double alpha)."""
import numpy as np
import pytest

from repro.core import (Instance, get_algorithm, lognormal_predictions,
                        lower_bound, run)
from repro.core.algorithms.adaptive import (DepartureErrorEstimator,
                                            pow2_ceiling, prediction_error)
from repro.data import make_azure_like_suite


@pytest.fixture(scope="module")
def suite():
    return make_azure_like_suite(n_instances=4, n_items=1200)


def _mean_ratio(name, suite, sigma, **kw):
    out = []
    for inst in suite:
        pd = lognormal_predictions(inst, sigma, seed=3)
        r = run(inst, get_algorithm(name, **kw), predicted_durations=pd)
        out.append(r.ratio(lower_bound(inst)))
    return float(np.mean(out))


def test_matches_nrt_under_perfect_predictions(suite):
    for inst in suite[:2]:
        pd = lognormal_predictions(inst, 0.0)
        a = run(inst, get_algorithm("adaptive"), predicted_durations=pd)
        n = run(inst, get_algorithm("nrt_prioritized"),
                predicted_durations=pd)
        # with zero error the switch never leaves NRT
        assert a.usage_time == pytest.approx(n.usage_time)


def test_switches_regimes_under_error(suite):
    inst = suite[0]
    pd = lognormal_predictions(inst, 3.0, seed=1)
    alg = get_algorithm("adaptive")
    run(inst, alg, predicted_durations=pd)
    assert alg.regime_switches >= 1
    assert alg._err > alg.low


def test_does_no_harm_across_spectrum(suite):
    """Adaptive should track the best of its constituents within a margin
    at every error level (the whole point of the future-work item)."""
    for sigma in (0.0, 1.0, 4.0):
        adaptive = _mean_ratio("adaptive", suite, sigma)
        best_fixed = min(_mean_ratio(n, suite, sigma)
                         for n in ("nrt_prioritized", "greedy", "first_fit"))
        assert adaptive <= best_fixed * 1.10, (sigma, adaptive, best_fixed)


def test_capacity_invariants_hold(suite):
    inst = suite[1]
    pd = lognormal_predictions(inst, 2.0, seed=2)
    r = run(inst, get_algorithm("adaptive"), predicted_durations=pd)
    assert np.all(r.placements >= 0)
    assert r.usage_time >= lower_bound(inst) - 1e-6


def test_switch_decisions_pinned():
    """Regression pin for the estimator refactor: a crafted error staircase
    must produce exactly the same regime switches at the same arrivals."""
    sizes = np.full((6, 1), 0.375)
    arrivals = np.array([0.0, 10.0, 250.0, 260.0, 500.0, 510.0])
    inst = Instance(sizes, arrivals, arrivals + 100.0, "staircase")
    # item 1 departs at 110 with err 2 (-> greedy for items 2/3); item 3
    # departs at 360 with err 20 (-> first_fit for items 4/5)
    pd = np.array([100.0, 50.0, 100.0, 5.0, 100.0, 100.0])
    alg = get_algorithm("adaptive")
    r = run(inst, alg, predicted_durations=pd)
    assert alg.regime_switches == 2
    assert alg._last == 2                      # ends in the first_fit regime
    assert alg.estimator.err == 20.0
    assert r.n_bins_opened == 3                # one bin per concurrent pair


def test_estimator_is_shared_with_ppe_alpha():
    """PPE's guess-and-double alpha is pow2_ceiling of the same running-max
    estimator AdaptiveSwitch reads - not a separate recomputation."""
    rng = np.random.default_rng(5)
    n = 80
    sizes = rng.integers(1, 24, (n, 2)) / 64.0
    arr = np.sort(rng.integers(0, 20000, n)).astype(float)
    dur = rng.integers(10, 5000, n).astype(float)
    inst = Instance(sizes, arr, arr + dur, "ppe").sorted_by_arrival()
    pd = dur * rng.choice([0.25, 0.5, 1.0, 2.0, 8.0], n)
    alg = get_algorithm("ppe")
    run(inst, alg, predicted_durations=pd)
    assert isinstance(alg._estimator, DepartureErrorEstimator)
    expect = max(1.0, float(prediction_error(dur, pd).max()))
    assert alg._estimator.err == expect
    x = max(len(alg._seen_cats), 1)
    assert alg._threshold() == pow2_ceiling(expect) / np.sqrt(x)


def test_estimator_observe_is_running_max():
    est = DepartureErrorEstimator()
    assert est.err == 1.0 and est.pow2_alpha() == 1.0
    est.observe(100.0, 50.0)          # err 2
    est.observe(100.0, 100.0)         # err 1: no decrease
    assert est.err == 2.0 and est.pow2_alpha() == 2.0
    est.observe(10.0, 90.0)           # err 9 -> alpha 16
    assert est.err == 9.0 and est.pow2_alpha() == 16.0
