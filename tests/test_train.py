"""Training substrate: optimizer (int8 states), grad accumulation,
checkpointing, elastic resume, gradient compression."""
import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models import params as P_
from repro.models.transformer import Runtime
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state,
                                   opt_state_pspecs)
from repro.train.train_step import make_train_step
from repro.data.tokens import TokenStream

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
                  dtype="float32", attn_q_chunk=64)
RT = Runtime(mesh=None)


def test_adamw_minimizes_quadratic():
    for state_dtype in ("float32", "int8"):
        opt = OptConfig(lr=0.1, weight_decay=0.0, state_dtype=state_dtype,
                        warmup_steps=1, total_steps=200)
        params = {"w": jnp.array([[4.0, -3.0], [2.0, 5.0]])}
        state = init_opt_state(params, opt)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}     # d/dw ||w||^2
            params, state, _ = adamw_update(params, grads, state, opt)
        assert float(jnp.abs(params["w"]).max()) < 0.3, state_dtype


def test_int8_state_roundtrip_quality():
    from repro.train.optimizer import _dequant, _quant
    x = np.random.default_rng(0).normal(size=(64, 256)).astype(np.float32)
    q, s = _quant(jnp.asarray(x))
    err = np.abs(np.asarray(_dequant(q, s)) - x).max()
    assert err <= np.abs(x).max() / 127.0 + 1e-7


def test_grad_accumulation_equivalence():
    """microbatches=4 must match microbatches=1 up to accumulation order."""
    p = P_.init_params(jax.random.PRNGKey(0), CFG, dtype=jnp.float32)
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    stream = TokenStream(CFG.vocab, 32, 8)
    batch = jax.tree.map(jnp.asarray, stream.batch(0))
    outs = []
    for mb in (1, 4):
        state = init_opt_state(p, opt)
        step = make_train_step(CFG, RT, opt, microbatches=mb)
        p2, _, m = jax.jit(step)(p, state, batch)
        outs.append((p2, float(m["loss"])))
    assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-5)
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"a": jnp.arange(12.0).reshape(3, 4),
             "b": {"c": jnp.ones(5, jnp.int32)}}
    for step in (10, 20, 30):
        ck.save(step, state)
    assert ck.all_steps() == [20, 30]            # GC kept last 2
    step, restored = ck.restore(jax.eval_shape(lambda: state))
    assert step == 30
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    state = {"w": jnp.ones((128, 128))}
    ck.save(5, state)
    ck.wait()
    assert ck.latest_step() == 5


def test_opt_state_pspecs_mirror_params():
    from jax.sharding import PartitionSpec as P
    opt = OptConfig(state_dtype="int8")
    pspecs = {"w": P("data", "model"), "b": P(None)}
    os = opt_state_pspecs(pspecs, opt)
    assert os["m"]["w"]["q"] == P("data", "model")
    assert os["m"]["w"]["s"] == P("data", None)
    assert os["step"] == P()


def test_grad_compression_error_feedback():
    from repro.train.grad_compress import _quant as gq
    rng = np.random.default_rng(0)
    g = rng.normal(size=(8, 1024)).astype(np.float32) * 1e-3
    err = np.zeros_like(g)
    # accumulated dequantized updates track the true sum thanks to feedback
    total_true = np.zeros_like(g)
    total_sent = np.zeros_like(g)
    for t in range(50):
        gt = rng.normal(size=g.shape).astype(np.float32) * 1e-3
        total_true += gt
        x = gt + err
        q, s = gq(jnp.asarray(x))
        deq = np.asarray(q, np.float32) * np.asarray(s)
        err = x - deq
        total_sent += deq
    drift = np.abs(total_sent - total_true).max()
    assert drift <= np.abs(total_true).max() * 0.02 + 1e-5


def test_elastic_resume_exact(tmp_path):
    from repro.train.elastic import ElasticConfig, ElasticTrainer
    from repro.launch.mesh import make_host_mesh
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    stream = TokenStream(CFG.vocab, 32, 4)

    def make_state():
        p = P_.init_params(jax.random.PRNGKey(0), CFG, dtype=jnp.float32)
        return (p, init_opt_state(p, opt))

    def make_step(mesh):
        fn = make_train_step(CFG, Runtime(mesh=None), opt, microbatches=1)

        @jax.jit
        def step(state, batch):
            p, o = state
            p, o, m = fn(p, o, batch)
            return (p, o), m
        return step, None

    def batch_fn(step):
        return jax.tree.map(jnp.asarray, stream.batch(step))

    a = ElasticTrainer(make_state, make_step, batch_fn,
                       str(tmp_path / "a"), ElasticConfig(ckpt_every=5))
    a.attach(make_host_mesh())
    ref = float(a.run(20)["loss"])

    b = ElasticTrainer(make_state, make_step, batch_fn,
                       str(tmp_path / "b"), ElasticConfig(ckpt_every=5))
    b.attach(make_host_mesh())
    with pytest.raises(RuntimeError):
        b.run(20, fail_at=13)
    b2 = ElasticTrainer(make_state, make_step, batch_fn,
                        str(tmp_path / "b"), ElasticConfig(ckpt_every=5))
    b2.attach(make_host_mesh())
    assert b2.step == 10
    got = float(b2.run(20 - b2.step)["loss"])
    assert got == pytest.approx(ref, abs=1e-6)
