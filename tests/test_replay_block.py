"""The event-blocked replay megakernel vs the per-event reference paths.

Acceptance matrix for ``kernels.fitscore.fitscore_replay_block`` (whole
T-event blocks of the DVBP scan on-chip, carry resident in VMEM):

  * decision-for-decision parity (exact usage totals, bin counts and - via
    simulate() - placements) with the per-event jnp backend for EVERY scan
    policy (all 21: the 8-policy score family plus the 13 category-
    structured names) across clairvoyant / nonclairvoyant-style
    (pdep == arrival) / noisy-predicted rows on mixed-size /
    mixed-dimension padded batches,
  * the T tail block (2n not a multiple of block_events) and non-multiple
    tile geometry (``select_pad_geometry`` with n_slots and d not
    divisible by the kernel tile sizes),
  * the overflow-escalation ladder composing with blocked replay,
  * one-trace-per-geometry jit behavior across grid sweeps that vary
    which instances / how many seed rows fill the lanes, and
  * the per-instance event-sequence content-digest cache.

Instances are fp32-exact (1/64-grid sizes, integer times, power-of-two
noise) so all paths must agree bitwise, not approximately.
"""
import numpy as np
import pytest

from repro.core import Instance
from repro.core.jaxsim import SCAN_POLICIES, simulate
from repro.kernels.fitscore import select_pad_geometry
from repro.sweep import pack_instances, pad_predictions, run_batch


def quantized_instance(seed, n, d):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 24, (n, d)) / 64.0
    arr = np.sort(rng.integers(0, 50000, n)).astype(float)
    dur = rng.integers(10, 5000, n).astype(float)
    return Instance(sizes, arr, arr + dur, f"q{seed}").sorted_by_arrival()


@pytest.fixture(scope="module")
def mixed():
    """Mixed item counts AND dimensionality (pad events + dmask), with
    three prediction rows per lane: clairvoyant, pdep == arrival (the
    serving-style nonclairvoyant replay), and power-of-two noise."""
    insts = [quantized_instance(1, 40, 2), quantized_instance(2, 60, 4),
             quantized_instance(3, 30, 3)]
    batch = pack_instances(insts)
    preds = []
    for i in insts:
        rng = np.random.default_rng(100)
        noisy = i.durations * rng.choice([0.25, 0.5, 1.0, 2.0, 4.0],
                                         i.n_items)
        preds.append(np.stack([i.durations, np.zeros(i.n_items), noisy]))
    return insts, batch, pad_predictions(batch, preds)


@pytest.mark.parametrize("policy", SCAN_POLICIES)
def test_blocked_backend_matches_jnp_all_policies(policy, mixed):
    """Every scan policy, every lane, all three information rows: the
    blocked kernel backend (T=16, with a tail block: 120 events per lane)
    is bit-identical to the per-event jnp reference."""
    insts, batch, pdeps = mixed
    a = run_batch(batch, policy, pdeps, max_bins=32, backend="jnp")
    b = run_batch(batch, policy, pdeps, max_bins=32,
                  backend="pallas_interpret", block_events=16)
    assert (a.usage_time == b.usage_time).all(), policy
    assert (a.n_bins_opened == b.n_bins_opened).all(), policy
    assert (a.max_bins == b.max_bins).all(), policy


def test_blocked_matches_perevent_kernel_path(mixed):
    """Blocked and per-event flavors of the SAME kernel backend agree (the
    per-event kernel path is itself proven against jnp and the oracle)."""
    insts, batch, pdeps = mixed
    for policy in ("best_fit_linf", "cbd"):
        a = run_batch(batch, policy, pdeps, max_bins=32,
                      backend="pallas_interpret")
        b = run_batch(batch, policy, pdeps, max_bins=32,
                      backend="pallas_interpret", block_events=8)
        assert (a.usage_time == b.usage_time).all(), policy
        assert (a.n_bins_opened == b.n_bins_opened).all(), policy


def test_blocked_placements_identical():
    """simulate() through the blocked backend: identical placements (the
    strongest decision-for-decision check), tail block included (2n = 60,
    T = 16)."""
    inst = quantized_instance(9, 30, 3)
    noise = inst.durations * np.random.default_rng(4).choice(
        [0.5, 1.0, 2.0], inst.n_items)
    for policy in ("nrt_prioritized", "reduced_hybrid", "ppe_modified",
                   "la_geometric", "adaptive"):
        a = simulate(inst, policy, noise, max_bins=16, backend="jnp")
        b = simulate(inst, policy, noise, max_bins=16,
                     backend="pallas_interpret", block_events=16)
        assert (a.placements == b.placements).all(), policy
        assert a.usage_time == b.usage_time, policy


def test_nonmultiple_tile_geometry():
    """n_slots and d not divisible by the kernel tile sizes: an odd slot
    pool (max_bins=20: Np=20, not a sublane multiple), a pool spanning
    multiple bin tiles with layout padding rows (max_bins=300 -> Np=512),
    and d=5 (dpad=128), on both the per-event and blocked kernel paths."""
    Np, dpad, bn, nb = select_pad_geometry(300, 5)
    assert (Np, dpad, bn, nb) == (512, 128, 256, 2)   # layout padding rows
    assert select_pad_geometry(20, 5)[0] == 20        # odd Np
    insts = [quantized_instance(21, 25, 5), quantized_instance(22, 35, 5)]
    batch = pack_instances(insts)
    for max_bins in (20, 300):
        a = run_batch(batch, "best_fit_linf", max_bins=max_bins,
                      auto_grow=False, backend="jnp")
        for kw in (dict(), dict(block_events=8)):
            b = run_batch(batch, "best_fit_linf", max_bins=max_bins,
                          auto_grow=False, backend="pallas_interpret", **kw)
            assert (a.usage_time == b.usage_time).all(), (max_bins, kw)
            assert (a.n_bins_opened == b.n_bins_opened).all(), (max_bins, kw)


def dense_instance(seed, n, d):
    """High concurrency: many items alive at once, so max_bins=2 overflows
    and the escalation ladder must actually climb."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 24, (n, d)) / 64.0
    arr = np.sort(rng.integers(0, 2000, n)).astype(float)
    dur = rng.integers(500, 4000, n).astype(float)
    return Instance(sizes, arr, arr + dur, f"d{seed}").sorted_by_arrival()


def test_blocked_dense_rcp_conversion_paths():
    """High-concurrency lanes push RCP/PPE through the base-bin-conversion
    and category ON/OFF machinery (and hybrids through threshold
    crossings); the blocked kernel must track the jnp reference exactly
    under heavy load and extreme (0.25x / 4x) prediction noise."""
    insts = [dense_instance(35, 50, 3), dense_instance(36, 60, 2)]
    batch = pack_instances(insts)
    rng = np.random.default_rng(3)
    pdeps = pad_predictions(
        batch, [np.stack([i.durations,
                          i.durations * rng.choice([0.25, 4.0], i.n_items)])
                for i in insts])
    for policy in ("rcp", "ppe_modified", "cbdt", "hybrid_direct_sum"):
        a = run_batch(batch, policy, pdeps, max_bins=32, backend="jnp")
        b = run_batch(batch, policy, pdeps, max_bins=32,
                      backend="pallas_interpret", block_events=8)
        assert (a.usage_time == b.usage_time).all(), policy
        assert (a.n_bins_opened == b.n_bins_opened).all(), policy


def test_blocked_overflow_escalation():
    """The lane-wise slot-pool doubling ladder composes with blocked
    replay: a tiny starting pool converges to the same result."""
    insts = [dense_instance(31, 40, 3), dense_instance(32, 50, 3)]
    batch = pack_instances(insts)
    a = run_batch(batch, "first_fit", max_bins=2, backend="jnp")
    b = run_batch(batch, "first_fit", max_bins=2,
                  backend="pallas_interpret", block_events=8)
    assert not b.overflowed.any() and (b.max_bins > 2).any()
    assert (a.usage_time == b.usage_time).all()
    assert (a.max_bins == b.max_bins).all()


def test_one_trace_across_grid():
    """Grid sweeps that vary which instances / seeds fill the lanes - but
    not the padded geometry (L, n_max, d, max_bins, T) - compile exactly
    once per policy: the jitted replay is keyed on the flattened lane
    layout, not the (B, S) split (regression: 6x2 and 12x1 grids used to
    retrace).  Retraces are read off the ``sweep.jit_trace`` obs counter -
    the same signal ``benchmarks/perf.py::sweep_retrace`` gates in CI."""
    from repro import obs
    i6 = [quantized_instance(40 + k, 30, 3) for k in range(6)]
    i12 = [quantized_instance(60 + k, 30, 3) for k in range(12)]
    b6 = pack_instances(i6)
    p6 = pad_predictions(
        b6, [np.stack([i.durations, 2.0 * i.durations]) for i in i6])
    for kw in (dict(backend="jnp"),
               dict(backend="pallas_interpret", block_events=8)):
        c0 = obs.counter_get("sweep.jit_trace")
        run_batch(b6, "greedy", p6, max_bins=64, **kw)       # 6 x 2 lanes
        c1 = obs.counter_get("sweep.jit_trace")
        assert c1 == c0 + 1
        h0 = obs.counter_get("sweep.jit_cache_hit")
        run_batch(pack_instances(i12), "greedy", max_bins=64, **kw)  # 12 x 1
        run_batch(b6, "greedy", p6, max_bins=64, **kw)       # repeat cell
        assert obs.counter_get("sweep.jit_trace") == c1, \
            "same padded geometry must not retrace"
        assert obs.counter_get("sweep.jit_cache_hit") == h0 + 2


def test_event_sequence_digest_cache():
    """pack_instances memoizes the host-side event sort per instance
    *content* digest: repacking the same instances (same or different
    list) is a cache hit; different content is not.  Hit/miss stats live
    on the obs counter registry (``pack.evseq_hit`` / ``pack.evseq_miss``),
    not a module-private dict."""
    from repro import obs
    from repro.sweep import batching
    insts = [quantized_instance(71, 20, 2), quantized_instance(72, 25, 2)]
    pack_instances(insts)
    h0 = obs.counter_get("pack.evseq_hit")
    m0 = obs.counter_get("pack.evseq_miss")
    pack_instances(list(insts))
    assert obs.counter_get("pack.evseq_hit") == h0 + 2
    assert obs.counter_get("pack.evseq_miss") == m0
    other = quantized_instance(73, 20, 2)
    pack_instances([insts[0], other])
    assert obs.counter_get("pack.evseq_hit") == h0 + 3
    assert obs.counter_get("pack.evseq_miss") == m0 + 1
    assert obs.counter_get("pack.evseq_bytes") > 0   # resident-bytes gauge
    # digest covers content, not the name
    renamed = Instance(other.sizes, other.arrivals, other.departures, "x")
    assert batching.instance_digest(renamed) == \
        batching.instance_digest(other)
