"""Lock the trip-count-aware HLO cost model against known-FLOP programs
(this is the §Roofline measurement instrument - it must stay calibrated)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import module_cost, parse_module


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplied():
    def f(x):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c
    text = _compiled_text(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    cost = module_cost(text)
    expect = 10 * 2 * 256 ** 3
    assert cost.flops == pytest.approx(expect, rel=0.05)


def test_unrolled_exact():
    def g(x):
        for _ in range(7):
            x = x @ x
        return x
    text = _compiled_text(g, jax.ShapeDtypeStruct((512, 512), jnp.float32))
    assert module_cost(text).flops == pytest.approx(7 * 2 * 512 ** 3,
                                                    rel=0.02)


def test_nested_scan_with_remat_grad():
    def h(x):
        def outer(c, _):
            def inner(ci, _):
                return jax.checkpoint(lambda y: jnp.tanh(y @ y))(ci), None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return jnp.sum(c)
    text = _compiled_text(jax.grad(h),
                          jax.ShapeDtypeStruct((128, 128), jnp.float32))
    # fwd 12 + remat 12 + bwd 24 matmul-equivalents
    assert module_cost(text).flops == pytest.approx(48 * 2 * 128 ** 3,
                                                    rel=0.1)


def test_xla_cost_analysis_undercounts_scans():
    """The reason hlo_cost exists: XLA counts while bodies once."""
    def f(x):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per device
        ca = ca[0]
    xla_flops = ca["flops"]
    ours = module_cost(compiled.as_text()).flops
    assert ours > 5 * xla_flops   # 10x modulo fusion noise


def test_parse_module_entry_with_index_comments():
    """ENTRY headers with many params carry /*index=N*/ comments."""
    def f(*args):
        return sum(a.sum() for a in args)
    args = [jax.ShapeDtypeStruct((8, 8), jnp.float32) for _ in range(12)]
    text = _compiled_text(f, *args)
    comps = parse_module(text)
    assert "ENTRY" in comps


def test_dus_aliasing_not_overcharged():
    """A scan writing one row per step must not be charged the full buffer
    per iteration."""
    def f(x):
        buf = jnp.zeros((128, 1024))

        def body(b, i):
            upd = x[None] * (1.0 + i.astype(jnp.float32))
            return jax.lax.dynamic_update_slice_in_dim(
                b, upd, i, axis=0), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(128))
        return out
    text = _compiled_text(f, jax.ShapeDtypeStruct((1024,), jnp.float32))
    cost = module_cost(text)
    full_per_step = 128 * 128 * 1024 * 4
    assert cost.bytes < full_per_step * 4, \
        "DUS writes must be charged at update size"


def test_custom_call_bytes_charged_only_on_request():
    """custom-call ops are free by default (opaque kernels model their own
    interiors) but charge_custom_calls counts their operand+result HBM
    boundary bytes - times the enclosing trip count (the accounting the
    perf/replay_block_bytes_* rows rely on)."""
    text = """
HloModule m

%cond (p: (s32[], f32[64,128])) -> pred[] {
  %p = (s32[], f32[64,128]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %n), direction=LT
}

%body (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %x = f32[64,128] get-tuple-element(%p), index=1
  %y = f32[64,128] custom-call(%x), custom_call_target="my_kernel"
  %one = s32[] constant(1)
  %nxt = s32[] add(%iv, %one)
  ROOT %t = (s32[], f32[64,128]) tuple(%nxt, %y)
}

ENTRY %main (a: f32[64,128]) -> f32[64,128] {
  %a = f32[64,128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64,128]) tuple(%zero, %a)
  %w = (s32[], f32[64,128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[64,128] get-tuple-element(%w), index=1
}
"""
    free = module_cost(text)
    charged = module_cost(text, charge_custom_calls=True)
    per_call = 2 * 64 * 128 * 4            # operand + result
    assert charged.bytes - free.bytes == pytest.approx(5 * per_call)
