"""Category-structured policies through the single replay engine.

The acceptance matrix for the unified ``_replay_batch``: every
category-structured policy family - CBD/CBDT, Hybrid / Reduced Hybrid
(+ direct-sum), RCP/PPE (+ modified), Lifetime Alignment, adaptive - runs
as batched scan lanes with

  * decision-for-decision parity against the host oracle classes
    (clairvoyant AND noisy predictions, mixed-size / mixed-dimension padded
    batches: usage time and bins-opened are exact, not approximate), and
  * bit-identical results between the "jnp" and interpret-mode Pallas
    backends (the category mask rides through the fused kernel).

Instances are fp32-exact (sizes on a 1/64 grid, integer times, power-of-two
prediction noise) so the fp32 scan must match the f64 oracle exactly; class
boundaries are exact by construction (frexp categorization).
"""
import numpy as np
import pytest

from repro.core import Instance, run
from repro.core.jaxsim import (CATEGORY_POLICIES, host_algorithm,
                               known_policy, policy_spec, simulate)
from repro.sweep import (PredModel, SuiteSpec, SweepSpec, pack_instances,
                         pad_predictions, run_batch, run_sweep,
                         summarize_sweep)

SETTINGS = ("clairvoyant", "noisy")


def quantized_instance(seed, n, d):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 24, (n, d)) / 64.0
    arr = np.sort(rng.integers(0, 50000, n)).astype(float)
    dur = rng.integers(10, 5000, n).astype(float)
    return Instance(sizes, arr, arr + dur, f"q{seed}").sorted_by_arrival()


def pow2_noise(inst, seed):
    rng = np.random.default_rng(seed)
    return inst.durations * rng.choice([0.25, 0.5, 1.0, 2.0, 4.0],
                                       inst.n_items)


@pytest.fixture(scope="module")
def mixed():
    """Mixed item counts AND dimensionality: pad events, dmask, and (for
    hybrid_direct_sum) varying per-lane class counts."""
    insts = [quantized_instance(1, 50, 2), quantized_instance(2, 80, 4),
             quantized_instance(3, 30, 3)]
    batch = pack_instances(insts)
    preds = [np.stack([i.durations, pow2_noise(i, 100)]) for i in insts]
    return insts, batch, pad_predictions(batch, preds), preds


@pytest.mark.parametrize("policy", CATEGORY_POLICIES)
def test_category_lane_matches_oracle(policy, mixed):
    """Every category policy, every lane, clairvoyant + noisy: exact."""
    insts, batch, pdeps, preds = mixed
    res = run_batch(batch, policy, pdeps, max_bins=64, backend="jnp")
    assert not res.overflowed.any()
    for i, inst in enumerate(insts):
        for si, setting in enumerate(SETTINGS):
            r = run(inst, host_algorithm(policy),
                    predicted_durations=preds[i][si])
            assert res.n_bins_opened[i, si] == r.n_bins_opened, \
                (policy, inst.name, setting)
            assert res.usage_time[i, si] == r.usage_time, \
                (policy, inst.name, setting)


@pytest.mark.parametrize("policy", CATEGORY_POLICIES)
def test_category_kernel_backend_bit_identical(policy, mixed):
    """The category mask through the fused Pallas kernel (interpret mode)
    reproduces the inline jnp path bit-for-bit."""
    insts, batch, pdeps, _ = mixed
    a = run_batch(batch, policy, pdeps, max_bins=32, backend="jnp")
    b = run_batch(batch, policy, pdeps, max_bins=32,
                  backend="pallas_interpret")
    assert (a.usage_time == b.usage_time).all(), policy
    assert (a.n_bins_opened == b.n_bins_opened).all(), policy
    assert (a.max_bins == b.max_bins).all(), policy


def test_nonclairvoyant_setting_equals_clairvoyant(mixed):
    """PredModel("none"): prediction-requiring policies see the real
    departures - identical to the clairvoyant replay (engine semantics)."""
    insts, batch, _, _ = mixed
    res = run_batch(batch, "reduced_hybrid", max_bins=64)   # pdeps=None
    for i, inst in enumerate(insts):
        r = run(inst, host_algorithm("reduced_hybrid"))
        assert res.usage_time[i, 0] == r.usage_time


def test_parametric_policy_names(mixed):
    """cbd_beta* / cbdt_rho* parse and replay with the right parameter."""
    insts, batch, pdeps, preds = mixed
    for name in ("cbd_beta4", "cbdt_rho2048"):
        assert known_policy(name)
        res = run_batch(batch, name, pdeps, max_bins=64)
        for i, inst in enumerate(insts):
            r = run(inst, host_algorithm(name),
                    predicted_durations=preds[i][0])
            assert res.usage_time[i, 0] == r.usage_time, name
    assert policy_spec("cbd_beta4").beta == 4.0
    assert policy_spec("cbdt_rho2048").rho == 2048.0
    assert not known_policy("no_such_policy")


def test_simulate_single_instance_category(mixed):
    """simulate() routes category policies through the same engine; the
    jnp and interpret-mode kernel backends agree on placements (the
    strongest decision-for-decision check between backends)."""
    insts, _, _, _ = mixed
    for policy in ("cbd", "ppe_modified", "la_binary"):
        a = simulate(insts[2], policy, max_bins=16, backend="jnp")
        b = simulate(insts[2], policy, max_bins=16,
                     backend="pallas_interpret")
        assert (a.placements == b.placements).all(), policy
        assert a.usage_time == b.usage_time


def test_category_overflow_escalation(mixed):
    """The lane-wise slot-pool doubling ladder covers category lanes too:
    a tiny starting pool still converges to oracle-exact results."""
    insts, batch, pdeps, preds = mixed
    res = run_batch(batch, "cbd", pdeps, max_bins=2)
    assert not res.overflowed.any()
    assert (res.max_bins > 2).any()
    for i, inst in enumerate(insts):
        r = run(inst, host_algorithm("cbd"),
                predicted_durations=preds[i][0])
        assert res.usage_time[i, 0] == r.usage_time


def test_sweep_grid_with_category_policies(tmp_path):
    """Category policies are sweepable lanes: SweepSpec grids over them and
    the store caches them like any other policy."""
    from repro.sweep import SweepStore
    spec = SweepSpec(suites=(SuiteSpec("azure", 2, 100, 5),),
                     policies=("first_fit", "cbd", "reduced_hybrid",
                               "ppe_modified", "la_binary", "adaptive"),
                     predictions=(PredModel("clairvoyant"),), max_bins=64)
    store = SweepStore(str(tmp_path))
    records = run_sweep(spec, store=store)
    assert len(records) == 6 * 2
    assert all(r["ratio"] >= 1.0 - 1e-6 for r in records.values())
    stats = summarize_sweep(records)
    assert ("cbd", "clairvoyant") in stats
    log = []
    again = run_sweep(spec, store=store, progress=log.append)
    assert again == records and all(m.startswith("skip") for m in log)
