"""Unit tests for the DVBP engine, lower bound and hand-checkable algorithms."""
import numpy as np
import pytest

from repro.core import (Instance, get_algorithm, lower_bound, run, span)


def inst(items, name="t"):
    """items: list of (sizes, arrival, departure)."""
    sizes = np.array([i[0] for i in items], float)
    if sizes.ndim == 1:
        sizes = sizes[:, None]
    arr = np.array([i[1] for i in items], float)
    dep = np.array([i[2] for i in items], float)
    return Instance(sizes, arr, dep, name).sorted_by_arrival()


def test_single_item():
    i = inst([(0.5, 0.0, 10.0)])
    r = run(i, get_algorithm("first_fit"))
    assert r.usage_time == 10.0
    assert r.n_bins_opened == 1
    assert lower_bound(i) == 10.0
    assert span(i) == 10.0


def test_lower_bound_ceil():
    # two 0.6 items overlapping for [5,10): aggregate 1.2 -> 2 bins needed
    i = inst([(0.6, 0.0, 10.0), (0.6, 5.0, 15.0)])
    # [0,5): 1 bin, [5,10): 2 bins, [10,15): 1 bin => 5+10+5 = 20
    assert lower_bound(i) == 20.0


def test_first_fit_prefers_earliest():
    # b0 holds 0.5; b1 opened by 0.9; third 0.4 fits b0 (earliest)
    i = inst([(0.5, 0.0, 100.0), (0.9, 1.0, 100.0), (0.4, 2.0, 100.0)])
    r = run(i, get_algorithm("first_fit"))
    assert r.placements[2] == r.placements[0]


def test_best_fit_linf_tightest():
    # bins at load 0.5 and 0.7; item 0.2 -> linf picks the 0.7 bin
    i = inst([(0.5, 0.0, 100.0), (0.7, 1.0, 100.0), (0.2, 2.0, 100.0)])
    r = run(i, get_algorithm("best_fit", norm="linf"))
    assert r.placements[2] == r.placements[1]


def test_next_fit_abandons():
    # item1 opens b0; item2 (0.8) cannot fit -> b1; item3 (0.1) would fit b0
    # but Next Fit only considers b1
    i = inst([(0.5, 0.0, 100.0), (0.8, 1.0, 100.0), (0.1, 2.0, 100.0)])
    r = run(i, get_algorithm("next_fit"))
    assert r.placements[2] == r.placements[1] != r.placements[0]


def test_rr_next_fit_wraps_around():
    # cursor sits at b1 (0.8); 0.4 does not fit b1 but RRNF wraps to b0
    i = inst([(0.5, 0.0, 100.0), (0.8, 1.0, 100.0), (0.4, 2.0, 100.0)])
    r = run(i, get_algorithm("rr_next_fit"))
    assert r.placements[2] == r.placements[0]


def test_greedy_latest_close():
    i = inst([(0.3, 0.0, 50.0), (0.3, 1.0, 200.0), (0.3, 2.0, 60.0)])
    r = run(i, get_algorithm("greedy"))
    assert r.placements[2] == r.placements[1]   # latest indicated close


def test_nrt_prioritized_case_a_first():
    # bins closing at 50 and 200; item departing at 40: case (a) for both,
    # nearest is 50
    i = inst([(0.3, 0.0, 50.0), (0.3, 1.0, 200.0), (0.3, 2.0, 40.0)])
    r = run(i, get_algorithm("nrt_prioritized"))
    assert r.placements[2] == r.placements[0]


def test_nrt_prioritized_case_b_least_extension():
    # bins closing at 50 and 45; item departs 100: case (b); extend the 50
    i = inst([(0.3, 0.0, 50.0), (0.3, 1.0, 45.0), (0.3, 2.0, 100.0)])
    r = run(i, get_algorithm("nrt_prioritized"))
    assert r.placements[2] == r.placements[0]


def test_cbdt_separates_categories():
    # two items, same time, departures in different rho-windows
    i = inst([(0.1, 0.0, 10.0), (0.1, 0.0, 1000.0)])
    r = run(i, get_algorithm("cbdt", rho=100.0))
    assert r.placements[0] != r.placements[1]
    r2 = run(i, get_algorithm("cbdt", rho=10000.0))
    assert r2.placements[0] == r2.placements[1]


def test_multidim_feasibility():
    # items fit in dim0 but not dim1 jointly
    i = inst([([0.5, 0.9], 0.0, 10.0), ([0.5, 0.9], 1.0, 10.0)])
    r = run(i, get_algorithm("first_fit"))
    assert r.n_bins_opened == 2


def test_exact_fit_accepted():
    i = inst([(0.5, 0.0, 10.0), (0.5, 1.0, 10.0)])
    r = run(i, get_algorithm("first_fit"))
    assert r.n_bins_opened == 1


def test_usage_time_episodes():
    # non-overlapping items: two episodes (bin closes in between)
    i = inst([(0.9, 0.0, 10.0), (0.9, 20.0, 30.0)])
    r = run(i, get_algorithm("first_fit"))
    assert r.usage_time == 20.0
    assert r.n_bins_opened == 2   # closed bins are never reused
