"""Batched admission / double-buffered block dispatch: decision parity
with the sequential host oracle, degradation under injected faults,
shed ordering, retrace and memoization invariants."""
import heapq

import numpy as np
import pytest

from repro import obs
from repro.resilience import faults
from repro.serving.admission import AdmissionQueue
from repro.serving.dispatch import (BatchedFrontEnd, BlockDispatcher,
                                    serve_traffic)
from repro.serving.scheduler import (DVBPScheduler, ReplicaCapacity, Request,
                                     _demand_vector)
from repro.serving.traffic import diurnal_requests, poisson_requests

CAPS = ReplicaCapacity()
TPS = 50.0

# one kernel policy per family, paired with the host-zoo oracle policy
FAMILY_PAIRS = [
    ("best_fit_linf", "best_fit", {"norm": "linf"}),      # score
    ("cbd", "cbd", {"beta": 2.0}),                        # cbd
    ("rcp", "rcp", None),                                 # rcp
    ("la_binary", "lifetime_alignment", {"mode": "binary"}),  # la
    ("adaptive", "adaptive", None),                       # adaptive
]


def _oracle_placements(reqs, policy, kwargs):
    """Sequential oracle: one DVBPScheduler.place per request at its
    arrival, departures replayed in finish-time order."""
    sched = DVBPScheduler(policy, CAPS, kwargs, tokens_per_second=TPS)
    heap, placements = [], {}
    for r in sorted(reqs, key=lambda x: x.arrival):
        while heap and heap[0][0] <= r.arrival:
            ft, rid = heapq.heappop(heap)
            sched.finish(rid, ft)
        placements[r.rid] = sched.place(r, r.arrival)
        heapq.heappush(heap, (r.arrival + r.decode_len / TPS, r.rid))
    return placements


@pytest.mark.parametrize("kpol,hpol,kw", FAMILY_PAIRS,
                         ids=[p[0] for p in FAMILY_PAIRS])
def test_batch_of_one_matches_host(kpol, hpol, kw):
    """T=1 dispatch is decision-for-decision identical to the host
    scheduler for one policy per kernel family."""
    reqs = poisson_requests(70, rate=50.0, seed=2, sigma_pred=0.3)
    rep = serve_traffic(reqs, kpol, CAPS, tps=TPS, batch_max=1,
                        impl="pallas_interpret")
    assert rep.placements == _oracle_placements(reqs, hpol, kw)


@pytest.mark.parametrize("kpol,hpol,kw", FAMILY_PAIRS[:2],
                         ids=[p[0] for p in FAMILY_PAIRS[:2]])
def test_batched_matches_sequential_oracle(kpol, hpol, kw):
    """Blocks of T pending arrivals plus departures place exactly as the
    sequential oracle - batching changes throughput, not decisions."""
    reqs = poisson_requests(90, rate=50.0, seed=3, sigma_pred=0.3)
    oracle = _oracle_placements(reqs, hpol, kw)
    for bm in (8, 32):
        rep = serve_traffic(reqs, kpol, CAPS, tps=TPS, batch_max=bm,
                            impl="pallas_interpret")
        assert rep.placements == oracle, f"batch_max={bm} diverged"


def test_diurnal_traffic_matches_oracle():
    reqs = diurnal_requests(60, rate=40.0, period=4.0, depth=0.8, seed=4,
                            sigma_pred=0.3)
    rep = serve_traffic(reqs, "best_fit_linf", CAPS, tps=TPS, batch_max=16,
                        impl="pallas_interpret")
    assert rep.placements == _oracle_placements(reqs, "best_fit",
                                                {"norm": "linf"})


def test_replica_accounting_matches_oracle():
    """replica_seconds / opened / peak from the host mirror equal the
    host scheduler's own stats."""
    reqs = poisson_requests(80, rate=50.0, seed=5, sigma_pred=0.3)
    sched = DVBPScheduler("best_fit", CAPS, {"norm": "linf"},
                          tokens_per_second=TPS)
    heap = []
    for r in sorted(reqs, key=lambda x: x.arrival):
        while heap and heap[0][0] <= r.arrival:
            ft, rid = heapq.heappop(heap)
            sched.finish(rid, ft)
        sched.place(r, r.arrival)
        heapq.heappush(heap, (r.arrival + r.decode_len / TPS, r.rid))
    while heap:
        ft, rid = heapq.heappop(heap)
        sched.finish(rid, ft)
    rep = serve_traffic(reqs, "best_fit_linf", CAPS, tps=TPS, batch_max=32,
                        impl="pallas_interpret")
    st = sched.stats
    assert rep.replicas_opened == st.replicas_opened
    assert rep.peak_replicas == st.peak_replicas
    assert rep.replica_seconds == pytest.approx(st.replica_seconds)


def test_degrade_ladder_fires_and_decisions_survive():
    """An injected serving.select fault on the block rung steps the
    ladder down (counter ticks); placements still match the oracle."""
    reqs = poisson_requests(40, rate=50.0, seed=6, sigma_pred=0.3)
    oracle = _oracle_placements(reqs, "best_fit", {"norm": "linf"})
    before = obs.counters()
    with faults.injected("serving.select:xla:1:1"):
        rep = serve_traffic(reqs, "best_fit_linf", CAPS, tps=TPS,
                            batch_max=8, impl="pallas_interpret")
    delta = obs.counter_deltas(before)
    assert rep.placements == oracle
    assert delta.get("resilience.degrade_dispatch_block_events", 0) >= 1


def test_carry_regrow_preserves_decisions():
    """Overflowing the live carry grows the pool (doubling ladder) and
    replays the in-flight blocks; decisions stay oracle-equal."""
    reqs = poisson_requests(90, rate=400.0, seed=7, sigma_pred=0.3)
    oracle = _oracle_placements(reqs, "best_fit", {"norm": "linf"})
    before = obs.counters()
    rep = serve_traffic(reqs, "best_fit_linf", CAPS, tps=TPS, batch_max=16,
                        max_bins=2, impl="pallas_interpret")
    delta = obs.counter_deltas(before)
    assert rep.placements == oracle
    assert delta.get("serving.carry_regrow", 0) >= 1


def test_shed_deadline_before_queue_full():
    """A full queue evicts deadline-expired entries before it ever sheds
    a fresh arrival - the two counters are deterministic."""
    q = AdmissionQueue(None, max_pending=2, deadline=1.0, batch_max=4)
    assert q.submit(Request(0, 0.0, 64, 32), now=0.0)
    assert q.submit(Request(1, 0.1, 64, 32), now=0.1)
    # queue full; rid 0 and 1 are expired by t=2.0, so the fresh arrival
    # must be admitted (expired head shed), NOT rejected
    assert q.submit(Request(2, 2.0, 64, 32), now=2.0)
    assert q.stats.shed_deadline >= 1
    assert q.stats.shed_queue_full == 0
    # now saturate with live requests: the fresh arrival is shed
    assert q.submit(Request(3, 2.0, 64, 32), now=2.0)
    assert not q.submit(Request(4, 2.1, 64, 32), now=2.1)
    assert q.stats.shed_queue_full == 1


def test_take_sheds_expired_and_keeps_survivors():
    q = AdmissionQueue(None, max_pending=8, deadline=1.0, batch_max=8)
    q.submit(Request(0, 0.0, 64, 32), now=0.0)
    q.submit(Request(1, 1.5, 64, 32), now=1.5)
    got = q.take(now=2.0)
    assert [r.rid for r, _ in got] == [1]
    assert q.stats.shed_deadline == 1


def test_retrace_bounded_by_geometries():
    """Padding to a fixed geometry set bounds the jit trace count: a
    second run over the same geometries adds ZERO new traces."""
    reqs = poisson_requests(60, rate=50.0, seed=8, sigma_pred=0.3)
    kw = dict(tps=TPS, batch_max=8, geometries=(1, 8, 32),
              impl="pallas_interpret")
    serve_traffic(reqs, "best_fit_linf", CAPS, **kw)   # warm the cache
    before = obs.counters()
    rep = serve_traffic(reqs, "best_fit_linf", CAPS, **kw)
    delta = obs.counter_deltas(before)
    assert delta.get("serving.jit_trace", 0) == 0
    assert delta.get("serving.jit_cache_hit", 0) >= 1
    assert rep.metrics.get("serving.jit_trace", 0) == 0


def test_demand_vector_memoized():
    """Per-request demand vectors are content-keyed and cached; repeat
    shapes hit the LRU (counter-verified)."""
    before = obs.counters()
    a = _demand_vector(128, 64, CAPS)
    b = _demand_vector(128, 64, CAPS)
    assert a is b                      # cached object, not a rebuild
    assert not a.flags.writeable       # shared arrays are frozen
    delta = obs.counter_deltas(before)
    assert delta.get("serving.size_memo_hit", 0) >= 1
    r = Request(0, 0.0, 128, 64)
    np.testing.assert_array_equal(r.size(CAPS), a)


def test_dispatch_histogram_counters_surface_in_metrics():
    """serving.dispatch_batch_size / serving.queue_depth ride the plain
    counter plumbing into ServeReport.metrics as histogram buckets."""
    reqs = poisson_requests(50, rate=50.0, seed=9, sigma_pred=0.3)
    rep = serve_traffic(reqs, "best_fit_linf", CAPS, tps=TPS, batch_max=8,
                        impl="pallas_interpret")
    m = rep.metrics
    assert m.get("serving.dispatch_batch_size.count", 0) >= 1
    assert m.get("serving.dispatch_batch_size.sum", 0) == len(reqs) * 2
    assert any(k.startswith("serving.dispatch_batch_size.le_") for k in m)
    assert m.get("serving.queue_depth.count", 0) >= 1


def test_latencies_recorded_per_placed_request():
    reqs = poisson_requests(40, rate=50.0, seed=10, sigma_pred=0.3)
    rep = serve_traffic(reqs, "best_fit_linf", CAPS, tps=TPS, batch_max=8,
                        impl="pallas_interpret")
    assert rep.placed == len(reqs)
    assert len(rep.latencies) == len(reqs)
    assert all(x >= 0 for x in rep.latencies)
    p50, p99 = rep.latency_quantiles()
    assert 0 <= p50 <= p99


def test_front_end_rejects_adaptive_alpha_policies():
    """ppe needs real durations at departure - unsupported live."""
    with pytest.raises(AssertionError):
        BlockDispatcher("ppe", CAPS, TPS)


def test_front_end_force_drains_before_finish():
    """finish() hands queued arrivals to the dispatcher before the
    departure, keeping the event stream in global time order."""
    fe = BatchedFrontEnd("best_fit_linf", CAPS, tps=TPS, batch_max=64,
                         impl="pallas_interpret")
    fe.submit(Request(0, 0.0, 64, 32, 32), now=0.0)
    fe.submit(Request(1, 0.1, 64, 32, 32), now=0.1)
    fe.finish(0, now=0.5)       # rid 0 not yet dispatched: must drain first
    fe.sync()
    assert set(fe.placements) == {0, 1}
