"""Resilience: deterministic fault injection, the retry/degradation
ladder, checkpoint/resume, store recovery, input quarantine and serving
admission hardening.

The chaos contract everything here asserts: injected failures change HOW
a result is computed (slower rung, resumed scan, journal rebuild) but
never WHAT is computed - usage/decisions stay bit-identical to the
fault-free run.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import obs
from repro.core import Instance
from repro.resilience import checkpoint, faults, guard, validate
from repro.resilience.checkpoint import ReplayCheckpointer
from repro.serving.admission import AdmissionQueue
from repro.serving.scheduler import DVBPScheduler, ReplicaCapacity, Request
from repro.sweep import (PredModel, SuiteSpec, SweepSpec, SweepStore,
                         pack_instances, run_batch, run_sweep)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# one scan policy per family: score / cbd / rcp / la / adaptive
FAMILY_POLICIES = ("greedy", "cbd", "rcp", "la_binary", "adaptive")


@pytest.fixture(autouse=True)
def _clean_faults(monkeypatch):
    """No ambient fault plan, no real backoff sleeps, ever."""
    monkeypatch.setenv("REPRO_RESILIENCE_BACKOFF_SCALE", "0")
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    faults.clear()
    yield
    faults.clear()


def quantized_instance(seed=7, n=60, d=3):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 24, (n, d)) / 64.0
    arr = np.sort(rng.integers(0, 50000, n)).astype(float)
    dur = rng.integers(10, 5000, n).astype(float)
    return Instance(sizes, arr, arr + dur, f"q{seed}").sorted_by_arrival()


@pytest.fixture(scope="module")
def small_batch():
    return pack_instances([quantized_instance(s) for s in (1, 2, 3)])


# ------------------------------------------------------------ fault plans

def test_fault_spec_arming():
    plan = faults.parse_plan("a.b:error:2:2")
    assert plan.on_call("a.b") is None           # call 1: not armed yet
    assert plan.on_call("a.b").kind == "error"   # call 2 fires
    assert plan.on_call("a.b").kind == "error"   # call 3 fires
    assert plan.on_call("a.b") is None           # count exhausted
    assert plan.calls["a.b"] == 4


def test_fault_spec_glob_and_forever():
    plan = faults.parse_plan("sweep.*:xla:1:0")  # count 0 = forever
    for _ in range(5):
        assert plan.on_call("sweep.scan").kind == "xla"
    assert plan.on_call("store.load") is None


def test_fire_raises_and_counts():
    c0 = obs.counter_get("resilience.fault_oom")
    with faults.injected("x.y:oom"):
        with pytest.raises(faults.InjectedFault, match="RESOURCE_EXHAUSTED"):
            faults.fire("x.y")
    assert obs.counter_get("resilience.fault_oom") == c0 + 1
    faults.fire("x.y")    # plan gone: a no-op


def test_parse_plan_rejects_unknown_kind():
    with pytest.raises(AssertionError):
        faults.parse_plan("a.b:meteor")


# ------------------------------------------------------- guarded dispatch

def test_guarded_call_retries_transient():
    attempts = []

    def flaky():
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("RESOURCE_EXHAUSTED: injected")
        return 7

    c0 = obs.counter_get("resilience.retry")
    assert guard.guarded_call(flaky, site="t", retries=2) == 7
    assert len(attempts) == 3
    assert obs.counter_get("resilience.retry") == c0 + 2


def test_guarded_call_propagates_non_transient():
    def bug():
        raise ValueError("shape mismatch")
    with pytest.raises(ValueError):
        guard.guarded_call(bug, site="t", retries=5)


def test_replay_rungs_ladder_shape():
    labels = [r.label for r in guard.replay_rungs("pallas_interpret", 4, 2)]
    assert labels == ["blocked_sharded", "perevent_sharded", "perevent",
                      "jnp"]
    assert [r.label for r in guard.replay_rungs("jnp", 0, 1)] == ["jnp"]


def test_run_ladder_degrades_and_counts():
    rungs = guard.replay_rungs("pallas_interpret", 4, 1)

    def attempt(rung):
        if rung.block_events:
            raise faults.InjectedFault("INTERNAL: kernel died")
        return rung.label

    c0 = obs.counter_get("resilience.degrade_blocked_perevent")
    rung, out = guard.run_ladder(attempt, rungs, site="t")
    assert (rung.label, out) == ("perevent", "perevent")
    assert obs.counter_get("resilience.degrade_blocked_perevent") == c0 + 1


def test_run_ladder_last_rung_failure_propagates():
    rungs = guard.replay_rungs("jnp", 0, 1)

    def attempt(rung):
        raise faults.InjectedFault("INTERNAL: dead")
    with pytest.raises(faults.InjectedFault):
        guard.run_ladder(attempt, rungs, site="t")


@pytest.mark.parametrize("plan,counter", [
    # blocked megakernel dies once -> per-event kernel serves
    ("sweep.scan:xla:1:1", "resilience.degrade_blocked_perevent"),
    # blocked AND per-event die -> the jnp reference serves
    ("sweep.scan:xla:1:2", "resilience.degrade_pallas_interpret_jnp"),
])
def test_sweep_degradation_bit_identity(small_batch, plan, counter):
    """A degraded dispatch must return the exact usage of the fault-free
    jnp reference: the ladder trades throughput, never results."""
    base = run_batch(small_batch, "greedy", max_bins=64, backend="jnp",
                     shard="never")
    c0 = obs.counter_get(counter)
    with faults.injected(plan):
        res = run_batch(small_batch, "greedy", max_bins=64,
                        backend="pallas_interpret", block_events=4,
                        shard="never")
    assert obs.counter_get(counter) == c0 + 1
    assert np.array_equal(res.usage_time, base.usage_time)
    assert np.array_equal(res.n_bins_opened, base.n_bins_opened)


def test_sweep_transient_oom_retries_same_rung(small_batch):
    base = run_batch(small_batch, "greedy", max_bins=64, backend="jnp",
                     shard="never")
    r0 = obs.counter_get("resilience.retry")
    d0 = obs.counter_get("resilience.degrade_blocked_perevent")
    with faults.injected("sweep.scan:oom:1:1"):
        res = run_batch(small_batch, "greedy", max_bins=64,
                        backend="pallas_interpret", block_events=4,
                        shard="never")
    assert obs.counter_get("resilience.retry") == r0 + 1
    assert obs.counter_get("resilience.degrade_blocked_perevent") == d0
    assert np.array_equal(res.usage_time, base.usage_time)


# --------------------------------------------------------- checkpointing

def test_checkpoint_roundtrip(tmp_path):
    carry = {"a": np.arange(5), "b": (np.ones((2, 3), np.float32), None),
             "c": [np.float64(2.5)]}
    path = str(tmp_path / "c.npz")
    checkpoint.save_checkpoint(path, carry, {"digest": "x", "next_seg": 3})
    loaded, meta = checkpoint.load_checkpoint(path)
    assert meta == {"digest": "x", "next_seg": 3}
    assert np.array_equal(loaded["a"], carry["a"])
    assert isinstance(loaded["b"], tuple) and loaded["b"][1] is None
    assert np.array_equal(loaded["b"][0], carry["b"][0])
    assert isinstance(loaded["c"], list)


def test_checkpoint_tamper_quarantined(tmp_path):
    path = str(tmp_path / "c.npz")
    checkpoint.save_checkpoint(path, {"a": np.arange(8)}, {"digest": "x"})
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF                  # flip a payload byte
    open(path, "wb").write(bytes(blob))
    c0 = obs.counter_get("resilience.ckpt_corrupt")
    assert checkpoint.load_checkpoint(path) is None
    assert obs.counter_get("resilience.ckpt_corrupt") == c0 + 1
    assert os.path.exists(path + ".corrupt")
    assert not os.path.exists(path)               # quarantined, not kept


def test_checkpoint_stale_meta_ignored(tmp_path):
    path = str(tmp_path / "c.npz")
    checkpoint.save_checkpoint(path, {"a": np.arange(3)}, {"digest": "x"})
    c0 = obs.counter_get("resilience.ckpt_stale")
    assert checkpoint.load_checkpoint(path, {"digest": "y"}) is None
    assert obs.counter_get("resilience.ckpt_stale") == c0 + 1
    assert os.path.exists(path)                   # stale stays in place


@pytest.mark.parametrize("policy", FAMILY_POLICIES)
def test_checkpointed_replay_bit_identical(small_batch, tmp_path, policy):
    """Segmented checkpointed replay == the unsegmented scan, for one
    policy per family (rcp exercises the full-stream category cumsum)."""
    base = run_batch(small_batch, policy, max_bins=64, backend="jnp",
                     shard="never")
    ckpt = ReplayCheckpointer(str(tmp_path), every_events=16)
    res = run_batch(small_batch, policy, max_bins=64, backend="jnp",
                    shard="never", checkpoint=ckpt, checkpoint_key=policy)
    assert np.array_equal(res.usage_time, base.usage_time)
    assert np.array_equal(res.n_bins_opened, base.n_bins_opened)
    # a completed replay leaves no resume point behind
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".npz")]


def test_interrupt_resume_bit_identical(small_batch, tmp_path):
    """Kill the segmented replay mid-scan (in-process), rerun: it resumes
    from the snapshot and produces the exact fault-free result."""
    base = run_batch(small_batch, "rcp", max_bins=64, backend="jnp",
                     shard="never")
    ckpt = ReplayCheckpointer(str(tmp_path), every_events=16)
    with faults.injected("ckpt.segment:error:3"):
        with pytest.raises(faults.InjectedFault):
            run_batch(small_batch, "rcp", max_bins=64, backend="jnp",
                      shard="never", checkpoint=ckpt, checkpoint_key="k")
    assert [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    c0 = obs.counter_get("resilience.ckpt_resume")
    res = run_batch(small_batch, "rcp", max_bins=64, backend="jnp",
                    shard="never", checkpoint=ckpt, checkpoint_key="k")
    assert obs.counter_get("resilience.ckpt_resume") == c0 + 1
    assert np.array_equal(res.usage_time, base.usage_time)
    assert np.array_equal(res.n_bins_opened, base.n_bins_opened)


def _migrate_stream(n=24, every=8):
    """A flattened single-lane event stream with MIGRATE events spliced
    across checkpoint-segment boundaries: each picks an item alive at its
    splice point, at the clock of the preceding event."""
    from repro.kernels.fitscore import (ARRIVAL_KIND, DEPARTURE_KIND,
                                        MIGRATE_KIND)
    from repro.sweep.runner import _flatten_lanes, instances_pdeps
    batch = pack_instances([quantized_instance(7, n=n)])
    arrays = (batch.sizes, batch.times, batch.kinds, batch.items,
              instances_pdeps(batch), batch.dmask, batch.arrivals,
              batch.pdeps, batch.n_items)
    sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps, n_items = \
        [np.asarray(a) for a in _flatten_lanes(*arrays)]
    alive, live_at = set(), []      # live_at[i] = items alive before event i
    for i in range(2 * n):
        live_at.append(frozenset(alive))
        if kinds[0, i] == ARRIVAL_KIND:
            alive.add(int(items[0, i]))
        elif kinds[0, i] == DEPARTURE_KIND:
            alive.discard(int(items[0, i]))
    cands = [i for i in range(1, 2 * n) if live_at[i]]
    assert len(cands) >= 3, "instance too sparse for a migrate stream"
    picks = sorted({cands[len(cands) // 4], cands[len(cands) // 2],
                    cands[3 * len(cands) // 4]}, reverse=True)
    for k in picks:                 # descending: earlier indices stay valid
        mig = min(live_at[k])
        times = np.insert(times, k, times[0, k - 1], axis=1)
        kinds = np.insert(kinds, k, MIGRATE_KIND, axis=1)
        items = np.insert(items, k, mig, axis=1)
    return (sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps,
            n_items)


@pytest.mark.parametrize("policy", ("first_fit", "rcp"))
def test_checkpointed_migrate_stream_bit_identical(tmp_path, policy):
    """Segmented replay of a MIGRATE-bearing stream == the unsegmented
    scan with the MIGRATE branch compiled in - snapshots taken between
    migrations resume the exact consolidation state."""
    from repro.core.jaxsim import _replay_batch
    arrays = _migrate_stream()
    ref = _replay_batch(*arrays, policy=policy, max_bins=32, backend="jnp",
                        migrate=True)
    ckpt = ReplayCheckpointer(str(tmp_path), every_events=8)
    out = checkpoint.checkpointed_replay(
        arrays, policy=policy, max_bins=32, backend="jnp", block_events=0,
        ckpt=ckpt, key=f"mig-{policy}", migrate=True)
    assert np.array_equal(np.asarray(out[0]), np.asarray(ref[0]))   # usage
    assert np.array_equal(np.asarray(out[1]), np.asarray(ref[1]))   # bins
    assert np.array_equal(np.asarray(out[2]), np.asarray(ref[2]))   # place
    # kill mid-stream, rerun: resumes from the snapshot, bit-identical
    ckpt2 = ReplayCheckpointer(str(tmp_path / "killed"), every_events=8)
    with faults.injected("ckpt.segment:error:3"):
        with pytest.raises(faults.InjectedFault):
            checkpoint.checkpointed_replay(
                arrays, policy=policy, max_bins=32, backend="jnp",
                block_events=0, ckpt=ckpt2, key="kill", migrate=True)
    c0 = obs.counter_get("resilience.ckpt_resume")
    out2 = checkpoint.checkpointed_replay(
        arrays, policy=policy, max_bins=32, backend="jnp", block_events=0,
        ckpt=ckpt2, key="kill", migrate=True)
    assert obs.counter_get("resilience.ckpt_resume") == c0 + 1
    assert np.array_equal(np.asarray(out2[0]), np.asarray(ref[0]))
    assert np.array_equal(np.asarray(out2[2]), np.asarray(ref[2]))


# -------------------------------------------- chaos matrix: kill + resume

def _sweep_cmd(store):
    return [sys.executable, "-m", "repro", "sweep",
            "--suites", "azure", "--n-instances", "2", "--n-items", "50",
            "--policies", ",".join(FAMILY_POLICIES),
            "--preds", "clairvoyant", "--backend", "jnp",
            "--store", store, "--resume", "--checkpoint-every", "16"]


def _sweep_env(fault=""):
    env = {**os.environ, "PYTHONPATH": SRC,
           "REPRO_RESILIENCE_BACKOFF_SCALE": "0"}
    env.pop("REPRO_FAULTS", None)
    if fault:
        env["REPRO_FAULTS"] = fault
    return env


def _store_results(store):
    files = [f for f in os.listdir(store)
             if f.startswith("sweep_") and f.endswith(".json")]
    assert len(files) == 1, files
    return json.load(open(os.path.join(store, files[0])))["results"]


@pytest.fixture(scope="module")
def clean_sweep(tmp_path_factory):
    """The fault-free reference store the killed runs are compared to."""
    store = str(tmp_path_factory.mktemp("clean"))
    p = subprocess.run(_sweep_cmd(store), env=_sweep_env(),
                       capture_output=True, text=True)
    assert p.returncode == 0, p.stderr
    return _store_results(store)


@pytest.mark.parametrize("fault", [
    "sweep.group:kill:2",     # die between (suite, policy, pred) groups
    "sweep.group:kill:4",     # ... later in the grid
    "ckpt.segment:kill:7",    # die MID-scan, between carry snapshots
])
def test_killed_sweep_resumes_bit_identical(clean_sweep, tmp_path, fault):
    """SIGKILL the sweep CLI at several boundaries; the resumed run must
    reproduce the fault-free store exactly (group journal + carry
    checkpoints)."""
    store = str(tmp_path / "store")
    p = subprocess.run(_sweep_cmd(store), env=_sweep_env(fault),
                       capture_output=True, text=True)
    assert p.returncode == 137, (p.returncode, p.stdout, p.stderr)
    p = subprocess.run(_sweep_cmd(store), env=_sweep_env(),
                       capture_output=True, text=True)
    assert p.returncode == 0, p.stderr
    assert _store_results(store) == clean_sweep


# ------------------------------------------------------- store resilience

@pytest.fixture()
def swept_store(tmp_path):
    spec = SweepSpec(suites=(SuiteSpec("azure", 2, 60, 5),),
                     policies=("first_fit", "greedy"),
                     predictions=(PredModel("clairvoyant"),), max_bins=32)
    store = SweepStore(str(tmp_path))
    rec = run_sweep(spec, store=store)
    assert rec
    return spec, store, rec


def test_store_truncated_main_rebuilt_from_journal(swept_store):
    spec, store, rec = swept_store
    path = store.path(spec)
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[:len(blob) // 2])       # torn write
    c0 = obs.counter_get("store.corrupt")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        rec2 = run_sweep(spec, store=store)
    assert rec2 == rec                                   # journal rebuild
    assert obs.counter_get("store.corrupt") == c0 + 1
    assert os.path.exists(path + ".corrupt")


def test_store_checksum_mismatch_quarantined(swept_store):
    spec, store, rec = swept_store
    path = store.path(spec)
    blob = json.load(open(path))
    key = sorted(blob["results"])[0]
    blob["results"][key]["usage_time"] += 1.0            # bit rot
    json.dump(blob, open(path, "w"))
    with pytest.warns(RuntimeWarning, match="checksum"):
        rec2 = run_sweep(spec, store=store)
    assert rec2 == rec           # the tampered record never surfaces


def test_store_journal_torn_tail_skipped(swept_store):
    spec, store, rec = swept_store
    with open(store.journal_path(spec), "a") as f:
        f.write('{"suites_hash": "dead, torn mid-')     # crash mid-append
    c0 = obs.counter_get("store.journal_skipped")
    assert run_sweep(spec, store=store) == rec
    assert obs.counter_get("store.journal_skipped") == c0 + 1


def test_store_truncate_fault_recovers(tmp_path):
    """The injected torn write (store.save:truncate) on the LAST group's
    main rewrite: the next load quarantines the main file and rebuilds
    every record from the journal."""
    spec = SweepSpec(suites=(SuiteSpec("azure", 2, 60, 5),),
                     policies=("first_fit", "greedy"),
                     predictions=(PredModel("clairvoyant"),), max_bins=32)
    store = SweepStore(str(tmp_path))
    with faults.injected("store.save:truncate:2:1"):    # 2 groups, 2 saves
        rec = run_sweep(spec, store=store)
    with pytest.warns(RuntimeWarning, match="corrupt"):
        rec2 = run_sweep(spec, store=store)
    assert rec2 == rec


# ------------------------------------------------------ serving hardening

def _drive_scheduler(policy="nrt_prioritized", backend="host", n=80):
    caps = ReplicaCapacity(slots=4, kv_tokens=65536, prefill_budget=262144)
    sched = DVBPScheduler(policy, caps, select_backend=backend)
    rng = np.random.default_rng(5)
    live, t, picks = [], 0.0, []
    for rid in range(n):
        t += float(rng.integers(1, 8))
        while live and live[0][0] <= t:
            ft, r = live.pop(0)
            sched.finish(r, ft)
        req = Request(rid, t, int(rng.integers(16, 512)),
                      int(rng.integers(8, 1024)),
                      predicted_decode_len=int(rng.integers(8, 1024)))
        picks.append(sched.place(req, t))
        live.append((t + req.decode_len / 50.0, rid))
        live.sort()
    return picks, sched


def test_serving_select_degrades_to_jnp_same_decisions():
    host, _ = _drive_scheduler(backend="host")
    c0 = obs.counter_get("resilience.degrade_select_kernel_jnp")
    with faults.injected("serving.select:xla:5:1"):
        picks, sched = _drive_scheduler(backend="pallas_interpret")
    assert picks == host          # a degraded select decides identically
    assert obs.counter_get("resilience.degrade_select_kernel_jnp") == c0 + 1


def test_serving_never_stops_placing_under_total_kernel_failure():
    host, _ = _drive_scheduler(backend="host")
    with faults.injected("serving.select:xla:1:0"):     # every select dies
        picks, sched = _drive_scheduler(backend="pallas_interpret")
    assert picks == host          # the host algorithm zoo still places
    assert sched.last_select_backend == "host"
    assert sched.stats.replica_seconds > 0


def test_admission_queue_sheds_on_saturation_and_deadline():
    caps = ReplicaCapacity(slots=4, kv_tokens=65536, prefill_budget=262144)
    q = AdmissionQueue(DVBPScheduler("first_fit", caps),
                       max_pending=4, deadline=1.0, batch_max=2)
    qf0 = obs.counter_get("resilience.shed_queue_full")
    dl0 = obs.counter_get("resilience.shed_deadline")
    reqs = [Request(i, 0.0, 64, 100) for i in range(6)]
    admitted = [q.submit(r, 0.0) for r in reqs]
    assert admitted == [True] * 4 + [False] * 2        # queue saturates
    assert obs.counter_get("resilience.shed_queue_full") == qf0 + 2
    placed = q.drain(0.5)
    assert [rid for rid, _ in placed] == [0, 1]        # batch_max, FIFO
    assert len(q) == 2
    assert q.drain(5.0) == []                          # deadline lapsed
    assert obs.counter_get("resilience.shed_deadline") == dl0 + 2
    assert q.stats.placed == 2 and q.stats.shed == 4
    assert q.stats.submitted == 6


def test_admission_queue_keeps_draining_under_kernel_failure():
    caps = ReplicaCapacity(slots=4, kv_tokens=65536, prefill_budget=262144)
    q = AdmissionQueue(DVBPScheduler(
        "first_fit", caps, select_backend="pallas_interpret"),
        max_pending=16, deadline=100.0, batch_max=16)
    for i in range(8):
        q.submit(Request(i, 0.0, 64, 100), 0.0)
    with faults.injected("serving.select:xla:1:0"):
        placed = q.drain(1.0)
    assert len(placed) == 8       # degraded placement, nothing shed
    assert q.stats.shed == 0


def test_admission_queue_drains_in_deadline_order():
    """take() pops by earliest expiry (submission order breaking ties) -
    a request about to lapse goes before one with slack; expired entries
    shed mid-drain; uniform deadlines degenerate to exact FIFO."""
    q = AdmissionQueue(None, max_pending=16, deadline=5.0, batch_max=16)
    reqs = [Request(i, 0.0, 64, 100) for i in range(6)]
    q.submit(reqs[0], 0.0, deadline=10.0)
    q.submit(reqs[1], 0.0, deadline=3.0)
    q.submit(reqs[2], 0.0, deadline=1.0)
    q.submit(reqs[3], 0.0)                  # queue default: 5.0
    q.submit(reqs[4], 0.0, deadline=3.0)    # ties with rid 1: rid 1 first
    q.submit(reqs[5], 0.0, deadline=0.2)    # already lapsed by drain time
    dl0 = obs.counter_get("resilience.shed_deadline")
    out = [r.rid for r, _ in q.take(0.5)]
    assert out == [2, 1, 4, 3, 0]
    assert obs.counter_get("resilience.shed_deadline") == dl0 + 1
    assert q.stats.shed_deadline == 1 and len(q) == 0
    # uniform deadline == the legacy insertion-order drain, exactly
    q2 = AdmissionQueue(None, max_pending=16, deadline=5.0, batch_max=16)
    for r in reqs:
        q2.submit(r, 0.0)
    assert [r.rid for r, _ in q2.take(0.1)] == [0, 1, 2, 3, 4, 5]


# ------------------------------------------------- validation / quarantine

def test_validate_rows_reasons():
    sizes = np.array([[0.5], [np.nan], [-0.1], [1.5], [0.5], [0.5]])
    arr = np.array([0.0, 1.0, 2.0, 3.0, 4.0, 5.0])
    dep = np.array([10.0, 11.0, 12.0, 13.0, 4.0, 15.0])
    ids = np.array([0, 1, 2, 3, 4, 0])
    rep = validate.validate_rows(sizes, arr, dep, ids)
    assert rep.counts() == {"nan": 1, "nonpos_size": 1, "oversize": 1,
                            "nonpos_duration": 1, "dup_id": 1}
    assert rep.n_bad == 5 and not rep.ok
    assert rep.keep.tolist() == [True, False, False, False, False, False]
    assert "quarantined" in rep.summary()


def test_sanitize_rows_builds_clean_instance():
    sizes = np.array([[0.5], [np.nan], [0.25]])
    arr = np.array([5.0, 1.0, 0.0])
    dep = np.array([10.0, 2.0, 7.0])
    c0 = obs.counter_get("resilience.quarantine_rows")
    inst, rep = validate.sanitize_rows(sizes, arr, dep, name="t")
    assert rep.n_bad == 1
    assert obs.counter_get("resilience.quarantine_rows") == c0 + 1
    assert obs.counter_get("resilience.quarantine_nan") >= 1
    assert inst.n_items == 2
    assert inst.arrivals.tolist() == [0.0, 5.0]        # sorted by arrival
    assert validate.validate_instance(inst).ok


def test_validate_cli_clean_suite():
    # generated suites are well-formed: the CLI returns without raising
    assert validate.main(["--suites", "azure", "--n-instances", "2",
                          "--n-items", "50"]) is None


# ----------------------------------------------------------- obs plumbing

def test_obs_instant_point_events():
    with obs.recording():
        obs.instant("resilience.marker", foo=1)
        evs = [e for e in obs.events()
               if e["name"] == "resilience.marker"]
    assert len(evs) == 1
    assert evs[0]["ph"] == "i" and evs[0]["dur"] == 0.0
    assert evs[0]["args"] == {"foo": 1}
    assert obs.chrome_trace_events(evs)["traceEvents"][0]["ph"] == "i"
