"""Smoke test: ``python -m benchmarks.run --fast`` must run end-to-end and
emit the machine-readable BENCH JSON with the sweep perf rows (the perf
trajectory tracked across PRs)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_benchmarks_fast_mode_emits_json(tmp_path):
    out = tmp_path / "bench.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--fast",
         "--json", str(out)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    blob = json.loads(out.read_text())
    rows = {r["name"]: r for r in blob["rows"]}
    assert rows, "no benchmark rows emitted"
    # figure rows (paper metric = mean performance ratio >= 1)
    fig = [r for n, r in rows.items() if n.startswith("fig")]
    assert fig and all(r["derived"] >= 0.99 for r in fig)
    # sweep perf rows: loop vs batched grid + speedup
    sweep = [n for n in rows if n.startswith("perf/sweep_")]
    assert any("sweep_loop" in n for n in sweep)
    assert any("sweep_batched" in n for n in sweep)
    speedup = [r for n, r in rows.items() if "sweep_speedup" in n]
    assert speedup and speedup[0]["derived"] > 0
    # event-blocked replay rows ride the fast artifact (CI checks them)
    for name in ("perf/replay_block_T=1", "perf/replay_block_T=8",
                 "perf/replay_block_T=32",
                 "perf/replay_block_bytes_perevent"):
        assert name in rows, name
    # blocked replay must beat the per-event kernel path per step...
    assert rows["perf/replay_block_T=8"]["derived"] > 1.0
    # ...and move strictly fewer HBM bytes (ratio column is per-event /
    # blocked; the bench itself asserts strict inequality too)
    assert rows["perf/replay_block_bytes_T=8"]["derived"] > 1.0
