"""End-to-end behaviour + paper-claim validation (DESIGN.md §1.4).

Asserts the paper's qualitative experimental findings hold on the synthetic
Azure-like family (fixed seeds; means over instances; weak inequalities with
margins, since the suite is smaller than the paper's 28 instances)."""
import functools

import numpy as np
import pytest

from repro.core import (get_algorithm, lognormal_predictions, lower_bound,
                        run)
from repro.data import make_azure_like_suite

N_INST, N_ITEMS = 6, 1500


@functools.lru_cache()
def suite():
    return tuple(make_azure_like_suite(n_instances=N_INST, n_items=N_ITEMS))


@functools.lru_cache()
def lbs():
    return tuple(lower_bound(i) for i in suite())


def mean_ratio(factory, sigma=None, seed=0):
    out = []
    for inst, lb in zip(suite(), lbs()):
        pdur = None if sigma is None else \
            lognormal_predictions(inst, sigma, seed=seed)
        r = run(inst, factory(), predicted_durations=pdur)
        out.append(r.ratio(lb))
    return float(np.mean(out))


A = lambda name, **kw: (lambda: get_algorithm(name, **kw))


def test_ratios_at_least_one():
    for name in ["first_fit", "greedy", "reduced_hybrid"]:
        assert mean_ratio(A(name)) >= 1.0


def test_claim_first_fit_best_nonclairvoyant():
    """Paper Fig. 3: First Fit has the lowest mean among non-clairvoyant."""
    ff = mean_ratio(A("first_fit"))
    for other in ["mru", "next_fit", "rr_next_fit"]:
        assert ff <= mean_ratio(A(other)) + 0.02


def test_claim_any_fit_feature_rrnf_beats_nf():
    """Paper Fig. 3: Round-Robin Next Fit dramatically improves Next Fit."""
    assert mean_ratio(A("rr_next_fit")) < mean_ratio(A("next_fit")) - 0.3


def test_claim_prioritized_nrt_beats_standard():
    """Paper Fig. 5."""
    assert mean_ratio(A("nrt_prioritized")) < mean_ratio(A("nrt_standard"))


def test_claim_prioritized_nrt_best_clairvoyant():
    """Paper Fig. 8: Prioritized NRT leads the clairvoyant field."""
    nrt = mean_ratio(A("nrt_prioritized"))
    for other in [A("greedy"), A("cbd", beta=2.0), A("reduced_hybrid"),
                  A("cbdt", rho=21600.0)]:
        assert nrt <= mean_ratio(other) + 0.02


def test_claim_departure_time_beats_duration_clairvoyant():
    """Paper Fig. 8: departure-time algorithms beat duration algorithms."""
    dep = min(mean_ratio(A("nrt_prioritized")), mean_ratio(A("greedy")))
    dur = min(mean_ratio(A("cbd", beta=2.0)), mean_ratio(A("reduced_hybrid")))
    assert dep < dur


def test_claim_reduced_hybrid_beats_hybrid_and_direct_sum():
    """Paper Fig. 7."""
    rh = mean_ratio(A("reduced_hybrid"))
    assert rh <= mean_ratio(A("hybrid")) + 0.02
    assert rh < mean_ratio(A("reduced_hybrid_direct_sum"))
    assert mean_ratio(A("hybrid")) < mean_ratio(A("hybrid_direct_sum")) + 0.02


def test_claim_modified_rcp_ppe_no_worse():
    """Paper Fig. 10: removing large bins improves RCP/PPE."""
    for sigma in (0.5, 2.0):
        assert mean_ratio(A("rcp_modified"), sigma=sigma) <= \
            mean_ratio(A("rcp"), sigma=sigma) + 0.03
        assert mean_ratio(A("ppe_modified"), sigma=sigma) <= \
            mean_ratio(A("ppe"), sigma=sigma) + 0.03


def test_claim_ppe_approaches_first_fit_at_huge_error():
    """Paper Fig. 10: PPE's threshold grows with error -> behaves like FF."""
    ff = mean_ratio(A("first_fit"))
    ppe = mean_ratio(A("ppe_modified"), sigma=4.0)
    assert ppe <= ff * 1.15


def test_claim_greedy_more_robust_than_nrt():
    """Paper Fig. 12: Greedy (conservative) degrades slower than
    Prioritized NRT (aggressive) as errors grow."""
    d_nrt = mean_ratio(A("nrt_prioritized"), sigma=2.0) - \
        mean_ratio(A("nrt_prioritized"))
    d_greedy = mean_ratio(A("greedy"), sigma=2.0) - mean_ratio(A("greedy"))
    assert d_greedy <= d_nrt + 0.02


def test_claim_cbdt_less_robust_than_cbd():
    """Paper Fig. 9: departure-time classification degrades faster with
    error than duration classification."""
    d_cbdt = mean_ratio(A("cbdt", rho=21600.0), sigma=2.0) - \
        mean_ratio(A("cbdt", rho=21600.0))
    d_cbd = mean_ratio(A("cbd", beta=2.0), sigma=2.0) - \
        mean_ratio(A("cbd", beta=2.0))
    assert d_cbd <= d_cbdt + 0.05


def test_claim_clairvoyant_beats_nonclairvoyant():
    assert mean_ratio(A("nrt_prioritized")) < mean_ratio(A("first_fit"))


def test_end_to_end_training_improves_loss():
    """(b)-grade check: the quickstart trainer actually learns."""
    import jax
    import jax.numpy as jnp
    from repro.models.config import ModelConfig
    from repro.models import params as P_
    from repro.models.transformer import Runtime
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_step import make_train_step
    from repro.data.tokens import TokenStream
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab=256, dtype="float32", attn_q_chunk=64)
    opt = OptConfig(lr=3e-3, warmup_steps=3, total_steps=30)
    p = P_.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    state = init_opt_state(p, opt)
    fn = jax.jit(make_train_step(cfg, Runtime(mesh=None), opt),
                 donate_argnums=(0, 1))
    stream = TokenStream(cfg.vocab, 64, 8)
    losses = []
    for step in range(30):
        p, state, m = fn(p, state, jax.tree.map(jnp.asarray,
                                                stream.batch(step)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < losses[0] - 0.5
