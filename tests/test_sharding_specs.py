"""Sharding-spec validity for every (arch x rules) combination: each sharded
dim must divide the mesh axis product (the dry-run's divisibility contract),
and kv projections must never be ragged-sharded."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models import params as P_
from repro.models.sharding import ShardingRules, tree_pspecs


class FakeMesh:
    """shape-only stand-in (tree_pspecs only reads mesh.shape)."""

    def __init__(self, shape):
        self.shape = shape


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16})]
RULES = [ShardingRules(fsdp=False),
         ShardingRules(fsdp=True),
         ShardingRules(fsdp=True, seq_parallel=True,
                       data_axes=("pod", "data"))]


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("rules", RULES, ids=["tp", "fsdp", "fsdp_mp"])
def test_specs_divisible(arch, rules):
    cfg = get_config(arch)
    mesh = MESHES[1] if "pod" in rules.data_axes else MESHES[0]
    specs = tree_pspecs(cfg, mesh, rules)
    shapes = P_.abstract_params(cfg)

    def check(path, spec, arr):
        assert isinstance(spec, P)
        assert len(spec) == len(arr.shape) or len(spec) <= len(arr.shape)
        for dim, ax in zip(arr.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            n = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n *= mesh.shape[a]
            assert dim % n == 0, f"{path}: {dim} % {n} != 0 ({ax})"

    jax.tree_util.tree_map_with_path(
        check, specs, shapes,
        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "gemma3-12b", "hymba-1.5b"])
def test_kv_projections_not_ragged(arch):
    """kv heads (8 or 5) don't divide model=16: wk/wv must be replicated on
    their head dim (the §Perf it1 fix)."""
    cfg = get_config(arch)
    mesh = MESHES[0]
    specs = tree_pspecs(cfg, mesh, ShardingRules(fsdp=True))
    wk_spec = specs["layers"]["wk"]
    assert wk_spec[-1] is None, f"wk head dim must be replicated: {wk_spec}"


def test_ep_when_divisible():
    cfg = get_config("deepseek-v2-lite-16b")   # 64 experts % 16 == 0 -> EP
    specs = tree_pspecs(cfg, MESHES[0], ShardingRules(fsdp=True))
    assert specs["layers"]["we_in"][1] == "model"   # (layers, E, d, f)
    cfg2 = get_config("granite-moe-3b-a800m")  # 40 % 16 != 0 -> expert-TP
    specs2 = tree_pspecs(cfg2, MESHES[0], ShardingRules(fsdp=True))
    assert specs2["layers"]["we_in"][1] is None
    assert specs2["layers"]["we_in"][3] == "model"
