"""Prediction-error models: batch variants' shapes, seed-stability, and the
paper's statistical properties (log-normal median ratio == 1; sigma=0 /
eps=1 are exact)."""
import numpy as np
import pytest

from repro.core import (Instance, lognormal_predictions,
                        lognormal_predictions_batch, uniform_predictions,
                        uniform_predictions_batch)


@pytest.fixture(scope="module")
def inst():
    rng = np.random.default_rng(3)
    n = 4000
    arr = np.sort(rng.uniform(0, 1e5, n))
    dur = rng.uniform(10, 5000, n)
    return Instance(rng.uniform(0.01, 0.5, (n, 4)), arr, arr + dur, "pred")


def test_batch_shapes(inst):
    assert lognormal_predictions_batch(inst, 1.0, range(3)).shape == \
        (3, inst.n_items)
    assert uniform_predictions_batch(inst, 4.0, range(5)).shape == \
        (5, inst.n_items)


def test_batch_rows_match_scalar_seed_for_seed(inst):
    seeds = (0, 7, 42)
    ln = lognormal_predictions_batch(inst, 1.5, seeds)
    un = uniform_predictions_batch(inst, 16.0, seeds)
    for i, s in enumerate(seeds):
        np.testing.assert_array_equal(
            ln[i], lognormal_predictions(inst, 1.5, seed=s))
        np.testing.assert_array_equal(
            un[i], uniform_predictions(inst, 16.0, seed=s))


def test_sigma_zero_is_exact(inst):
    batch = lognormal_predictions_batch(inst, 0.0, (0, 1))
    np.testing.assert_array_equal(batch[0], inst.durations)
    np.testing.assert_array_equal(batch[1], inst.durations)


def test_eps_one_is_exact(inst):
    batch = uniform_predictions_batch(inst, 1.0, (0, 1))
    np.testing.assert_allclose(batch, np.broadcast_to(
        inst.durations, batch.shape), rtol=1e-12)


def test_lognormal_median_ratio_is_one(inst):
    """delta ~ LogNormal(0, sigma) has median 1: half the predictions
    over-estimate, half under-estimate, for every sigma."""
    for sigma in (0.5, 1.0, 2.0):
        batch = lognormal_predictions_batch(inst, sigma, range(4))
        ratio = batch / inst.durations[None, :]
        assert np.median(ratio) == pytest.approx(1.0, abs=0.05)
        assert (ratio > 0).all()


def test_uniform_ratio_bounds_and_balance(inst):
    eps = 16.0
    batch = uniform_predictions_batch(inst, eps, range(4))
    ratio = batch / inst.durations[None, :]
    assert (ratio >= 1 / eps - 1e-12).all() and (ratio <= eps + 1e-12).all()
    over = (ratio > 1.0).mean()          # fair coin for over/under
    assert 0.45 < over < 0.55
