"""Bounded-memory streamed replay: full-length traces in O(alive) memory.

The in-memory sweep path materializes every instance as one padded
``(L, 2 n_max)`` event tensor plus ``n_max`` item rows, so lane memory
grows with trace length - a few thousand VMs per lane, far short of the
5.56M-request Azure Packing2020 trace the paper evaluates on.  This
package replays the same event stream in fixed-geometry chunks against
the same carried state:

  * ``events``  - request sources (in-memory instances, the streaming
    Azure CSV reader) and :class:`~repro.stream.events.ChunkedWorkload`,
    the host-side merge/pool builder.
  * ``replay``  - :func:`~repro.stream.replay.replay_stream`, the jitted
    chunk driver with double-buffered prefetch staging, and
    ``replay_chunked_events`` for pre-materialized event arrays.

Results are bit-identical to ``core.jaxsim.simulate`` on the
materialized instance (tests/test_stream.py); memory is O(max alive VMs),
independent of trace length.
"""
from .events import (ChunkedWorkload, CsvSource, EventChunk,
                     InstanceSource, POOL_SENTINEL, StreamMeta,
                     chunk_instance_events, synthetic_source)
from .replay import (StreamResult, replay_chunked_events, replay_stream)

__all__ = [
    "ChunkedWorkload", "CsvSource", "EventChunk", "InstanceSource",
    "POOL_SENTINEL", "StreamMeta", "StreamResult",
    "chunk_instance_events", "replay_chunked_events", "replay_stream",
    "synthetic_source",
]
