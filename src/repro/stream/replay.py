"""The streamed replay driver: chunked device work on a carried state.

``replay_stream`` drives one policy over one request stream in
fixed-geometry chunks (see ``stream.events``): every chunk is a single
jitted step that (1) scatters the chunk's newly arrived items into the
device row pool, (2) replays the C events through
``core.jaxsim._replay_batch`` with the carry threaded in and out
(``carry0`` / ``return_carry`` - the checkpoint-segment machinery), and
(3) harvests the placements of rows the chunk freed, before they are
recycled.  Usage / opened-bins / overflow accumulate inside the carry, so
the last chunk's outputs are the full-run totals, bit-identical to the
in-memory replay of the same event stream (tests/test_stream.py).

Staging is double-buffered: with ``prefetch >= 1`` the host builds and
``device_put`` s up to that many chunks ahead while the device replays the
current one, and nothing fences until the final resolve - jax's async
dispatch overlaps host merge/CSV work with device compute exactly as the
serving front end's block placement does.  ``prefetch=0`` is the
synchronous reference (fence after every chunk), kept for the
``perf/stream_prefetch`` comparison.

Memory is O(pool): the carry, the row pool and at most ``prefetch + 1``
staged chunks - independent of trace length.  ``peak_device_bytes``
reports the accounted maximum.  Overflow keeps the in-memory escalation
ladder: the stream is replayed again from the source with a doubled slot
pool (sources are re-iterable factories).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core.jaxsim import (CapacityError, MAX_BINS_CAP, _replay_batch,
                           grow_live_items, grow_max_bins, policy_spec,
                           replay_init_carry, resolve_backend)
from ..kernels import fitscore as _fk
from .events import ChunkedWorkload, InstanceSource, chunk_instance_events


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Outcome of one streamed replay (single lane)."""
    usage: float
    opened: int
    overflow: bool
    max_bins: int
    n_items: int
    n_events: int
    n_chunks: int
    item_rows: int
    peak_device_bytes: int
    placements: Optional[np.ndarray] = None


def _pool0(item_rows: int, d: int):
    f32 = jnp.float32
    return {"sizes": jnp.zeros((1, item_rows, d), f32),
            "arrivals": jnp.zeros((1, item_rows), f32),
            "rdeps": jnp.zeros((1, item_rows), f32),
            "pdeps": jnp.zeros((1, item_rows), f32)}


def _pool_full(source: InstanceSource):
    """Identity (hybrid) mode: the whole item table up front."""
    f32 = jnp.float32
    sizes, arrivals, rdeps, pdeps = source.full_arrays()
    return {"sizes": jnp.asarray(sizes, f32)[None],
            "arrivals": jnp.asarray(arrivals, f32)[None],
            "rdeps": jnp.asarray(rdeps, f32)[None],
            "pdeps": jnp.asarray(pdeps, f32)[None]}


def _grow_pool(pool, item_rows: int):
    n = pool["sizes"].shape[1]
    if item_rows <= n:
        return pool
    pad = item_rows - n
    return {k: jnp.concatenate(
        [v, jnp.zeros((1, pad) + v.shape[2:], v.dtype)], axis=1)
        for k, v in pool.items()}


def _grow_carry(carry, item_rows: int):
    """Pad the carried state's item axis; fresh rows are virgin (-1
    placements, zero category state), so decisions are unchanged - new
    rows are only referenced once the builder assigns them."""
    if isinstance(carry, dict):            # packed kernel carry
        return grow_live_items(carry, item_rows)
    core, cat = carry
    n = core[7].shape[1]
    if item_rows <= n:
        return carry
    pad = item_rows - n
    core = core[:7] + (jnp.concatenate(
        [core[7], jnp.full((1, pad), -1, jnp.int32)], axis=1),) + core[8:]
    cat = dict(cat)
    if "loc" in cat:                       # RCP's per-item slot memo
        cat["loc"] = jnp.concatenate(
            [cat["loc"], jnp.zeros((1, pad), jnp.int32)], axis=1)
    assert "agg" not in cat, "hybrid never grows (identity mode)"
    return (core, cat)


def _carry_placements(carry):
    if isinstance(carry, dict):
        return carry["itemi"][:, :, _fk.ITEMI_PLACE]
    return carry[0][7]


@partial(jax.jit, donate_argnums=(0, 1),
         static_argnames=("policy", "max_bins", "backend", "block_events",
                          "migrate", "harvest"))
def _chunk_step(carry, pool, times, kinds, items, upd_idx, upd_size,
                upd_arr, upd_rdep, upd_pdep, extras, freed, *, policy: str,
                max_bins: int, backend: str, block_events: int,
                migrate: bool, harvest: bool):
    """One chunk of device work: pool scatter -> replay -> harvest.

    Carry and pool are donated (reused in place chunk over chunk); the
    ``POOL_SENTINEL`` padding of ``upd_idx`` / ``freed`` is dropped /
    filled, so every chunk shares this one trace."""
    pool = dict(pool)
    pool["sizes"] = pool["sizes"].at[0, upd_idx].set(upd_size, mode="drop")
    pool["arrivals"] = pool["arrivals"].at[0, upd_idx].set(
        upd_arr, mode="drop")
    pool["rdeps"] = pool["rdeps"].at[0, upd_idx].set(upd_rdep, mode="drop")
    pool["pdeps"] = pool["pdeps"].at[0, upd_idx].set(upd_pdep, mode="drop")
    item_rows = pool["sizes"].shape[1]
    n1 = jnp.full((1,), item_rows, jnp.int32)
    usage, opened, placements, overflow, carry = _replay_batch(
        pool["sizes"], times[None], kinds[None], items[None],
        pool["pdeps"], None, pool["arrivals"], pool["rdeps"], n1,
        policy=policy, max_bins=max_bins, backend=backend,
        block_events=block_events, carry0=carry, return_carry=True,
        ev_extra=tuple(x[None] for x in extras) if extras else None,
        migrate=migrate)
    freed_place = jnp.take(placements[0], freed, mode="fill",
                           fill_value=-1) if harvest else None
    return carry, pool, usage[0], opened[0], overflow[0], freed_place


def _nbytes(tree) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(tree))


def _replay_once(source, policy, *, chunk_events, item_rows, max_bins,
                 backend, block_events, prefetch, grow_pool,
                 collect_placements, checkpointer):
    wl = ChunkedWorkload(source, policy, chunk_events=chunk_events,
                         item_rows=item_rows, grow=grow_pool)
    d = wl.d
    rows = wl.item_rows
    carry = replay_init_carry(policy, max_bins, d, rows, L=1,
                              backend=backend, block_events=block_events)
    pool = _pool_full(source) if wl.identity else _pool0(rows, d)
    gen = wl.chunks()
    resumed_chunks = 0
    ckpt_key = None
    if checkpointer is not None:
        assert not collect_placements, \
            "checkpoint/resume discards freed-row placement logs; " \
            "collect only on un-checkpointed runs"
        ckpt_key = checkpointer.key(
            source.meta().fingerprint, policy=policy, max_bins=max_bins,
            backend=backend, block_events=block_events,
            chunk_events=chunk_events)
        state = checkpointer.load(ckpt_key)
        if state is not None:
            carry, pool, resumed_chunks = state
            rows = pool["sizes"].shape[1]
            for _ in range(resumed_chunks):   # host fast-forward (cheap,
                next(gen)                     # deterministic builder)

    depth = max(int(prefetch), 0)
    staged: deque = deque()
    harvest = []                # (freed_seqs, freed_place) per chunk
    last = None
    nchunks = resumed_chunks
    peak = 0
    done = False
    while True:
        while not done and len(staged) <= depth:
            try:
                ch = next(gen)
            except StopIteration:
                done = True
                break
            dev = jax.device_put((ch.times, ch.kinds, ch.items, ch.upd_idx,
                                  ch.upd_size, ch.upd_arrival, ch.upd_rdep,
                                  ch.upd_pdep, ch.extras, ch.freed))
            staged.append((ch, dev))
            peak = max(peak, _nbytes(carry) + _nbytes(pool) +
                       sum(_nbytes(s[1]) for s in staged))
        if not staged:
            break
        ch, dev = staged.popleft()
        if ch.item_rows > rows:
            # the builder outgrew the pool: pad pool + carry (one retrace)
            obs.counter_add("stream.pool_growths")
            rows = ch.item_rows
            pool = _grow_pool(pool, rows)
            carry = _grow_carry(carry, rows)
        carry, pool, usage, opened, overflow, fp = _chunk_step(
            carry, pool, *dev, policy=policy, max_bins=max_bins,
            backend=backend, block_events=block_events, migrate=False,
            harvest=collect_placements)
        if collect_placements:
            harvest.append((ch.freed_seqs, fp))
        last = (usage, opened, overflow)
        nchunks += 1
        if depth == 0:
            jax.block_until_ready(carry)   # synchronous reference mode
        if checkpointer is not None:
            checkpointer.maybe_save(ckpt_key, carry, pool, nchunks,
                                    final=ch.final)

    usage, opened, overflow = (jax.block_until_ready(x) for x in last)
    placements = None
    if collect_placements:
        placements = np.full(wl.n_items, -1, np.int32)
        for seqs, fp in harvest:
            fp = np.asarray(fp)
            m = seqs >= 0
            placements[seqs[m]] = fp[m]
        live = wl.live_rows()
        if live:                   # items still alive at stream end
            final = np.asarray(_carry_placements(carry))[0]
            for row, seq in live.items():
                placements[seq] = final[row]
    return StreamResult(float(usage), int(opened), bool(overflow),
                        max_bins, wl.n_items, 2 * wl.n_items, nchunks,
                        rows, int(peak), placements)


def replay_stream(source, policy: str, *, chunk_events: int = 2048,
                  item_rows: int = 256, max_bins: int = 64,
                  max_bins_cap: int = MAX_BINS_CAP, auto_grow: bool = True,
                  backend: Optional[str] = None, block_events: int = 0,
                  prefetch: int = 1, grow_pool: bool = True,
                  collect_placements: bool = False,
                  checkpointer=None) -> StreamResult:
    """Replay one request stream under one policy in bounded memory.

    Bit-identical to ``jaxsim.simulate`` on the materialized instance
    (same events, same carry evolution, same escalation ladder); peak
    memory O(item-row pool + slot pool + staged chunks).  See the module
    docstring for staging/prefetch semantics."""
    backend = resolve_backend(backend)
    policy_spec(policy)            # validate before any device work
    with obs.span("stream.replay", cat="stream", policy=policy,
                  backend=backend, chunk_events=int(chunk_events)):
        while True:
            res = _replay_once(
                source, policy, chunk_events=chunk_events,
                item_rows=item_rows, max_bins=max_bins, backend=backend,
                block_events=block_events, prefetch=prefetch,
                grow_pool=grow_pool,
                collect_placements=collect_placements,
                checkpointer=checkpointer)
            if not res.overflow or not auto_grow:
                return res
            if max_bins >= max_bins_cap:
                raise CapacityError(
                    f"slot pool exhausted streaming with {policy!r}: "
                    f"still overflowing at max_bins={max_bins} "
                    f"(cap {max_bins_cap})", policy=policy,
                    max_bins=max_bins)
            obs.counter_add("stream.overflow_rungs")
            max_bins = grow_max_bins(max_bins, max_bins_cap)


def replay_chunked_events(sizes, times, kinds, items, pdeps, arrivals,
                          rdeps, *, policy: str, chunk_events: int,
                          max_bins: int, backend: str = "jnp",
                          block_events: int = 0, migrate: bool = False,
                          ev_extra=None):
    """Replay pre-materialized single-lane event arrays (any kinds,
    MIGRATE included) in fixed-geometry chunks with the carry threaded
    across boundaries - the minimal chunked path for the chunk-boundary
    equivalence tests, sharing ``_chunk_step``'s scatter-free core.

    ``ev_extra`` (full-event-axis tuple, e.g. ``replay_event_extras``) is
    sliced per chunk exactly as the checkpointed replay slices segments.
    Returns (usage, opened, placements, overflow) like ``_replay_batch``
    on a single lane."""
    n_max, d = np.asarray(sizes).shape
    carry = replay_init_carry(policy, max_bins, d, n_max, L=1,
                              backend=backend, block_events=block_events)
    pool = {"sizes": jnp.asarray(sizes, jnp.float32)[None],
            "arrivals": jnp.asarray(arrivals, jnp.float32)[None],
            "rdeps": jnp.asarray(rdeps, jnp.float32)[None],
            "pdeps": jnp.asarray(pdeps, jnp.float32)[None]}
    extras = tuple(np.asarray(x)[0] if np.asarray(x).ndim == 2 else
                   np.asarray(x) for x in (ev_extra or ()))
    sent = np.full(1, 2 ** 30, np.int32)
    no_upd = (sent, np.zeros((1, d), np.float32), np.zeros(1, np.float32),
              np.zeros(1, np.float32), np.zeros(1, np.float32))
    out = None
    for t, k, i, ex, final in chunk_instance_events(
            times, kinds, items, chunk_events, extras):
        carry, pool, usage, opened, overflow, _ = _chunk_step(
            carry, pool, t, k, i, *no_upd, ex, sent, policy=policy,
            max_bins=max_bins, backend=backend, block_events=block_events,
            migrate=migrate, harvest=False)
        out = (usage, opened, overflow)
    usage, opened, overflow = out
    placements = _carry_placements(carry)[0]
    return (np.asarray(usage), np.asarray(opened), np.asarray(placements),
            np.asarray(overflow))
