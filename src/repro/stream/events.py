"""Bounded-memory event sources and the fixed-geometry chunk builder.

The in-memory replay materializes one padded ``(L, 2 n_max)`` event tensor
per lane, so memory grows with trace *length*.  This module turns a
request stream (arrival-sorted ``(size, arrival, departure[, predicted])``
records) into a sequence of fixed-geometry :class:`EventChunk` s of ``C``
events each, with item metadata held in a recycled *row pool*: an arriving
VM is assigned a pool row, a departing VM frees it, and a freed row becomes
allocatable again from the *next* chunk on (never inside the chunk that
freed it, so the chunk's pool scatter happens once, up front).  Peak pool
size is therefore O(max concurrently alive VMs), not O(trace length).

Event ordering is bit-compatible with ``core.jaxsim.event_sequence``:
events sort by time (compared in float64, exactly as the in-memory
``np.lexsort`` does before the device cast to float32), departures before
arrivals at equal times, equal-time departures by item sequence number and
equal-time arrivals in source order.  Chunks are padded to ``C`` with
``PAD_KIND`` no-op events - the replay carry passes through them unchanged,
so padding never affects decisions and every chunk shares one jit trace.

Two policy families need care beyond the elementwise per-item constants
(``jaxsim._category_setup`` derives those from the pool's size / arrival /
departure rows, so a correctly scattered pool reproduces them exactly):

  * RCP's running distinct-category count is a cumsum over the whole event
    axis; the builder maintains it on the host (``geo_class`` twin on
    float32 durations, the exact dtype path of the device computation) and
    ships it per chunk as the ``ev_extra`` stream.
  * Hybrid builds its key table from the *whole* instance up front
    (clairvoyant, like ``make_live_carry``'s serving prohibition), so it
    streams in *identity* mode: events are chunked but the item table is
    the full instance - memory O(n_items), still free of the O(2 n_max)
    event tensor.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
from typing import Iterable, Iterator, Optional, Tuple

import numpy as np

from ..core.algorithms.learned import geo_class
from ..core.jaxsim import policy_spec
from ..core.types import Instance
from ..kernels.fitscore import ARRIVAL_KIND, DEPARTURE_KIND, KCAT, PAD_KIND

# Scatter index for padding rows of the per-chunk pool update: far out of
# range, dropped by the device scatter's mode="drop".
POOL_SENTINEL = np.int32(2 ** 30)


@dataclasses.dataclass(frozen=True)
class StreamMeta:
    """Static facts about a request stream.

    ``fingerprint`` identifies the stream content + order (checkpoint
    digests); ``n_items`` is the total request count when the source knows
    it up front, else -1 (a CSV stream discovers it only by draining)."""
    d: int
    fingerprint: str
    n_items: int = -1


class InstanceSource:
    """Stream one in-memory :class:`Instance` (arrival-sorted), optionally
    with predicted durations - the bit-equality reference source and the
    bridge from every existing suite generator."""

    def __init__(self, inst: Instance,
                 predicted_durations: Optional[np.ndarray] = None):
        assert np.all(np.diff(inst.arrivals) >= 0), \
            f"{inst.name!r} is not arrival-sorted; use .sorted_by_arrival()"
        self.inst = inst
        self.pdeps = inst.departures if predicted_durations is None \
            else inst.arrivals + np.asarray(predicted_durations, np.float64)

    def meta(self) -> StreamMeta:
        from ..sweep.batching import instance_digest
        h = hashlib.blake2b(digest_size=8)
        h.update(instance_digest(self.inst).encode())
        h.update(np.ascontiguousarray(self.pdeps).tobytes())
        return StreamMeta(self.inst.d, h.hexdigest(), self.inst.n_items)

    def records(self) -> Iterator[Tuple[np.ndarray, float, float, float]]:
        inst = self.inst
        for i in range(inst.n_items):
            yield (inst.sizes[i], float(inst.arrivals[i]),
                   float(inst.departures[i]), float(self.pdeps[i]))

    def full_arrays(self):
        """(sizes, arrivals, rdeps, pdeps) float64 - identity (hybrid)
        mode's whole-instance item table."""
        return (self.inst.sizes, self.inst.arrivals, self.inst.departures,
                np.asarray(self.pdeps, np.float64))


class CsvSource:
    """Stream Azure-format requests (``data.traces.iter_azure_requests``)
    for one machineId without ever materializing the trace."""

    def __init__(self, root: str, machine_id: int = 0):
        self.root, self.machine_id = root, int(machine_id)

    def meta(self) -> StreamMeta:
        from ..data.traces import azure_stream_meta
        d = azure_stream_meta(self.root, self.machine_id)
        return StreamMeta(
            d, f"azure:{self.root}:pm{self.machine_id}", -1)

    def records(self):
        from ..data.traces import iter_azure_requests
        for size, arr, dep in iter_azure_requests(self.root,
                                                  self.machine_id):
            yield size, arr, dep, dep   # clairvoyant predictions


@dataclasses.dataclass
class EventChunk:
    """One fixed-geometry unit of device work: ``C`` merged events plus the
    pool-row scatter that makes their item metadata resolvable.

    ``times``/``kinds``/``items`` are the (C,) event streams (float32 /
    int32, PAD-padded); ``upd_*`` the (C,)-shaped pool update for rows
    first written in this chunk (``POOL_SENTINEL`` index padding - a row's
    constants are scattered exactly once, in the chunk its VM arrives);
    ``extras`` the per-event ``ev_extra`` streams (RCP's running count);
    ``freed``/``freed_seqs`` the rows released by this chunk's departures
    and the global item sequence numbers that owned them (placement
    harvest - those rows may be recycled from the next chunk on).
    ``item_rows`` is the pool size this chunk's rows require (mid-chunk
    growth included - the driver grows pool + carry *before* replaying
    the chunk whenever it increases)."""
    times: np.ndarray
    kinds: np.ndarray
    items: np.ndarray
    n_events: int
    upd_idx: np.ndarray
    upd_size: np.ndarray
    upd_arrival: np.ndarray
    upd_rdep: np.ndarray
    upd_pdep: np.ndarray
    extras: Tuple[np.ndarray, ...]
    freed: np.ndarray
    freed_seqs: np.ndarray
    item_rows: int
    final: bool


class ChunkedWorkload:
    """Merge a request stream into arrival/departure events and cut them
    into :class:`EventChunk` s over a recycled row pool.

    The pending-departure heap is keyed ``(departure_time, item_seq)`` -
    together with "drain every departure whose time <= the next arrival's
    time first", this reproduces the in-memory event order exactly (time,
    then departures-before-arrivals, then source position).  ``grow``
    doubles the pool when the alive population outruns it (the driver
    re-traces once per growth); identity mode disables recycling and pins
    ``item_rows`` to the full item count."""

    def __init__(self, source, policy: str, *, chunk_events: int = 2048,
                 item_rows: int = 256, grow: bool = True,
                 identity: bool = False):
        spec = policy_spec(policy)
        self.source = source
        self.spec = spec
        self.chunk_events = int(chunk_events)
        self.identity = bool(identity or spec.family == "hybrid")
        if self.identity:
            n = source.meta().n_items
            assert n >= 0, \
                f"{policy!r} streams in identity (whole-table) mode, " \
                "which needs a source with a known item count"
            item_rows = max(int(n), 1)
            grow = False
        self.item_rows = max(int(item_rows), 1)
        self.grow = bool(grow)
        self.d = source.meta().d
        # live pool state (populated while chunks() runs)
        self._row_seq = {}          # pool row -> global item seq, alive only
        self._seq_count = 0
        self._done = False

    # ------------------------------------------------------------ builder
    def chunks(self) -> Iterator[EventChunk]:
        C, d = self.chunk_events, self.d
        rcp = self.spec.family == "rcp"
        free: list = []             # allocatable rows (min-heap)
        next_fresh = 0
        heap: list = []             # (dep_time f64, seq, row) pending deps
        seen_cats = [False] * KCAT
        xcount = 0
        last_arr = -np.inf

        ev_t = np.zeros(C, np.float32)
        ev_k = np.full(C, PAD_KIND, np.int32)
        ev_i = np.zeros(C, np.int32)
        ev_x = np.zeros(C, np.int32)
        upd_idx = np.full(C, POOL_SENTINEL, np.int32)
        upd_size = np.zeros((C, d), np.float32)
        upd_arr = np.zeros(C, np.float32)
        upd_rdep = np.zeros(C, np.float32)
        upd_pdep = np.zeros(C, np.float32)
        freed: list = []            # rows released by this chunk's deps
        freed_seqs: list = []
        fill = 0                    # events in the open chunk
        nupd = 0                    # pool updates in the open chunk

        def cut(final: bool) -> EventChunk:
            nonlocal fill, nupd
            # freed rows padded to the fixed (C,) geometry too, so the
            # placement harvest shares the chunk step's single jit trace
            fr = np.full(C, POOL_SENTINEL, np.int32)
            fr[:len(freed)] = freed
            fseq = np.full(C, -1, np.int64)
            fseq[:len(freed_seqs)] = freed_seqs
            chunk = EventChunk(
                ev_t.copy(), ev_k.copy(), ev_i.copy(), fill,
                upd_idx.copy(), upd_size.copy(), upd_arr.copy(),
                upd_rdep.copy(), upd_pdep.copy(),
                (ev_x.copy(),) if rcp else (),
                fr, fseq, self.item_rows, final)
            # rows freed by this chunk become allocatable from the next
            # chunk on - never inside it (the pool scatter is chunk-start)
            for r in freed:
                heapq.heappush(free, int(r))
            freed.clear()
            freed_seqs.clear()
            ev_t[:] = 0.0
            ev_k[:] = PAD_KIND
            ev_i[:] = 0
            ev_x[:] = xcount
            upd_idx[:] = POOL_SENTINEL
            upd_size[:] = 0.0
            upd_arr[:] = upd_rdep[:] = upd_pdep[:] = 0.0
            fill = nupd = 0
            return chunk

        def put(t: float, kind: int, row: int) -> Optional[EventChunk]:
            nonlocal fill, xcount
            ev_t[fill] = np.float32(t)
            ev_k[fill] = kind
            ev_i[fill] = row
            ev_x[fill] = xcount
            fill += 1
            return cut(False) if fill == C else None

        def alloc(seq: int) -> int:
            nonlocal next_fresh
            if not self.identity and free:
                return heapq.heappop(free)
            if next_fresh >= self.item_rows:
                if not self.grow:
                    raise RuntimeError(
                        f"item-row pool exhausted ({self.item_rows} rows) "
                        f"at request #{seq} with grow=False; pass a larger "
                        "item_rows or grow=True")
                self.item_rows *= 2
            row = next_fresh
            next_fresh += 1
            return row

        for size, arr, rdep, pdep in self.source.records():
            if arr < last_arr:
                raise ValueError(
                    f"stream not arrival-sorted: {arr} after {last_arr}")
            assert rdep > arr, f"departure {rdep} <= arrival {arr}"
            last_arr = arr
            # every departure at or before this arrival's time goes first
            # (equal times: departures precede arrivals, by item seq)
            while heap and heap[0][0] <= arr:
                dt, dseq, drow = heapq.heappop(heap)
                freed.append(drow)
                freed_seqs.append(dseq)
                del self._row_seq[drow]
                out = put(dt, DEPARTURE_KIND, drow)
                if out is not None:
                    yield out
            seq = self._seq_count
            self._seq_count += 1
            row = seq if self.identity else alloc(seq)
            if rcp:
                # host twin of the device category: float32 duration
                # arithmetic end to end, frexp-exact class boundaries
                pdur = np.float32(pdep) - np.float32(arr)
                cat = int(np.clip(geo_class(max(pdur, np.float32(0.0))),
                                  0, KCAT - 1))
                if not seen_cats[cat]:
                    seen_cats[cat] = True
                    xcount += 1
            self._row_seq[row] = seq
            upd_idx[nupd] = row
            upd_size[nupd] = np.asarray(size, np.float32)[:d]
            upd_arr[nupd] = np.float32(arr)
            upd_rdep[nupd] = np.float32(rdep)
            upd_pdep[nupd] = np.float32(pdep)
            nupd += 1
            heapq.heappush(heap, (float(rdep), seq, row))
            out = put(arr, ARRIVAL_KIND, row)
            if out is not None:
                yield out
        while heap:                 # drain the tail departures
            dt, dseq, drow = heapq.heappop(heap)
            freed.append(drow)
            freed_seqs.append(dseq)
            del self._row_seq[drow]
            out = put(dt, DEPARTURE_KIND, drow)
            if out is not None:
                yield out
        self._done = True
        yield cut(True)

    # ----------------------------------------------------------- queries
    @property
    def n_items(self) -> int:
        """Items streamed so far (total once the stream is drained)."""
        return self._seq_count

    def live_rows(self):
        """{pool row: global item seq} still alive (empty after a full
        drain; non-empty only if iteration stopped early)."""
        return dict(self._row_seq)


def synthetic_source(n_items: int, d: int = 4, seed: int = 0,
                     pm_cores: int = 64, med_lifetime: float = 1800.0,
                     sigma_lifetime: float = 1.6,
                     name: str = "stream_synth") -> InstanceSource:
    """A calibrated synthetic request stream (the azure-like generator),
    sized for benchmarks: ``n_items`` VMs => ``2 n_items`` events."""
    from ..data.traces import _one_instance
    return InstanceSource(_one_instance(seed, n_items, d, pm_cores,
                                        med_lifetime, sigma_lifetime, name))


def chunk_instance_events(times, kinds, items, chunk_events: int,
                          extras: Tuple[np.ndarray, ...] = ()):
    """Cut pre-materialized single-lane event arrays (any kinds, including
    MIGRATE) into PAD-padded fixed-geometry slices - the low-level chunking
    used by ``stream.replay.replay_chunked_events`` and the chunk-boundary
    tests.  Yields (times, kinds, items, extras, final) per chunk."""
    C = int(chunk_events)
    E = len(times)
    times = np.asarray(times, np.float32)
    kinds = np.asarray(kinds, np.int32)
    items = np.asarray(items, np.int32)
    nchunks = max(-(-E // C), 1)
    for s in range(0, nchunks * C, C):
        e = min(s + C, E)
        t = np.zeros(C, np.float32)
        k = np.full(C, PAD_KIND, np.int32)
        i = np.zeros(C, np.int32)
        t[:e - s] = times[s:e]
        k[:e - s] = kinds[s:e]
        i[:e - s] = items[s:e]
        ex = []
        for x in extras:
            xa = np.asarray(x)
            pad = np.zeros(C, xa.dtype)
            pad[:e - s] = xa[s:e]
            if e > s:               # PAD events carry the running value
                pad[e - s:] = xa[e - 1]
            ex.append(pad)
        yield t, k, i, tuple(ex), e >= E
