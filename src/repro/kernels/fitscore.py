"""DVBP placement Pallas TPU kernels - the paper's inner loop, fused.

At cloud scale an arrival must be scored against thousands of bin slots
x d resource dims: a bandwidth-bound stream over the loads matrix, ideal for
VMEM tiling.  Three kernels live here:

``fitscore`` (legacy scoring kernel)
    Tiles of 256 bins x d(pad 128) are scored per grid step: feasibility
    (all dims fit, ``EPS`` tolerance) + an l1/l2/linf fit score, and a
    running argmin in SMEM scratch emits the chosen bin directly.  Ties are
    broken by **opening order** (``open_seq``; defaults to slot index), the
    same rule the oracle engine applies when it walks open bins in opening
    order and takes the first minimum.

``fitscore_select_batch`` (the sweep scan's placement step)
    The full fused placement decision for a *batch of lanes*, covering the
    complete 8-policy score family of ``core.jaxsim`` (``SELECT_POLICIES``):
    feasibility, policy score, oracle-consistent (score, open_seq)
    lexicographic running argmin, the two-stage case-(a)/case-(b) select of
    ``nrt_prioritized``, and first-free-slot selection - one VMEM-tiled pass
    over a ``(lanes, bin-tiles)`` grid that emits the chosen slot per lane
    plus ``found`` / ``no_free`` flags.

    The optional *category mask* operand (``cmask``, (L, N) int32; 1 =
    eligible slot) restricts feasibility to category-compatible slots -
    how ``core.jaxsim`` replays the category-structured policy families
    (CBD/CBDT, Hybrid, RCP/PPE, Lifetime Alignment): their class-restricted
    First Fit / Best Fit stages are this same kernel with a mask computed
    from the carried per-slot category tags.

    ``fitscore_select_batch_padded`` is the hot-loop entry: the same
    decision for state already held in the kernel's padded (Np, dpad)
    layout (``select_pad_geometry``).  ``core.jaxsim._replay_batch`` keeps
    its whole scan carry in that layout and calls it once per event-scan
    step, so a whole sweep batch replays with zero host round-trips AND
    zero per-step re-padding (~25x redundant data traffic at d=5 before).

``fitscore_replay_block`` (the event-blocked replay megakernel)
    The next rung: instead of launching the select once per event and
    round-tripping the whole carry through HBM between scan steps, a block
    of ``T`` consecutive events is replayed *entirely on-chip* - departure
    application, category-state update, feasibility AND category-mask
    select, commit - with the packed padded carry resident in VMEM and
    written back once per block.  Covers every ``core.jaxsim`` policy
    family (score / CBD / CBDT / Hybrid / RCP-PPE / Lifetime Alignment /
    adaptive); ``core.jaxsim._replay_batch(block_events=T)`` drives it
    from a short ``lax.scan`` over event blocks, so the combined iteration
    space is (lanes, event-blocks).  The serving scheduler reuses the same
    kernel at T=1 (``kernels.ops.fitscore_select_block``).

Constants ``SCORE_BIG`` / ``SCORE_NEG`` / ``F32_EPS`` / ``IBIG`` /
``SELECT_POLICIES`` plus the replay encodings (event kinds, TAG_* / LOC_*
carry tags, KCAT) are the single source of truth for the scoring and
replay semantics; ``core.jaxsim`` and ``kernels.ops`` import them so the
inline jnp paths and the kernels can never drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EPS = 1e-9     # legacy fitscore tolerance (matches ref.fitscore_ref)
BIG = 3.0e38   # python float: baked into the legacy kernel as an immediate

NORMS = ("l1", "l2", "linf", "first_fit")

# --- shared scoring semantics (core.jaxsim imports these; do not fork) ----
SELECT_POLICIES = ("first_fit", "best_fit_l1", "best_fit_l2", "best_fit_linf",
                   "mru", "greedy", "nrt_standard", "nrt_prioritized")
SCORE_BIG = 1e30     # +BIG == infeasible slot
SCORE_NEG = -1e30    # closes sentinel for virgin/closed slots
F32_EPS = 1e-6       # fp32 capacity tolerance (oracle uses 1e-9/f64)
IBIG = 2 ** 30      # int sentinel for (open_seq, row) tie-break argmins

# --- shared replay semantics (single definition site; core.jaxsim
# re-exports these so the scan, the batching layer and the event-blocked
# megakernel cannot drift) -------------------------------------------------
ARRIVAL_KIND = 1     # event kinds in the precomputed sequence
DEPARTURE_KIND = 0
PAD_KIND = -1        # no-op filler event (the carry passes through)
MIGRATE_KIND = 2     # consolidation: leave current bin, re-place via the
#                      select (replay paths gate the branch on a static
#                      ``migrate`` flag so non-consolidating replays compile
#                      the exact pre-MIGRATE computation)

# Bin-role tags carried per slot (category tags are >= 0: the raw class for
# CBD/CBDT/RCP, cls / d + key for Hybrid).
TAG_VIRGIN, TAG_GENERAL, TAG_BASE, TAG_LARGE = -1, -2, -3, -4
TAG_NONE = -99       # matches no slot: forces "open a new bin"

# RCP/PPE item locations (carried per item for departure bookkeeping).
LOC_G, LOC_B, LOC_C, LOC_L = 0, 1, 2, 3

# Dense bound for RCP/PPE's carried per-category aggregates (geometric
# prediction buckets X_i; bucket 63 would need a 2^62-second duration).
KCAT = 64


def _kernel(rem_ref, alive_ref, oseq_ref, item_ref, score_ref, best_ref,
            sseq_ref, *, norm: str, bn: int, nb: int, n: int, d: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        best_ref[0] = jnp.float32(BIG)
        best_ref[1] = jnp.float32(-1.0)
        sseq_ref[0] = jnp.int32(IBIG)

    rem = rem_ref[...].astype(jnp.float32)        # (bn, dpad)
    item = item_ref[...].astype(jnp.float32)      # (1, dpad)
    after = rem - item
    dmask = jax.lax.broadcasted_iota(jnp.int32, after.shape, 1) < d
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    oseq = oseq_ref[...]                          # (bn, 1) int32
    alive = (alive_ref[...] > 0) & (rows < n)
    feasible = jnp.all((after >= -EPS) | ~dmask, axis=1, keepdims=True) & alive

    masked = jnp.where(dmask, after, 0.0)
    if norm == "l1":
        score = jnp.sum(masked, axis=1, keepdims=True)
    elif norm == "l2":
        score = jnp.sqrt(jnp.sum(masked * masked, axis=1, keepdims=True))
    elif norm == "linf":
        score = jnp.max(jnp.where(dmask, after, -BIG), axis=1, keepdims=True)
    else:   # first_fit: prefer earliest-opened feasible bin
        score = oseq.astype(jnp.float32)
    score = jnp.where(feasible, score, BIG)
    score_ref[...] = score

    # (score, open_seq) lexicographic running argmin: the oracle walks open
    # bins in opening order and keeps the first minimum, so score ties must
    # fall to the earliest-opened bin - NOT the smallest slot index (a closed
    # slot reused later has a small index but a late open_seq).
    tile_best = jnp.min(score)
    tied_seq = jnp.where((score == tile_best) & feasible, oseq, IBIG)
    tile_seq = jnp.min(tied_seq)
    tied_row = jnp.where(tied_seq == tile_seq, rows, IBIG)
    tile_arg = jnp.min(tied_row)

    better = (tile_best < best_ref[0]) | \
        ((tile_best == best_ref[0]) & (tile_seq < sseq_ref[0]))

    @pl.when(better)
    def _upd():
        best_ref[0] = tile_best
        best_ref[1] = tile_arg.astype(jnp.float32)
        sseq_ref[0] = tile_seq


def fitscore(remaining, alive, item, open_seq=None, *, norm: str = "linf",
             bn: int = 256, interpret: bool = False):
    """remaining: (N,d); alive: (N,) bool/int; item: (d,); open_seq: (N,)
    opening-order keys for tie-breaking (defaults to the slot index).
    Returns (scores (N,), best_idx scalar int32, -1 if none feasible)."""
    assert norm in NORMS
    N, d = remaining.shape
    dpad = max(128, -(-d // 128) * 128)
    bn_ = min(bn, max(N, 8))
    nb = -(-N // bn_)
    rem_p = jnp.zeros((nb * bn_, dpad), remaining.dtype)
    rem_p = rem_p.at[:N, :d].set(remaining)
    alive_p = jnp.zeros((nb * bn_, 1), jnp.int32).at[:N, 0].set(
        alive.astype(jnp.int32))
    if open_seq is None:
        open_seq = jnp.arange(N, dtype=jnp.int32)
    oseq_p = jnp.full((nb * bn_, 1), IBIG, jnp.int32).at[:N, 0].set(
        open_seq.astype(jnp.int32))
    item_p = jnp.zeros((1, dpad), remaining.dtype).at[0, :d].set(item)

    kernel = functools.partial(_kernel, norm=norm, bn=bn_, nb=nb, n=N, d=d)
    scores, best = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn_, dpad), lambda i: (i, 0)),
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, dpad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * bn_, 1), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.float32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(rem_p, alive_p, oseq_p, item_p)
    scores = jnp.where(scores[:N, 0] >= BIG, jnp.inf, scores[:N, 0])
    best_idx = jnp.where(best[0] >= BIG, -1, best[1]).astype(jnp.int32)
    return scores, best_idx


# ======================================================================
# Fused batched placement-step kernel (all 8 jaxsim policies)
# ======================================================================

def _select_kernel(loads_ref, counts_ref, alive_ref, oseq_ref, aseq_ref,
                   closes_ref, size_ref, dmask_ref, cmask_ref, pdep_ref,
                   now_ref, out_ref, fbest, ibest, *, policy: str, bn: int,
                   nb: int, n: int):
    """One (lane, bin-tile) grid step of the fused placement decision.

    ``cmask_ref`` (1, bn) int32 is the *category mask*: 1 marks slots the
    policy's category structure allows for this arrival (same-tag bins for
    CBD/CBDT/Hybrid/RCP lanes, same-lifetime-class bins for Lifetime
    Alignment; all-ones for the plain score policies).  It is folded into
    feasibility before scoring, so a lane with no category-compatible
    feasible bin reports ``found=False`` and falls through to the free-slot
    stage - exactly the host classes' "open a new bin of my category"
    contract.

    SMEM scratch layout (running state for the current lane; grid iterates
    tiles innermost so it is reset at tile 0 and emitted at tile nb-1):
      fbest[0] best case-(a) score     fbest[1] best case-(b) score
      ibest[0] case-(a) open_seq       ibest[1] case-(a) slot
      ibest[2] case-(b) open_seq       ibest[3] case-(b) slot
      ibest[4] first free slot
    Case (b) is only maintained for ``nrt_prioritized`` (its strict
    case-(a)-before-case-(b) two-stage select); every other policy uses the
    case-(a) registers alone.
    """
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        fbest[0] = jnp.float32(SCORE_BIG)
        fbest[1] = jnp.float32(SCORE_BIG)
        ibest[0] = jnp.int32(IBIG)
        ibest[1] = jnp.int32(0)
        ibest[2] = jnp.int32(IBIG)
        ibest[3] = jnp.int32(0)
        ibest[4] = jnp.int32(IBIG)

    loads = loads_ref[...].astype(jnp.float32)    # (1, bn, dpad)
    size = size_ref[...].astype(jnp.float32)      # (1, dpad)
    dmask = dmask_ref[...].astype(jnp.float32)    # (1, dpad)
    counts = counts_ref[...]                      # (1, bn) int32
    oseq = oseq_ref[...]                          # (1, bn) int32
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    rowmask = rows < n
    alive = (alive_ref[...] > 0) & rowmask
    pdep = pdep_ref[0, 0]
    now = now_ref[0, 0]

    # feasibility - the exact jnp expression of core.jaxsim._score,
    # restricted to category-compatible slots
    feasible = jnp.all(size[:, None, :] <= 1.0 - loads + F32_EPS,
                       axis=2) & alive & (cmask_ref[...] > 0)   # (1, bn)

    if policy == "first_fit":
        s = oseq.astype(jnp.float32)
    elif policy == "mru":
        s = -aseq_ref[...].astype(jnp.float32)
    elif policy.startswith("best_fit"):
        after = 1.0 - loads - size[:, None, :]    # (1, bn, dpad)
        if policy.endswith("l1"):
            s = jnp.sum(after * dmask[:, None, :], axis=2)
        elif policy.endswith("l2"):
            masked = after * dmask[:, None, :]
            s = jnp.sqrt(jnp.sum(masked * masked, axis=2))
        else:
            s = jnp.max(jnp.where(dmask[:, None, :] > 0, after, SCORE_NEG),
                        axis=2)
    elif policy == "greedy":
        s = -jnp.maximum(closes_ref[...], now)
    elif policy == "nrt_standard":
        s = jnp.abs(jnp.maximum(closes_ref[...], now) - pdep)
    else:   # nrt_prioritized
        gap = jnp.maximum(closes_ref[...], now) - pdep
        sa = jnp.where(feasible & (gap >= 0), gap, SCORE_BIG)
        sb = jnp.where(feasible & (gap < 0), -gap, SCORE_BIG)

    def merge(score, f_slot: int, i_slot: int):
        """(score, open_seq) lexicographic running argmin over tiles."""
        tile_best = jnp.min(score)
        tied_seq = jnp.where((score == tile_best) & feasible, oseq, IBIG)
        tile_seq = jnp.min(tied_seq)
        tile_arg = jnp.min(jnp.where(tied_seq == tile_seq, rows, IBIG))
        better = (tile_best < fbest[f_slot]) | \
            ((tile_best == fbest[f_slot]) & (tile_seq < ibest[i_slot]))

        @pl.when(better)
        def _():
            fbest[f_slot] = tile_best
            ibest[i_slot] = tile_seq
            ibest[i_slot + 1] = tile_arg

    if policy == "nrt_prioritized":
        merge(sa, 0, 0)
        merge(sb, 1, 2)
    else:
        merge(jnp.where(feasible, s, SCORE_BIG), 0, 0)

    tile_free = jnp.min(jnp.where((counts == 0) & rowmask, rows, IBIG))
    ibest[4] = jnp.minimum(ibest[4], tile_free)

    @pl.when(i == nb - 1)
    def _emit():
        found_a = fbest[0] < SCORE_BIG
        if policy == "nrt_prioritized":
            found = found_a | (fbest[1] < SCORE_BIG)
            best = jnp.where(found_a, ibest[1], ibest[3])
        else:
            found = found_a
            best = ibest[1]
        no_free = ibest[4] >= IBIG
        free = jnp.where(no_free, 0, ibest[4])   # argmin-of-empty == 0 (jnp)
        out_ref[b, 0] = jnp.where(found, best, free)
        out_ref[b, 1] = found.astype(jnp.int32)
        out_ref[b, 2] = no_free.astype(jnp.int32)


def select_pad_geometry(n: int, d: int, bn: int = 256):
    """Kernel layout for an ``n``-slot, ``d``-dim pool: (Np, dpad, bn, nb).
    Shared with ``core.jaxsim`` so the scan carry can live pre-padded."""
    dpad = max(128, -(-d // 128) * 128)
    bn_ = min(bn, max(n, 8))
    nb = -(-n // bn_)
    return nb * bn_, dpad, bn_, nb


def fitscore_select_batch_padded(loads, counts, alive, open_seq, access_seq,
                                 closes, size, pdep, now, dmask, cmask=None,
                                 *, policy: str, n: int, bn: int = 256,
                                 interpret: bool = False):
    """``fitscore_select_batch`` for state already in kernel layout.

    Arguments are pre-padded per :func:`select_pad_geometry`: loads
    (L, Np, dpad); counts/alive/open_seq/access_seq/closes and the optional
    category mask ``cmask`` (L, Np); size/dmask (L, dpad); pdep/now (L,).
    ``n`` is the real slot-pool size (rows >= n are layout padding and are
    excluded from both the feasible and the free-slot stage).

    This is the replay scan's entry: ``core.jaxsim._replay_batch`` keeps its
    whole carry in this layout, so each step reads/writes the state the
    kernel consumes directly instead of re-padding (Np x dpad) every event
    (~25x redundant traffic at d=5).
    """
    assert policy in SELECT_POLICIES, policy
    L, Np, dpad = loads.shape
    Np_, dpad_, bn_, nb = select_pad_geometry(n, 1, bn)
    assert Np == Np_ and dpad % 128 == 0, (loads.shape, n, bn)
    f32, i32 = jnp.float32, jnp.int32
    if cmask is None:
        cmask = jnp.ones((L, Np), i32)
    kernel = functools.partial(_select_kernel, policy=policy, bn=bn_, nb=nb,
                               n=n)
    out = pl.pallas_call(
        kernel,
        grid=(L, nb),
        in_specs=[
            pl.BlockSpec((1, bn_, dpad), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, dpad), lambda b, i: (b, 0)),
            pl.BlockSpec((1, dpad), lambda b, i: (b, 0)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((L, 3), jnp.int32),
        scratch_shapes=[pltpu.SMEM((2,), jnp.float32),
                        pltpu.SMEM((8,), jnp.int32)],
        interpret=interpret,
    )(loads.astype(f32), counts.astype(i32), alive.astype(i32),
      open_seq.astype(i32), access_seq.astype(i32), closes.astype(f32),
      size.astype(f32), dmask.astype(f32), cmask.astype(i32),
      pdep.astype(f32).reshape(L, 1), now.astype(f32).reshape(L, 1))
    return out[:, 0], out[:, 1] > 0, out[:, 2] > 0


# ======================================================================
# Event-blocked replay megakernel: whole blocks of the DVBP scan on-chip
# ======================================================================
#
# ``fitscore_replay_block`` runs a block of ``T`` consecutive events of the
# replay scan - departure application, category-state update, feasibility
# AND category-mask select, and the commit - entirely inside one kernel
# invocation, for every policy family ``core.jaxsim._replay_batch``
# replays.  The padded (Np, dpad) carry stays resident in VMEM for the
# whole block and round-trips through HBM once per block instead of once
# per event; ``core.jaxsim`` drives it from a short ``lax.scan`` over
# event blocks, so the combined iteration space is (lanes, event-blocks).
#
# Carry layout (packed per lane; built by ``core.jaxsim``):
#   loads  (L, Np, dpad) f32   per-slot load vectors (kernel layout)
#   slotf  (L, Np, 8)    f32   cols: SLOTF_CLOSES, SLOTF_OPEN_TIME
#   sloti  (L, Np, 8)    i32   cols: counts, alive, open_seq, access_seq,
#                              category tag
#   itemi  (L, nmax, 8)  i32   cols: placements, family aux (hybrid ingen /
#                              rcp location)
#   sf     (L, 8)        f32   cols: usage, PPE alpha, adaptive error
#   si     (L, 8)        i32   cols: seq, opened, overflow, rcp base slot
#   hagg   (L, nmax, dpad) f32   hybrid per-key aggregates (hybrid only)
#   ragg   (L, 3*KCAT+8, dpad) f32  rcp aggregates: [gen | cat | bcat rows,
#                              base row at RAGG_BASE] (rcp only)
#   ron    (L, KCAT, 8)  i32   rcp per-category ON flags (rcp only)
#
# Per-event inputs stream in as (L, T) SMEM scalar blocks plus one
# (L, T, dpad) VMEM block of pre-gathered item sizes - all pure functions
# of the (predicted) durations, precomputed before the scan.

SLOTF_CLOSES, SLOTF_OPEN_TIME, SLOTF_COLS = 0, 1, 8
(SLOTI_COUNTS, SLOTI_ALIVE, SLOTI_OSEQ, SLOTI_ASEQ, SLOTI_TAG,
 SLOTI_COLS) = 0, 1, 2, 3, 4, 8
ITEMI_PLACE, ITEMI_AUX, ITEMI_COLS = 0, 1, 8
SF_USAGE, SF_ALPHA, SF_ERR, SF_COLS = 0, 1, 2, 8
SI_SEQ, SI_OPENED, SI_OVERFLOW, SI_BASE, SI_COLS = 0, 1, 2, 3, 8
RAGG_BASE = 3 * KCAT           # rcp aggregate row holding the base bin
RAGG_ROWS = 3 * KCAT + 8
RON_COLS = 8

REPLAY_FAMILIES = ("score", "cbd", "hybrid", "rcp", "la", "adaptive")
# per-family extra per-event scalar streams (beyond kind/item and t/pdep)
REPLAY_EV_I = {"score": (), "cbd": ("cat",), "hybrid": ("key", "cls"),
               "rcp": ("cat", "large", "x"), "la": ("cat",),
               "adaptive": ()}
REPLAY_EV_F = {"score": (), "cbd": (), "hybrid": ("thr",),
               "rcp": ("p2err",), "la": (), "adaptive": ("errmax",)}
_REPLAY_EXTRA_CARRY = {"hybrid": ("hagg",), "rcp": ("ragg", "ron")}


def replay_carry_names(family: str):
    """Ordered carry-array names for one policy family."""
    assert family in REPLAY_FAMILIES, family
    return (("loads", "slotf", "sloti", "itemi", "sf", "si") +
            _REPLAY_EXTRA_CARRY.get(family, ()))


def _replay_block_kernel(*refs, family: str, policy: str, n: int, d: int,
                         T: int, large_bins: bool, adaptive_alpha: bool,
                         direct_sum: bool, la_mode: str, la_split: float,
                         low: float, high: float, migrate: bool, nc: int,
                         ni: int, nf: int):
    """One lane's block of ``T`` events, carry resident in VMEM.

    ``refs`` = nc carry inputs, 2+ni event int streams, 2+nf event float
    streams, ev_size, dmask, then the nc carry outputs (aliased to the
    inputs).  The body is the exact fp32 op sequence of the jnp reference
    step (``core.jaxsim._replay_batch``) scalarized per lane: per-slot
    state updates are masked vector ops over (Np, 1) columns, per-item and
    per-category aggregate rows use dynamic sublane slices.
    """
    f32, i32 = jnp.float32, jnp.int32
    names = replay_carry_names(family)
    cin = dict(zip(names, refs[:nc]))
    k = nc
    evi = dict(zip(("kind", "item") + REPLAY_EV_I[family],
                   refs[k:k + 2 + ni]))
    k += 2 + ni
    evf = dict(zip(("t", "pdep") + REPLAY_EV_F[family], refs[k:k + 2 + nf]))
    k += 2 + nf
    size_ref, dmask_ref = refs[k], refs[k + 1]
    c = dict(zip(names, refs[k + 2:k + 2 + nc]))

    # one HBM->VMEM copy per block: every event below reads and writes the
    # (aliased) out blocks only
    for nm in names:
        c[nm][...] = cin[nm][...]

    Np = c["loads"].shape[1]
    nmax = c["itemi"].shape[1]
    rowsN = jax.lax.broadcasted_iota(i32, (Np, 1), 0)
    rowmask = rowsN < n
    rowsI = jax.lax.broadcasted_iota(i32, (nmax, 1), 0)
    rowsK = jax.lax.broadcasted_iota(i32, (KCAT, 1), 0)
    dm = dmask_ref[...]                                   # (1, dpad)

    def scol_i(col):
        return c["sloti"][0, :, col:col + 1]              # (Np, 1) i32

    def scol_f(col):
        return c["slotf"][0, :, col:col + 1]              # (Np, 1) f32

    def set_scol_i(col, v):
        c["sloti"][0, :, col:col + 1] = v

    def set_scol_f(col, v):
        c["slotf"][0, :, col:col + 1] = v

    def at_slot(colv, b, zero):
        return jnp.sum(jnp.where(rowsN == b, colv, zero))

    def at_item(col, j):
        return jnp.sum(jnp.where(rowsI == j, c["itemi"][0, :, col:col + 1],
                                 0))

    def body(e, _):
        kind = evi["kind"][0, e]
        j = evi["item"][0, e]
        t = evf["t"][0, e]
        pdep = evf["pdep"][0, e]
        size = size_ref[0, pl.ds(e, 1), :]                # (1, dpad)

        def select(pol, cmask, excl=None):
            """The fused placement decision on the current carry - the
            exact semantics of ``_select_kernel`` / ``_select_slot``.
            ``excl`` (migrate re-place only) removes one slot - the item's
            source bin - from feasibility, never from the free-slot stage.

            Deliberately a third expression of the shared scoring
            semantics (per-lane (Np, 1) columns here vs the tiled
            (1, bn) SMEM-register select kernel): the three stay pinned
            together by the shared SCORE_*/F32_EPS/IBIG constants and the
            bitwise parity matrix in tests/test_fitscore_select.py +
            tests/test_replay_block.py - any drift fails those, so edit
            all three together when touching a policy's score."""
            loads2 = c["loads"][0]                        # (Np, dpad)
            cnt = scol_i(SLOTI_COUNTS)
            oseq = scol_i(SLOTI_OSEQ)
            closes = scol_f(SLOTF_CLOSES)
            feas = jnp.all(size <= 1.0 - loads2 + F32_EPS, axis=1,
                           keepdims=True) & \
                (scol_i(SLOTI_ALIVE) > 0) & rowmask
            if excl is not None:
                feas = feas & (rowsN != excl)
            if cmask is not None:
                feas = feas & cmask

            def run_min(s, fm):
                s = jnp.where(fm, s, SCORE_BIG)
                smin = jnp.min(s)
                tied = jnp.where((s == smin) & fm, oseq, IBIG)
                tseq = jnp.min(tied)
                trow = jnp.min(jnp.where(tied == tseq, rowsN, IBIG))
                return smin, trow

            if pol == "nrt_prioritized":
                gap = jnp.maximum(closes, t) - pdep
                amin, arow = run_min(jnp.where(gap >= 0, gap, SCORE_BIG),
                                     feas)
                bmin, brow = run_min(jnp.where(gap < 0, -gap, SCORE_BIG),
                                     feas)
                found = (amin < SCORE_BIG) | (bmin < SCORE_BIG)
                best = jnp.where(amin < SCORE_BIG, arow, brow)
            else:
                if pol == "first_fit":
                    s = oseq.astype(f32)
                elif pol == "mru":
                    s = -scol_i(SLOTI_ASEQ).astype(f32)
                elif pol.startswith("best_fit"):
                    after = 1.0 - loads2 - size
                    if pol.endswith("l1"):
                        s = jnp.sum(after * dm, axis=1, keepdims=True)
                    elif pol.endswith("l2"):
                        m_ = after * dm
                        s = jnp.sqrt(jnp.sum(m_ * m_, axis=1,
                                             keepdims=True))
                    else:
                        s = jnp.max(jnp.where(dm > 0, after, SCORE_NEG),
                                    axis=1, keepdims=True)
                elif pol == "greedy":
                    s = -jnp.maximum(closes, t)
                else:   # nrt_standard
                    s = jnp.abs(jnp.maximum(closes, t) - pdep)
                smin, best = run_min(s, feas)
                found = smin < SCORE_BIG
            fr = jnp.min(jnp.where((cnt == 0) & rowmask, rowsN, IBIG))
            no_free = fr >= IBIG
            b = jnp.where(found, best, jnp.where(no_free, 0, fr))
            return b.astype(i32), found, no_free

        # ------------------------------------------------ departure branch
        def dep_apply(learn: bool):
            """Remove item ``j`` from its bin: shared bin bookkeeping plus
            the per-family aggregate decrements.  ``learn=False`` is the
            migrate flavor - a migration is not a departure *observation*,
            so the departure-driven learning updates (PPE's alpha
            guess-and-double, the adaptive switch's running error) are
            skipped."""
            b = at_item(ITEMI_PLACE, j)
            rm = rowsN == b
            cnt = scol_i(SLOTI_COUNTS) - rm.astype(i32)
            closing = at_slot(cnt, b, 0) == 0
            ot_b = at_slot(scol_f(SLOTF_OPEN_TIME), b, 0.0)
            c["sf"][0, SF_USAGE] = c["sf"][0, SF_USAGE] + \
                jnp.where(closing, t - ot_b, 0.0)
            loads2 = c["loads"][0]
            loads2 = jnp.where(rm, loads2 - size, loads2)
            c["loads"][0, :, :] = jnp.where(rm & closing, 0.0, loads2)
            set_scol_i(SLOTI_COUNTS, cnt)
            set_scol_i(SLOTI_ALIVE,
                       jnp.where(rm & closing, 0, scol_i(SLOTI_ALIVE)))
            set_scol_f(SLOTF_CLOSES,
                       jnp.where(rm & closing, SCORE_NEG,
                                 scol_f(SLOTF_CLOSES)))

            if family == "hybrid":
                keyj = evi["key"][0, e]
                wasg = at_item(ITEMI_AUX, j) > 0
                row = c["hagg"][0, pl.ds(keyj, 1), :]
                c["hagg"][0, pl.ds(keyj, 1), :] = jnp.maximum(
                    row - jnp.where(wasg, size, 0.0), 0.0)
            elif family == "rcp":
                catj = evi["cat"][0, e]
                locd = at_item(ITEMI_AUX, j)
                base = c["si"][0, SI_BASE]
                has_base = base >= 0
                gen_row = c["ragg"][0, pl.ds(catj, 1), :]
                c["ragg"][0, pl.ds(catj, 1), :] = jnp.maximum(
                    gen_row - jnp.where(locd == LOC_G, size, 0.0), 0.0)
                cat_row = c["ragg"][0, pl.ds(KCAT + catj, 1), :]
                new_cat = jnp.maximum(
                    cat_row - jnp.where(locd == LOC_C, size, 0.0), 0.0)
                c["ragg"][0, pl.ds(KCAT + catj, 1), :] = new_cat
                oncol = c["ron"][0, :, 0:1]
                on_cat = jnp.sum(jnp.where(rowsK == catj, oncol, 0)) > 0
                turn_off = (locd == LOC_C) & on_cat & \
                    (jnp.max(new_cat) < 0.5)
                c["ron"][0, :, 0:1] = jnp.where((rowsK == catj) & turn_off,
                                                0, oncol)
                base_closed = closing & has_base & (b == base)
                sz_b = jnp.where(locd == LOC_B, size, 0.0)
                base_row = c["ragg"][0, RAGG_BASE:RAGG_BASE + 1, :]
                c["ragg"][0, RAGG_BASE:RAGG_BASE + 1, :] = jnp.where(
                    base_closed, 0.0, jnp.maximum(base_row - sz_b, 0.0))
                bcat = c["ragg"][0, 2 * KCAT:3 * KCAT, :]
                bcat = jnp.where(rowsK == catj,
                                 jnp.maximum(bcat - sz_b, 0.0), bcat)
                c["ragg"][0, 2 * KCAT:3 * KCAT, :] = jnp.where(
                    base_closed, 0.0, bcat)
                c["si"][0, SI_BASE] = jnp.where(base_closed, -1, base)
                if adaptive_alpha and learn:
                    c["sf"][0, SF_ALPHA] = jnp.maximum(
                        c["sf"][0, SF_ALPHA], evf["p2err"][0, e])
            elif family == "adaptive" and learn:
                c["sf"][0, SF_ERR] = jnp.maximum(c["sf"][0, SF_ERR],
                                                 evf["errmax"][0, e])

        @pl.when(kind == DEPARTURE_KIND)
        def _dep():
            dep_apply(True)

        # -------------------------------------------------- arrival branch
        def arr_apply(excl):
            """Place item ``j``: the per-family decision + the shared
            commit.  ``excl`` (migrate re-place only) keeps the select off
            the item's source slot."""
            tag = scol_i(SLOTI_TAG)
            post = None      # family commit, needs (b, rm, found)

            if family == "score":
                b, found, no_free = select(policy, None, excl)

            elif family == "cbd":
                catj = evi["cat"][0, e]
                b, found, no_free = select("first_fit", tag == catj, excl)

                def post(b, rm, found):
                    set_scol_i(SLOTI_TAG,
                               jnp.where(rm & ~found, catj, tag))

            elif family == "hybrid":
                keyj = evi["key"][0, e]
                clsj = evi["cls"][0, e]
                thrj = evf["thr"][0, e]
                aggrow = c["hagg"][0, pl.ds(keyj, 1), :]
                after = aggrow + size
                if direct_sum:
                    cols = jax.lax.broadcasted_iota(i32, after.shape, 1)
                    norm = jnp.sum(jnp.where(cols == clsj, after, 0.0))
                else:
                    norm = jnp.max(after)
                is_gen = norm <= thrj + F32_EPS
                wanted = jnp.where(is_gen, clsj, d + keyj)
                b, found, no_free = select("first_fit", tag == wanted, excl)

                def post(b, rm, found):
                    set_scol_i(SLOTI_TAG,
                               jnp.where(rm & ~found, wanted, tag))
                    c["hagg"][0, pl.ds(keyj, 1), :] = aggrow + \
                        jnp.where(is_gen, size, 0.0)
                    aux = c["itemi"][0, :, ITEMI_AUX:ITEMI_AUX + 1]
                    c["itemi"][0, :, ITEMI_AUX:ITEMI_AUX + 1] = jnp.where(
                        rowsI == j, is_gen.astype(i32), aux)

            elif family == "rcp":
                catj = evi["cat"][0, e]
                largej = evi["large"][0, e] > 0
                x = jnp.maximum(evi["x"][0, e], 1).astype(f32)
                coef = c["sf"][0, SF_ALPHA] if adaptive_alpha else 1.0
                thr = coef / jnp.sqrt(x)
                gen_row = c["ragg"][0, pl.ds(catj, 1), :]
                fits_gen = jnp.max(gen_row + size) <= thr + F32_EPS
                base = c["si"][0, SI_BASE]
                has_base = base >= 0
                base_loads = c["loads"][0, pl.ds(jnp.maximum(base, 0), 1), :]
                base_fits = jnp.where(
                    has_base,
                    jnp.all(size <= 1.0 - base_loads + F32_EPS), True)
                if excl is not None:
                    # migrate off the base bin itself: the re-place must
                    # not target its own source (matches the host oracle,
                    # where the source bin is infeasible during the select)
                    base_fits = base_fits & (base != excl)
                oncol = c["ron"][0, :, 0:1]
                is_on = jnp.sum(jnp.where(rowsK == catj, oncol, 0)) > 0
                d_large = largej if large_bins else False
                d_gen = ~d_large & fits_gen
                d_cat = ~d_large & ~fits_gen & is_on
                d_base = ~d_large & ~fits_gen & ~is_on & base_fits
                d_catf = ~d_large & ~fits_gen & ~is_on & ~base_fits
                wanted = jnp.where(
                    d_gen, TAG_GENERAL,
                    jnp.where(d_cat, catj,
                              jnp.where(d_base & has_base, TAG_BASE,
                                        TAG_NONE)))
                b, found, no_free = select("first_fit", tag == wanted, excl)

                def post(b, rm, found):
                    open_tag = jnp.where(
                        d_large, TAG_LARGE,
                        jnp.where(d_gen, TAG_GENERAL,
                                  jnp.where(d_base, TAG_BASE, catj)))
                    tag1 = jnp.where(rm & ~found, open_tag, tag)
                    new_base = d_base & ~has_base
                    base_a = jnp.where(new_base, b, base)
                    # aggregates: general / category adds, base-zeroing on
                    # a fresh base bin, then the 1/2-threshold conversion
                    c["ragg"][0, pl.ds(catj, 1), :] = gen_row + \
                        jnp.where(d_gen, size, 0.0)
                    cat_row = c["ragg"][0, pl.ds(KCAT + catj, 1), :]
                    cat_row = cat_row + jnp.where(d_cat | d_catf, size, 0.0)
                    bcat = c["ragg"][0, 2 * KCAT:3 * KCAT, :]
                    bcat = jnp.where(new_base, 0.0, bcat)
                    bcat = jnp.where(rowsK == catj,
                                     bcat + jnp.where(d_base, size, 0.0),
                                     bcat)
                    base_row = c["ragg"][0, RAGG_BASE:RAGG_BASE + 1, :]
                    base_row = jnp.where(new_base, 0.0, base_row) + \
                        jnp.where(d_base, size, 0.0)
                    onc = jnp.where(rowsK == catj,
                                    oncol | d_catf.astype(i32), oncol)
                    aux = c["itemi"][0, :, ITEMI_AUX:ITEMI_AUX + 1]
                    locv = jnp.where(
                        d_gen, LOC_G,
                        jnp.where(d_base, LOC_B,
                                  jnp.where(d_large, LOC_L, LOC_C)))
                    aux = jnp.where(rowsI == j, locv, aux)
                    # base conversion (paper §VI-A): base exceeded 1/2 ->
                    # becomes a category bin of its dominant member
                    # category, which turns ON
                    conv = d_base & (jnp.max(base_row) > 0.5)
                    bmax = jnp.max(bcat, axis=1, keepdims=True)   # (KCAT,1)
                    mmax = jnp.max(bmax)
                    dom = jnp.min(jnp.where(bmax == mmax, rowsK, IBIG))
                    tag1 = jnp.where(rm & conv, dom, tag1)
                    onc = jnp.where(rowsK == dom, onc | conv.astype(i32),
                                    onc)
                    cat_row = jnp.where(
                        conv,
                        cat_row + jnp.sum(
                            jnp.where(rowsK == catj, bcat, 0.0), axis=0,
                            keepdims=True),
                        cat_row)
                    catblk = c["ragg"][0, KCAT:2 * KCAT, :]
                    # whole-block add of bcat into cat on conversion; the
                    # catj row was already read out, so write it last
                    catblk = jnp.where(conv, catblk + bcat, catblk)
                    catblk = jnp.where(rowsK == catj, cat_row, catblk)
                    c["ragg"][0, KCAT:2 * KCAT, :] = catblk
                    aux = jnp.where(conv & (aux == LOC_B), LOC_C, aux)
                    set_scol_i(SLOTI_TAG, tag1)
                    c["ron"][0, :, 0:1] = onc
                    c["itemi"][0, :, ITEMI_AUX:ITEMI_AUX + 1] = aux
                    c["ragg"][0, 2 * KCAT:3 * KCAT, :] = jnp.where(
                        conv, 0.0, bcat)
                    c["ragg"][0, RAGG_BASE:RAGG_BASE + 1, :] = jnp.where(
                        conv, 0.0, base_row)
                    c["si"][0, SI_BASE] = jnp.where(conv, -1, base_a)

            elif family == "la":
                icat = evi["cat"][0, e]
                remt = jnp.maximum(scol_f(SLOTF_CLOSES), t) - t   # (Np, 1)
                if la_mode == "binary":
                    bincat = (remt >= la_split).astype(i32)
                else:   # geometric: frexp exponent via the f32 bit pattern
                    bits = jax.lax.bitcast_convert_type(remt, i32)
                    bexp = ((bits >> 23) & 0xFF) - 126
                    bincat = jnp.where(remt < 1.0, 0, bexp)
                same = bincat == icat
                short = icat == 0
                ra = select("best_fit_linf", same | short, excl)
                rb = select("best_fit_linf", (~same) & ~short, excl)
                found = ra[1] | rb[1]
                b = jnp.where(ra[1], ra[0], rb[0]).astype(i32)
                no_free = ra[2]

            else:   # adaptive: regime-switch on the carried departure error
                err = c["sf"][0, SF_ERR]
                kreg = jnp.where(err < low, 0, jnp.where(err < high, 1, 2))
                r0 = select("nrt_prioritized", None, excl)
                r1 = select("greedy", None, excl)
                r2 = select("first_fit", None, excl)
                b = jnp.where(kreg == 0, r0[0],
                              jnp.where(kreg == 1, r1[0], r2[0])).astype(i32)
                found = jnp.where(kreg == 0, r0[1],
                                  jnp.where(kreg == 1, r1[1], r2[1]))
                no_free = r0[2]

            # ---- shared commit
            rm = rowsN == b
            seq = c["si"][0, SI_SEQ]
            loads2 = c["loads"][0]
            c["loads"][0, :, :] = jnp.where(rm, loads2 + size, loads2)
            set_scol_i(SLOTI_COUNTS, scol_i(SLOTI_COUNTS) + rm.astype(i32))
            set_scol_i(SLOTI_ALIVE,
                       jnp.where(rm, 1, scol_i(SLOTI_ALIVE)))
            set_scol_i(SLOTI_OSEQ,
                       jnp.where(rm & ~found, seq, scol_i(SLOTI_OSEQ)))
            set_scol_f(SLOTF_OPEN_TIME,
                       jnp.where(rm & ~found, t, scol_f(SLOTF_OPEN_TIME)))
            set_scol_i(SLOTI_ASEQ, jnp.where(rm, seq, scol_i(SLOTI_ASEQ)))
            closes = scol_f(SLOTF_CLOSES)
            set_scol_f(SLOTF_CLOSES, jnp.where(
                rm,
                jnp.maximum(jnp.where(found, closes, SCORE_NEG),
                            jnp.maximum(pdep, t)),
                closes))
            place = c["itemi"][0, :, ITEMI_PLACE:ITEMI_PLACE + 1]
            c["itemi"][0, :, ITEMI_PLACE:ITEMI_PLACE + 1] = jnp.where(
                rowsI == j, b, place)
            c["si"][0, SI_OPENED] = c["si"][0, SI_OPENED] + \
                (~found).astype(i32)
            c["si"][0, SI_OVERFLOW] = c["si"][0, SI_OVERFLOW] | \
                ((~found) & no_free).astype(i32)
            c["si"][0, SI_SEQ] = seq + 1
            if post is not None:
                post(b, rm, found)

        @pl.when(kind == ARRIVAL_KIND)
        def _arr():
            arr_apply(None)

        if migrate:
            # consolidation: a MIGRATE event is a full departure (learning
            # updates skipped) followed by the arrival machinery evaluated
            # on the post-departure carry, with the source slot excluded
            # from the select.  Compiled only when the replay carries
            # migrations - migrate=False is the exact pre-MIGRATE kernel.
            @pl.when(kind == MIGRATE_KIND)
            def _mig():
                src = at_item(ITEMI_PLACE, j)
                dep_apply(False)
                arr_apply(src)
        return 0

    jax.lax.fori_loop(0, T, body, 0)


def fitscore_replay_block(carry, ev_i, ev_f, ev_size, dmask, *, family: str,
                          policy: str, n: int, d: int,
                          large_bins: bool = True,
                          adaptive_alpha: bool = False,
                          direct_sum: bool = False, la_mode: str = "binary",
                          la_split: float = 7200.0, low: float = 2.0,
                          high: float = 16.0, migrate: bool = False,
                          interpret: bool = False):
    """Replay one block of ``T`` events for ``L`` lanes entirely on-chip.

    ``carry`` is a dict of the packed per-lane carry arrays (see the
    section comment above; ``replay_carry_names(family)`` lists them);
    ``ev_i`` / ``ev_f`` map stream names to (L, T) int32/float32 arrays
    (always ``kind``/``item`` resp. ``t``/``pdep`` plus the family's
    ``REPLAY_EV_I`` / ``REPLAY_EV_F`` extras); ``ev_size`` is the
    (L, T, dpad) pre-gathered item sizes and ``dmask`` the (L, dpad)
    real-dimension mask.  ``n`` is the real slot-pool size, ``d`` the real
    dimension count (hybrid tags encode ``d + key``).

    Returns the updated carry dict.  The big VMEM carry arrays are aliased
    input->output, so under jit the block update is in-place in HBM: the
    carry round-trips through HBM once per *block* instead of once per
    event (the per-event fused-select path re-reads and re-writes it every
    scan step).

    ``migrate=True`` additionally compiles the MIGRATE event branch
    (consolidation: departure + masked re-place in one event); the default
    False generates the exact migration-free kernel, so non-consolidating
    replays pay nothing for the third event kind.
    """
    names = replay_carry_names(family)
    assert set(names) == set(carry), (names, sorted(carry))
    ev_i_names = ("kind", "item") + REPLAY_EV_I[family]
    ev_f_names = ("t", "pdep") + REPLAY_EV_F[family]
    f32, i32 = jnp.float32, jnp.int32
    L, T, dpad = ev_size.shape
    smem = ("sf", "si")

    def carry_spec(a):
        nd = a.ndim
        if nd == 2:
            return pl.BlockSpec((1,) + a.shape[1:], lambda b: (b, 0),
                                memory_space=pltpu.SMEM)
        return pl.BlockSpec((1,) + a.shape[1:], lambda b: (b, 0, 0))

    carr = [carry[nm] for nm in names]
    in_specs = [carry_spec(a) for a in carr]
    in_specs += [pl.BlockSpec((1, T), lambda b: (b, 0),
                              memory_space=pltpu.SMEM)
                 for _ in ev_i_names + ev_f_names]
    in_specs += [pl.BlockSpec((1, T, dpad), lambda b: (b, 0, 0)),
                 pl.BlockSpec((1, dpad), lambda b: (b, 0))]
    kernel = functools.partial(
        _replay_block_kernel, family=family, policy=policy, n=n, d=d, T=T,
        large_bins=large_bins, adaptive_alpha=adaptive_alpha,
        direct_sum=direct_sum, la_mode=la_mode, la_split=la_split, low=low,
        high=high, migrate=migrate, nc=len(names),
        ni=len(REPLAY_EV_I[family]), nf=len(REPLAY_EV_F[family]))
    outs = pl.pallas_call(
        kernel,
        grid=(L,),
        in_specs=in_specs,
        out_specs=[carry_spec(a) for a in carr],
        out_shape=[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in carr],
        input_output_aliases={idx: idx for idx, nm in enumerate(names)
                              if nm not in smem},
        interpret=interpret,
    )(*carr, *(ev_i[nm] for nm in ev_i_names),
      *(ev_f[nm] for nm in ev_f_names), ev_size, dmask)
    return dict(zip(names, outs))


def fitscore_replay_chunk(carry, ev_i, ev_f, ev_size, dmask, *,
                          block_events: int, **block_kwargs):
    """Chunk-boundary replay entry: ``lax.scan`` of
    :func:`fitscore_replay_block` over a fixed-geometry chunk of
    ``C = NB * block_events`` events - the unit of device work for both the
    event-blocked in-memory path (``core.jaxsim._replay_batch_blocked``)
    and the streamed replay (``repro.stream``), which threads the returned
    packed carry into the next chunk.

    ``ev_i`` / ``ev_f`` are dicts of (L, C) event streams, ``ev_size`` the
    (L, C, dpad) pre-gathered sizes; C must be a multiple of
    ``block_events`` (pad the tail with ``PAD_KIND`` no-ops - the carry
    passes through them, so padding never changes decisions).  Because the
    carry after any block equals the carry the per-event scan would hold at
    the same event index, a replay chunked at *any* block-aligned boundary
    is bit-identical to the unchunked one (tests/test_stream.py)."""
    T = int(block_events)
    L, C, _ = ev_size.shape
    assert T >= 1 and C % T == 0, (C, T)
    NB = C // T

    def blocks(a):
        return jnp.swapaxes(a.reshape((L, NB, T) + a.shape[2:]), 0, 1)

    xs = (jax.tree.map(blocks, ev_i), jax.tree.map(blocks, ev_f),
          blocks(ev_size))

    def step(c, ev):
        evi_b, evf_b, size_b = ev
        return fitscore_replay_block(c, evi_b, evf_b, size_b, dmask,
                                     **block_kwargs), None

    carry, _ = jax.lax.scan(step, carry, xs)
    return carry


def fitscore_select_batch(loads, counts, alive, open_seq, access_seq, closes,
                          size, pdep, now, dmask, cmask=None, *, policy: str,
                          bn: int = 256, interpret: bool = False):
    """Fused batched DVBP placement step over ``L`` independent lanes.

    loads: (L, N, d) per-slot load vectors; counts/alive/open_seq/access_seq/
    closes: (L, N) slot state; size: (L, d) arriving item; pdep/now: (L,)
    scalars; dmask: (L, d) real-dimension mask (1.0 real / 0.0 padding);
    cmask: optional (L, N) category mask (1 = category-compatible slot, see
    ``_select_kernel``; None = unrestricted).

    Returns ``(slot, found, no_free)``, each ``(L,)`` - the slot the policy
    places into (the best feasible bin, else the first free slot, else slot
    0 with ``no_free`` set), matching ``core.jaxsim._select_slot`` decision
    -for-decision.  Pads the state into kernel layout on every call; hot
    loops should hold their state pre-padded and call
    :func:`fitscore_select_batch_padded` instead.
    """
    L, N, d = loads.shape
    Np, dpad, bn_, nb = select_pad_geometry(N, d, bn)
    f32, i32 = jnp.float32, jnp.int32
    loads_p = jnp.zeros((L, Np, dpad), f32).at[:, :N, :d].set(
        loads.astype(f32))
    counts_p = jnp.zeros((L, Np), i32).at[:, :N].set(counts.astype(i32))
    alive_p = jnp.zeros((L, Np), i32).at[:, :N].set(alive.astype(i32))
    oseq_p = jnp.zeros((L, Np), i32).at[:, :N].set(open_seq.astype(i32))
    aseq_p = jnp.zeros((L, Np), i32).at[:, :N].set(access_seq.astype(i32))
    closes_p = jnp.zeros((L, Np), f32).at[:, :N].set(closes.astype(f32))
    size_p = jnp.zeros((L, dpad), f32).at[:, :d].set(size.astype(f32))
    dmask_p = jnp.zeros((L, dpad), f32).at[:, :d].set(dmask.astype(f32))
    cmask_p = None if cmask is None else \
        jnp.zeros((L, Np), i32).at[:, :N].set(cmask.astype(i32))
    return fitscore_select_batch_padded(
        loads_p, counts_p, alive_p, oseq_p, aseq_p, closes_p, size_p,
        pdep, now, dmask_p, cmask_p, policy=policy, n=N, bn=bn,
        interpret=interpret)
