"""DVBP placement Pallas TPU kernels - the paper's inner loop, fused.

At cloud scale an arrival must be scored against thousands of bin slots
x d resource dims: a bandwidth-bound stream over the loads matrix, ideal for
VMEM tiling.  Two kernels live here:

``fitscore`` (legacy scoring kernel)
    Tiles of 256 bins x d(pad 128) are scored per grid step: feasibility
    (all dims fit, ``EPS`` tolerance) + an l1/l2/linf fit score, and a
    running argmin in SMEM scratch emits the chosen bin directly.  Ties are
    broken by **opening order** (``open_seq``; defaults to slot index), the
    same rule the oracle engine applies when it walks open bins in opening
    order and takes the first minimum.

``fitscore_select_batch`` (the sweep scan's placement step)
    The full fused placement decision for a *batch of lanes*, covering the
    complete 8-policy score family of ``core.jaxsim`` (``SELECT_POLICIES``):
    feasibility, policy score, oracle-consistent (score, open_seq)
    lexicographic running argmin, the two-stage case-(a)/case-(b) select of
    ``nrt_prioritized``, and first-free-slot selection - one VMEM-tiled pass
    over a ``(lanes, bin-tiles)`` grid that emits the chosen slot per lane
    plus ``found`` / ``no_free`` flags.

    The optional *category mask* operand (``cmask``, (L, N) int32; 1 =
    eligible slot) restricts feasibility to category-compatible slots -
    how ``core.jaxsim`` replays the category-structured policy families
    (CBD/CBDT, Hybrid, RCP/PPE, Lifetime Alignment): their class-restricted
    First Fit / Best Fit stages are this same kernel with a mask computed
    from the carried per-slot category tags.

    ``fitscore_select_batch_padded`` is the hot-loop entry: the same
    decision for state already held in the kernel's padded (Np, dpad)
    layout (``select_pad_geometry``).  ``core.jaxsim._replay_batch`` keeps
    its whole scan carry in that layout and calls it once per event-scan
    step, so a whole sweep batch replays with zero host round-trips AND
    zero per-step re-padding (~25x redundant data traffic at d=5 before).

Constants ``SCORE_BIG`` / ``SCORE_NEG`` / ``F32_EPS`` / ``IBIG`` /
``SELECT_POLICIES`` are the single source of truth for the scoring
semantics; ``core.jaxsim`` and ``kernels.ops`` import them so the inline
jnp paths and the kernel can never drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EPS = 1e-9     # legacy fitscore tolerance (matches ref.fitscore_ref)
BIG = 3.0e38   # python float: baked into the legacy kernel as an immediate

NORMS = ("l1", "l2", "linf", "first_fit")

# --- shared scoring semantics (core.jaxsim imports these; do not fork) ----
SELECT_POLICIES = ("first_fit", "best_fit_l1", "best_fit_l2", "best_fit_linf",
                   "mru", "greedy", "nrt_standard", "nrt_prioritized")
SCORE_BIG = 1e30     # +BIG == infeasible slot
SCORE_NEG = -1e30    # closes sentinel for virgin/closed slots
F32_EPS = 1e-6       # fp32 capacity tolerance (oracle uses 1e-9/f64)
IBIG = 2 ** 30      # int sentinel for (open_seq, row) tie-break argmins


def _kernel(rem_ref, alive_ref, oseq_ref, item_ref, score_ref, best_ref,
            sseq_ref, *, norm: str, bn: int, nb: int, n: int, d: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        best_ref[0] = jnp.float32(BIG)
        best_ref[1] = jnp.float32(-1.0)
        sseq_ref[0] = jnp.int32(IBIG)

    rem = rem_ref[...].astype(jnp.float32)        # (bn, dpad)
    item = item_ref[...].astype(jnp.float32)      # (1, dpad)
    after = rem - item
    dmask = jax.lax.broadcasted_iota(jnp.int32, after.shape, 1) < d
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    oseq = oseq_ref[...]                          # (bn, 1) int32
    alive = (alive_ref[...] > 0) & (rows < n)
    feasible = jnp.all((after >= -EPS) | ~dmask, axis=1, keepdims=True) & alive

    masked = jnp.where(dmask, after, 0.0)
    if norm == "l1":
        score = jnp.sum(masked, axis=1, keepdims=True)
    elif norm == "l2":
        score = jnp.sqrt(jnp.sum(masked * masked, axis=1, keepdims=True))
    elif norm == "linf":
        score = jnp.max(jnp.where(dmask, after, -BIG), axis=1, keepdims=True)
    else:   # first_fit: prefer earliest-opened feasible bin
        score = oseq.astype(jnp.float32)
    score = jnp.where(feasible, score, BIG)
    score_ref[...] = score

    # (score, open_seq) lexicographic running argmin: the oracle walks open
    # bins in opening order and keeps the first minimum, so score ties must
    # fall to the earliest-opened bin - NOT the smallest slot index (a closed
    # slot reused later has a small index but a late open_seq).
    tile_best = jnp.min(score)
    tied_seq = jnp.where((score == tile_best) & feasible, oseq, IBIG)
    tile_seq = jnp.min(tied_seq)
    tied_row = jnp.where(tied_seq == tile_seq, rows, IBIG)
    tile_arg = jnp.min(tied_row)

    better = (tile_best < best_ref[0]) | \
        ((tile_best == best_ref[0]) & (tile_seq < sseq_ref[0]))

    @pl.when(better)
    def _upd():
        best_ref[0] = tile_best
        best_ref[1] = tile_arg.astype(jnp.float32)
        sseq_ref[0] = tile_seq


def fitscore(remaining, alive, item, open_seq=None, *, norm: str = "linf",
             bn: int = 256, interpret: bool = False):
    """remaining: (N,d); alive: (N,) bool/int; item: (d,); open_seq: (N,)
    opening-order keys for tie-breaking (defaults to the slot index).
    Returns (scores (N,), best_idx scalar int32, -1 if none feasible)."""
    assert norm in NORMS
    N, d = remaining.shape
    dpad = max(128, -(-d // 128) * 128)
    bn_ = min(bn, max(N, 8))
    nb = -(-N // bn_)
    rem_p = jnp.zeros((nb * bn_, dpad), remaining.dtype)
    rem_p = rem_p.at[:N, :d].set(remaining)
    alive_p = jnp.zeros((nb * bn_, 1), jnp.int32).at[:N, 0].set(
        alive.astype(jnp.int32))
    if open_seq is None:
        open_seq = jnp.arange(N, dtype=jnp.int32)
    oseq_p = jnp.full((nb * bn_, 1), IBIG, jnp.int32).at[:N, 0].set(
        open_seq.astype(jnp.int32))
    item_p = jnp.zeros((1, dpad), remaining.dtype).at[0, :d].set(item)

    kernel = functools.partial(_kernel, norm=norm, bn=bn_, nb=nb, n=N, d=d)
    scores, best = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn_, dpad), lambda i: (i, 0)),
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, dpad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * bn_, 1), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.float32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(rem_p, alive_p, oseq_p, item_p)
    scores = jnp.where(scores[:N, 0] >= BIG, jnp.inf, scores[:N, 0])
    best_idx = jnp.where(best[0] >= BIG, -1, best[1]).astype(jnp.int32)
    return scores, best_idx


# ======================================================================
# Fused batched placement-step kernel (all 8 jaxsim policies)
# ======================================================================

def _select_kernel(loads_ref, counts_ref, alive_ref, oseq_ref, aseq_ref,
                   closes_ref, size_ref, dmask_ref, cmask_ref, pdep_ref,
                   now_ref, out_ref, fbest, ibest, *, policy: str, bn: int,
                   nb: int, n: int):
    """One (lane, bin-tile) grid step of the fused placement decision.

    ``cmask_ref`` (1, bn) int32 is the *category mask*: 1 marks slots the
    policy's category structure allows for this arrival (same-tag bins for
    CBD/CBDT/Hybrid/RCP lanes, same-lifetime-class bins for Lifetime
    Alignment; all-ones for the plain score policies).  It is folded into
    feasibility before scoring, so a lane with no category-compatible
    feasible bin reports ``found=False`` and falls through to the free-slot
    stage - exactly the host classes' "open a new bin of my category"
    contract.

    SMEM scratch layout (running state for the current lane; grid iterates
    tiles innermost so it is reset at tile 0 and emitted at tile nb-1):
      fbest[0] best case-(a) score     fbest[1] best case-(b) score
      ibest[0] case-(a) open_seq       ibest[1] case-(a) slot
      ibest[2] case-(b) open_seq       ibest[3] case-(b) slot
      ibest[4] first free slot
    Case (b) is only maintained for ``nrt_prioritized`` (its strict
    case-(a)-before-case-(b) two-stage select); every other policy uses the
    case-(a) registers alone.
    """
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        fbest[0] = jnp.float32(SCORE_BIG)
        fbest[1] = jnp.float32(SCORE_BIG)
        ibest[0] = jnp.int32(IBIG)
        ibest[1] = jnp.int32(0)
        ibest[2] = jnp.int32(IBIG)
        ibest[3] = jnp.int32(0)
        ibest[4] = jnp.int32(IBIG)

    loads = loads_ref[...].astype(jnp.float32)    # (1, bn, dpad)
    size = size_ref[...].astype(jnp.float32)      # (1, dpad)
    dmask = dmask_ref[...].astype(jnp.float32)    # (1, dpad)
    counts = counts_ref[...]                      # (1, bn) int32
    oseq = oseq_ref[...]                          # (1, bn) int32
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    rowmask = rows < n
    alive = (alive_ref[...] > 0) & rowmask
    pdep = pdep_ref[0, 0]
    now = now_ref[0, 0]

    # feasibility - the exact jnp expression of core.jaxsim._score,
    # restricted to category-compatible slots
    feasible = jnp.all(size[:, None, :] <= 1.0 - loads + F32_EPS,
                       axis=2) & alive & (cmask_ref[...] > 0)   # (1, bn)

    if policy == "first_fit":
        s = oseq.astype(jnp.float32)
    elif policy == "mru":
        s = -aseq_ref[...].astype(jnp.float32)
    elif policy.startswith("best_fit"):
        after = 1.0 - loads - size[:, None, :]    # (1, bn, dpad)
        if policy.endswith("l1"):
            s = jnp.sum(after * dmask[:, None, :], axis=2)
        elif policy.endswith("l2"):
            masked = after * dmask[:, None, :]
            s = jnp.sqrt(jnp.sum(masked * masked, axis=2))
        else:
            s = jnp.max(jnp.where(dmask[:, None, :] > 0, after, SCORE_NEG),
                        axis=2)
    elif policy == "greedy":
        s = -jnp.maximum(closes_ref[...], now)
    elif policy == "nrt_standard":
        s = jnp.abs(jnp.maximum(closes_ref[...], now) - pdep)
    else:   # nrt_prioritized
        gap = jnp.maximum(closes_ref[...], now) - pdep
        sa = jnp.where(feasible & (gap >= 0), gap, SCORE_BIG)
        sb = jnp.where(feasible & (gap < 0), -gap, SCORE_BIG)

    def merge(score, f_slot: int, i_slot: int):
        """(score, open_seq) lexicographic running argmin over tiles."""
        tile_best = jnp.min(score)
        tied_seq = jnp.where((score == tile_best) & feasible, oseq, IBIG)
        tile_seq = jnp.min(tied_seq)
        tile_arg = jnp.min(jnp.where(tied_seq == tile_seq, rows, IBIG))
        better = (tile_best < fbest[f_slot]) | \
            ((tile_best == fbest[f_slot]) & (tile_seq < ibest[i_slot]))

        @pl.when(better)
        def _():
            fbest[f_slot] = tile_best
            ibest[i_slot] = tile_seq
            ibest[i_slot + 1] = tile_arg

    if policy == "nrt_prioritized":
        merge(sa, 0, 0)
        merge(sb, 1, 2)
    else:
        merge(jnp.where(feasible, s, SCORE_BIG), 0, 0)

    tile_free = jnp.min(jnp.where((counts == 0) & rowmask, rows, IBIG))
    ibest[4] = jnp.minimum(ibest[4], tile_free)

    @pl.when(i == nb - 1)
    def _emit():
        found_a = fbest[0] < SCORE_BIG
        if policy == "nrt_prioritized":
            found = found_a | (fbest[1] < SCORE_BIG)
            best = jnp.where(found_a, ibest[1], ibest[3])
        else:
            found = found_a
            best = ibest[1]
        no_free = ibest[4] >= IBIG
        free = jnp.where(no_free, 0, ibest[4])   # argmin-of-empty == 0 (jnp)
        out_ref[b, 0] = jnp.where(found, best, free)
        out_ref[b, 1] = found.astype(jnp.int32)
        out_ref[b, 2] = no_free.astype(jnp.int32)


def select_pad_geometry(n: int, d: int, bn: int = 256):
    """Kernel layout for an ``n``-slot, ``d``-dim pool: (Np, dpad, bn, nb).
    Shared with ``core.jaxsim`` so the scan carry can live pre-padded."""
    dpad = max(128, -(-d // 128) * 128)
    bn_ = min(bn, max(n, 8))
    nb = -(-n // bn_)
    return nb * bn_, dpad, bn_, nb


def fitscore_select_batch_padded(loads, counts, alive, open_seq, access_seq,
                                 closes, size, pdep, now, dmask, cmask=None,
                                 *, policy: str, n: int, bn: int = 256,
                                 interpret: bool = False):
    """``fitscore_select_batch`` for state already in kernel layout.

    Arguments are pre-padded per :func:`select_pad_geometry`: loads
    (L, Np, dpad); counts/alive/open_seq/access_seq/closes and the optional
    category mask ``cmask`` (L, Np); size/dmask (L, dpad); pdep/now (L,).
    ``n`` is the real slot-pool size (rows >= n are layout padding and are
    excluded from both the feasible and the free-slot stage).

    This is the replay scan's entry: ``core.jaxsim._replay_batch`` keeps its
    whole carry in this layout, so each step reads/writes the state the
    kernel consumes directly instead of re-padding (Np x dpad) every event
    (~25x redundant traffic at d=5).
    """
    assert policy in SELECT_POLICIES, policy
    L, Np, dpad = loads.shape
    Np_, dpad_, bn_, nb = select_pad_geometry(n, 1, bn)
    assert Np == Np_ and dpad % 128 == 0, (loads.shape, n, bn)
    f32, i32 = jnp.float32, jnp.int32
    if cmask is None:
        cmask = jnp.ones((L, Np), i32)
    kernel = functools.partial(_select_kernel, policy=policy, bn=bn_, nb=nb,
                               n=n)
    out = pl.pallas_call(
        kernel,
        grid=(L, nb),
        in_specs=[
            pl.BlockSpec((1, bn_, dpad), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, dpad), lambda b, i: (b, 0)),
            pl.BlockSpec((1, dpad), lambda b, i: (b, 0)),
            pl.BlockSpec((1, bn_), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((L, 3), jnp.int32),
        scratch_shapes=[pltpu.SMEM((2,), jnp.float32),
                        pltpu.SMEM((8,), jnp.int32)],
        interpret=interpret,
    )(loads.astype(f32), counts.astype(i32), alive.astype(i32),
      open_seq.astype(i32), access_seq.astype(i32), closes.astype(f32),
      size.astype(f32), dmask.astype(f32), cmask.astype(i32),
      pdep.astype(f32).reshape(L, 1), now.astype(f32).reshape(L, 1))
    return out[:, 0], out[:, 1] > 0, out[:, 2] > 0


def fitscore_select_batch(loads, counts, alive, open_seq, access_seq, closes,
                          size, pdep, now, dmask, cmask=None, *, policy: str,
                          bn: int = 256, interpret: bool = False):
    """Fused batched DVBP placement step over ``L`` independent lanes.

    loads: (L, N, d) per-slot load vectors; counts/alive/open_seq/access_seq/
    closes: (L, N) slot state; size: (L, d) arriving item; pdep/now: (L,)
    scalars; dmask: (L, d) real-dimension mask (1.0 real / 0.0 padding);
    cmask: optional (L, N) category mask (1 = category-compatible slot, see
    ``_select_kernel``; None = unrestricted).

    Returns ``(slot, found, no_free)``, each ``(L,)`` - the slot the policy
    places into (the best feasible bin, else the first free slot, else slot
    0 with ``no_free`` set), matching ``core.jaxsim._select_slot`` decision
    -for-decision.  Pads the state into kernel layout on every call; hot
    loops should hold their state pre-padded and call
    :func:`fitscore_select_batch_padded` instead.
    """
    L, N, d = loads.shape
    Np, dpad, bn_, nb = select_pad_geometry(N, d, bn)
    f32, i32 = jnp.float32, jnp.int32
    loads_p = jnp.zeros((L, Np, dpad), f32).at[:, :N, :d].set(
        loads.astype(f32))
    counts_p = jnp.zeros((L, Np), i32).at[:, :N].set(counts.astype(i32))
    alive_p = jnp.zeros((L, Np), i32).at[:, :N].set(alive.astype(i32))
    oseq_p = jnp.zeros((L, Np), i32).at[:, :N].set(open_seq.astype(i32))
    aseq_p = jnp.zeros((L, Np), i32).at[:, :N].set(access_seq.astype(i32))
    closes_p = jnp.zeros((L, Np), f32).at[:, :N].set(closes.astype(f32))
    size_p = jnp.zeros((L, dpad), f32).at[:, :d].set(size.astype(f32))
    dmask_p = jnp.zeros((L, dpad), f32).at[:, :d].set(dmask.astype(f32))
    cmask_p = None if cmask is None else \
        jnp.zeros((L, Np), i32).at[:, :N].set(cmask.astype(i32))
    return fitscore_select_batch_padded(
        loads_p, counts_p, alive_p, oseq_p, aseq_p, closes_p, size_p,
        pdep, now, dmask_p, cmask_p, policy=policy, n=N, bn=bn,
        interpret=interpret)
