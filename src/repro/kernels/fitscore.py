"""DVBP placement scoring Pallas TPU kernel - the paper's inner loop.

At cloud scale an arrival must be scored against thousands of open bins
x d resource dims: a bandwidth-bound stream over the bins matrix, ideal for
VMEM tiling.  Tiles of 256 bins x d(pad 128) are scored per grid step:
feasibility (all dims fit, with the engine's EPS tolerance) + an l1/l2/linf
fit score, and a running argmin is kept in SMEM scratch so the kernel emits
the chosen bin directly (the Best-Fit/First-Fit decision, fused).

Scores are +inf for infeasible bins.  First Fit == argmin over open-order
index among feasible, realized by score = bin order index.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EPS = 1e-9
BIG = 3.0e38   # python float: baked into the kernel as an immediate

NORMS = ("l1", "l2", "linf", "first_fit")


def _kernel(rem_ref, alive_ref, item_ref, score_ref, best_ref, *,
            norm: str, bn: int, nb: int, n: int, d: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        best_ref[0] = jnp.float32(BIG)
        best_ref[1] = jnp.float32(-1.0)

    rem = rem_ref[...].astype(jnp.float32)        # (bn, dpad)
    item = item_ref[...].astype(jnp.float32)      # (1, dpad)
    after = rem - item
    dmask = jax.lax.broadcasted_iota(jnp.int32, after.shape, 1) < d
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    alive = (alive_ref[...] > 0) & (rows < n)
    feasible = jnp.all((after >= -EPS) | ~dmask, axis=1, keepdims=True) & alive

    masked = jnp.where(dmask, after, 0.0)
    if norm == "l1":
        score = jnp.sum(masked, axis=1, keepdims=True)
    elif norm == "l2":
        score = jnp.sqrt(jnp.sum(masked * masked, axis=1, keepdims=True))
    elif norm == "linf":
        score = jnp.max(jnp.where(dmask, after, -BIG), axis=1, keepdims=True)
    else:   # first_fit: prefer earliest-opened feasible bin
        score = rows.astype(jnp.float32)
    score = jnp.where(feasible, score, BIG)
    score_ref[...] = score

    tile_best = jnp.min(score)
    tile_arg = jnp.argmin(score[:, 0])

    @pl.when(tile_best < best_ref[0])
    def _upd():
        best_ref[0] = tile_best
        best_ref[1] = (i * bn + tile_arg).astype(jnp.float32)


def fitscore(remaining, alive, item, *, norm: str = "linf", bn: int = 256,
             interpret: bool = False):
    """remaining: (N,d); alive: (N,) bool/int; item: (d,).
    Returns (scores (N,), best_idx scalar int32, -1 if none feasible)."""
    assert norm in NORMS
    N, d = remaining.shape
    dpad = max(128, -(-d // 128) * 128)
    bn_ = min(bn, max(N, 8))
    nb = -(-N // bn_)
    rem_p = jnp.zeros((nb * bn_, dpad), remaining.dtype)
    rem_p = rem_p.at[:N, :d].set(remaining)
    alive_p = jnp.zeros((nb * bn_, 1), jnp.int32).at[:N, 0].set(
        alive.astype(jnp.int32))
    item_p = jnp.zeros((1, dpad), remaining.dtype).at[0, :d].set(item)

    kernel = functools.partial(_kernel, norm=norm, bn=bn_, nb=nb, n=N, d=d)
    scores, best = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn_, dpad), lambda i: (i, 0)),
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, dpad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * bn_, 1), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.float32),
        ],
        scratch_shapes=[],
        interpret=interpret,
    )(rem_p, alive_p, item_p)
    scores = jnp.where(scores[:N, 0] >= BIG, jnp.inf, scores[:N, 0])
    best_idx = jnp.where(best[0] >= BIG, -1, best[1]).astype(jnp.int32)
    return scores, best_idx
