"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Deliberately the simplest correct implementations: full score-matrix softmax
for attention, a sequential lax.scan over time for RWKV6 - no chunking, no
blocking, no numerical tricks beyond fp32 softmax.

``fitscore_ref`` scores only; the (score, opening-order) tie-break and
free-slot selection that complete the placement decision live in
``kernels.ops.fitscore`` / ``core.jaxsim._select_slot`` (and fused in the
``kernels.fitscore`` Pallas kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd); returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= qpos - kpos < window
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len, *, scale=None):
    """q: (B,H,hd) one token; k,v: (B,S,KV,hd); kv_len: (B,) valid lengths."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] < kv_len[:, None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def rwkv6_ref(r, k, v, logw, u, *, initial_state=None):
    """Sequential RWKV6: r,k,logw (B,S,H,K); v (B,S,H,V); u (H,K).
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k v."""
    B, S, H, K = k.shape
    V = v.shape[-1]
    f32 = lambda x: x.astype(jnp.float32)
    r, k, v, logw = f32(r), f32(k), f32(v), f32(logw)
    state = jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None \
        else f32(initial_state)

    def step(s, xs):
        rt, kt, vt, lw = xs
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s) \
            + jnp.einsum("bhk,hk->bh", rt * kt, f32(u))[..., None] * vt
        s = jnp.exp(lw)[..., None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, logw))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def fitscore_ref(remaining, alive, item, *, norm="linf", eps=1e-9):
    """DVBP placement scoring (the paper's inner loop, vectorized).

    remaining: (N,d) available capacity per bin; alive: (N,) bool;
    item: (d,).  Returns (scores (N,) with +inf where infeasible, feasible
    mask (N,)).  Score = l_p norm of capacity left after placement."""
    rem_after = remaining - item[None, :]
    feasible = jnp.all(rem_after >= -eps, axis=1) & alive
    if norm == "l1":
        score = rem_after.sum(axis=1)
    elif norm == "l2":
        score = jnp.sqrt(jnp.sum(rem_after * rem_after, axis=1))
    else:
        score = rem_after.max(axis=1)
    return jnp.where(feasible, score, jnp.inf), feasible
