"""Jit'd public wrappers with backend dispatch for every Pallas kernel.

On TPU backends the Pallas kernels run natively; elsewhere the pure-jnp
references (ref.py) run so the whole framework works identically on CPU
(dry-run, tests).  ``impl="pallas_interpret"`` forces the kernel body in
interpret mode (the correctness harness used by tests/test_kernels.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .fitscore import IBIG
from .fitscore import fitscore as _fitscore_pallas
from .flash_attention import flash_attention as _flash_pallas
from .rwkv6_scan import rwkv6_chunked as _rwkv6_pallas
from ..resilience import faults


def _use_pallas(impl: str) -> bool:
    if impl == "pallas":
        return True
    if impl == "auto":
        return jax.default_backend() == "tpu"
    return False


def resolved_select_impl(impl: str, block: bool = False) -> str:
    """The engine that will *actually* serve a ``fitscore_select`` /
    ``fitscore_select_block`` call with this ``impl`` argument: "pallas"
    (native kernel), "pallas_interpret" (kernel body interpreted) or "jnp"
    (the ``_select_slot`` twin).  ``impl="auto"`` silently falls back to
    jnp off-TPU - and the blocked select has no jnp twin, so it runs the
    kernel in interpret mode instead.  Surfacing the resolved name (the
    serving scheduler's span backend tag, ``obs`` counter suffix) makes
    that fallback visible instead of just slow."""
    if _use_pallas(impl):
        return "pallas"
    if impl == "pallas_interpret" or block:
        return "pallas_interpret"
    return "jnp"


@partial(jax.jit, static_argnames=("causal", "window", "impl"))
def flash_attention(q, k, v, *, causal=True, window=0, impl="auto"):
    if _use_pallas(impl):
        return _flash_pallas(q, k, v, causal=causal, window=window)
    if impl == "pallas_interpret":
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=True)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("impl",))
def decode_attention(q, k, v, kv_len, *, impl="auto"):
    if _use_pallas(impl):
        return _decode_pallas(q, k, v, kv_len)
    if impl == "pallas_interpret":
        return _decode_pallas(q, k, v, kv_len, interpret=True)
    return ref.decode_attention_ref(q, k, v, kv_len)


@partial(jax.jit, static_argnames=("chunk", "impl"))
def rwkv6(r, k, v, logw, u, *, chunk=16, impl="auto"):
    if _use_pallas(impl):
        return _rwkv6_pallas(r, k, v, logw, u, chunk=chunk)
    if impl == "pallas_interpret":
        return _rwkv6_pallas(r, k, v, logw, u, chunk=chunk, interpret=True)
    return ref.rwkv6_ref(r, k, v, jnp.clip(logw, -4.0, 0.0), u)


@partial(jax.jit, static_argnames=("norm", "impl"))
def fitscore(remaining, alive, item, open_seq=None, *, norm="linf",
             impl="auto"):
    """Scores + chosen bin.  Ties break by ``open_seq`` (opening order, the
    oracle's rule); ``open_seq=None`` means slot index == opening order."""
    if _use_pallas(impl):
        return _fitscore_pallas(remaining, alive, item, open_seq, norm=norm)
    if impl == "pallas_interpret":
        return _fitscore_pallas(remaining, alive, item, open_seq, norm=norm,
                                interpret=True)
    n = remaining.shape[0]
    if open_seq is None:
        open_seq = jnp.arange(n, dtype=jnp.int32)
    if norm == "first_fit":
        feasible = jnp.all(remaining - item[None, :] >= -1e-9, axis=1) & \
            (alive > 0)
        scores = jnp.where(feasible, open_seq.astype(jnp.float32), jnp.inf)
    else:
        scores, feasible = ref.fitscore_ref(remaining, alive > 0, item,
                                            norm=norm)
    tie = scores <= jnp.min(scores)
    best = jnp.argmin(jnp.where(tie, open_seq.astype(jnp.int32),
                                jnp.int32(IBIG)))
    best = jnp.where(jnp.isinf(scores).all(), -1, best)
    return scores, best.astype(jnp.int32)


def fitscore_select(loads, counts, alive, open_seq, access_seq, closes,
                    size, pdep, now, dmask=None, cmask=None, *, policy,
                    impl="auto"):
    """Host wrapper over the jitted select: crosses the ``kernel.select``
    fault seam, then dispatches.  The seam must sit *outside* the jit - a
    seam inside a traced body would fire once at trace time and never
    again (see ``resilience.faults``)."""
    faults.fire("kernel.select")
    return _fitscore_select_jit(
        loads, counts, alive, open_seq, access_seq, closes, size, pdep,
        now, dmask, cmask, policy=policy, impl=impl)


@partial(jax.jit, static_argnames=("policy", "impl"))
def _fitscore_select_jit(loads, counts, alive, open_seq, access_seq, closes,
                         size, pdep, now, dmask=None, cmask=None, *, policy,
                         impl="auto"):
    """Fused single-state placement decision over the full 8-policy family
    (``core.jaxsim.POLICIES``): loads (N,d), counts/alive/open_seq/
    access_seq/closes (N,), size (d,), pdep/now scalars.  ``cmask`` (N,)
    optionally restricts the decision to category-compatible slots (1 =
    eligible) - how the category-structured policies (CBD/CBDT, ...) route
    their First Fit stage through the same kernel.  Returns
    (slot, found, no_free); the serving scheduler's on-device select."""
    from ..core.jaxsim import _select_slot   # leaf-safe: jaxsim -> fitscore
    from .fitscore import fitscore_select_batch
    if dmask is None:
        dmask = jnp.ones_like(size)
    if _use_pallas(impl) or impl == "pallas_interpret":
        slot, found, no_free = fitscore_select_batch(
            loads[None], counts[None], alive[None], open_seq[None],
            access_seq[None], closes[None], size[None],
            jnp.asarray(pdep, jnp.float32).reshape(1),
            jnp.asarray(now, jnp.float32).reshape(1), dmask[None],
            None if cmask is None else cmask[None],
            policy=policy, interpret=(impl == "pallas_interpret"))
        return slot[0], found[0], no_free[0]
    return _select_slot(policy, loads, counts, alive, open_seq, access_seq,
                        closes, size, pdep, now, dmask, cmask)


def fitscore_select_block(loads, alive, open_seq, access_seq, closes, size,
                          pdep, now, cat=None, tags=None, *, policy, n, d,
                          impl="auto"):
    """Host wrapper over the jitted blocked select: crosses the
    ``kernel.select_block`` fault seam, then dispatches (seam outside the
    jit, same as ``fitscore_select``)."""
    faults.fire("kernel.select_block")
    return _fitscore_select_block_jit(
        loads, alive, open_seq, access_seq, closes, size, pdep, now, cat,
        tags, policy=policy, n=n, d=d, impl=impl)


def fitscore_replay_dispatch(carry, ev_i, ev_f, ev_size, dmask, *, policy,
                             n, d, impl="auto", migrate=False):
    """Host wrapper over the jitted block dispatch: crosses the
    ``kernel.dispatch_block`` fault seam, then dispatches (seam outside
    the jit, same as the other select wrappers).  ``migrate=True``
    compiles the MIGRATE branch in (consolidation drain blocks); plain
    arrival/departure blocks keep the exact non-migrating graph."""
    faults.fire("kernel.dispatch_block")
    return _fitscore_replay_dispatch_jit(
        carry, ev_i, ev_f, ev_size, dmask, policy=policy, n=n, d=d,
        impl=impl, migrate=migrate)


@partial(jax.jit, static_argnames=("policy", "n", "d", "impl", "migrate"))
def _fitscore_replay_dispatch_jit(carry, ev_i, ev_f, ev_size, dmask, *,
                                  policy, n, d, impl="auto",
                                  migrate=False):
    """One T-event block of a *live* replay: the serving front end's batch
    of pending arrivals (plus fired departures, plus ``PAD_KIND`` filler up
    to the fixed block geometry) replayed against a persistent single-lane
    carry (``core.jaxsim.make_live_carry``) by the event-blocked megakernel
    - the whole block placed in a single on-chip pass, carry aliased
    in -> out exactly as in the sweep scan.

    ``policy`` is any scan policy whose family has a live-carry form
    (score / cbd / cbdt / rcp / la / adaptive); the ``PolicySpec`` knobs
    resolve here so the dispatcher passes one name, not nine flags.  The
    jit cache is keyed on (policy, n, d, impl) and the event shapes, so a
    fixed set of T geometries keeps the trace count bounded
    (``dispatch_trace_count`` is the monitored invariant).  Returns the
    post-block carry; placements read back from
    ``itemi[..., ITEMI_PLACE]``, overflow from ``si[..., SI_OVERFLOW]``.
    """
    from ..core.jaxsim import _KERNEL_FAMILY, policy_spec   # leaf-safe
    from ..core.algorithms.learned import LA_BINARY_SPLIT
    from .fitscore import fitscore_replay_block
    spec = policy_spec(policy)
    fam = _KERNEL_FAMILY[spec.family]
    return fitscore_replay_block(
        carry, ev_i, ev_f, ev_size, dmask, family=fam,
        policy=policy if fam == "score" else "first_fit", n=n, d=d,
        large_bins=spec.large_bins, adaptive_alpha=spec.adaptive_alpha,
        direct_sum=spec.direct_sum, la_mode=spec.la_mode,
        la_split=LA_BINARY_SPLIT, low=spec.low, high=spec.high,
        migrate=migrate, interpret=not _use_pallas(impl))


def dispatch_trace_count() -> int:
    """Jit-cache entry count of the block-dispatch entry point - the
    serving retrace invariant (mirrors ``sweep.runner``'s
    ``_jit_cache_entries``): after warming the fixed T geometries, mixed
    batch sizes must be pure cache hits."""
    return _fitscore_replay_dispatch_jit._cache_size()


@partial(jax.jit, static_argnames=("policy", "n", "d", "impl"))
def _fitscore_select_block_jit(loads, alive, open_seq, access_seq, closes,
                               size, pdep, now, cat=None, tags=None, *,
                               policy, n, d, impl="auto"):
    """One placement decision through the event-blocked replay megakernel
    at T=1 (``kernels.fitscore.fitscore_replay_block``): a single-lane
    carry holding the pool state replays one arrival event and the chosen
    slot is read back from the committed placement.

    ``loads`` (n, d) absolute per-replica loads; ``alive``/``open_seq``/
    ``access_seq``/``closes`` (n,); ``size`` (d,); ``pdep``/``now``
    scalars.  ``cat``+``tags`` (the request's CBD/CBDT class and the
    per-replica class tags) switch the kernel into its class-restricted
    First Fit family - the same masked select the batched replay runs.
    The pool's free-slot stage is disabled (the serving pool uses absolute,
    never-reused bin indices), so the result is (slot, found): found=False
    means "open a new replica", exactly the host algorithms' contract.
    """
    from .fitscore import (ITEMI_PLACE, SI_OPENED, SLOTF_CLOSES, SLOTI_ALIVE,
                           SLOTI_ASEQ, SLOTI_COUNTS, SLOTI_OSEQ, SLOTI_TAG,
                           ARRIVAL_KIND, KCAT, SCORE_NEG,
                           fitscore_replay_block, replay_carry_names,
                           select_pad_geometry)
    from .fitscore import ITEMI_COLS, SF_COLS, SI_COLS, SLOTF_COLS, SLOTI_COLS
    f32, i32 = jnp.float32, jnp.int32
    Np, dpad, _, _ = select_pad_geometry(n, d)
    family = "score" if cat is None else "cbd"
    sloti = jnp.zeros((1, Np, SLOTI_COLS), i32)
    sloti = sloti.at[0, :n, SLOTI_COUNTS].set(1)   # no free slots: the pool
    #                                                opens bins itself
    sloti = sloti.at[0, :n, SLOTI_ALIVE].set(alive.astype(i32))
    sloti = sloti.at[0, :n, SLOTI_OSEQ].set(open_seq.astype(i32))
    sloti = sloti.at[0, :n, SLOTI_ASEQ].set(access_seq.astype(i32))
    if tags is not None:
        sloti = sloti.at[0, :n, SLOTI_TAG].set(tags.astype(i32))
    carry = {
        "loads": jnp.zeros((1, Np, dpad), f32).at[0, :n, :d].set(
            loads.astype(f32)),
        "slotf": jnp.full((1, Np, SLOTF_COLS), 0.0, f32)
        .at[0, :, SLOTF_CLOSES].set(SCORE_NEG)
        .at[0, :n, SLOTF_CLOSES].set(closes.astype(f32)),
        "sloti": sloti,
        "itemi": jnp.full((1, 1, ITEMI_COLS), -1, i32),
        "sf": jnp.zeros((1, SF_COLS), f32),
        "si": jnp.zeros((1, SI_COLS), i32),
    }
    ev_i = {"kind": jnp.full((1, 1), ARRIVAL_KIND, i32),
            "item": jnp.zeros((1, 1), i32)}
    if cat is not None:
        ev_i["cat"] = jnp.asarray(cat, i32).reshape(1, 1)
    ev_f = {"t": jnp.asarray(now, f32).reshape(1, 1),
            "pdep": jnp.asarray(pdep, f32).reshape(1, 1)}
    ev_size = jnp.zeros((1, 1, dpad), f32).at[0, 0, :d].set(
        size.astype(f32))
    dmask = jnp.zeros((1, dpad), f32).at[0, :d].set(1.0)
    out = fitscore_replay_block(
        carry, ev_i, ev_f, ev_size, dmask, family=family,
        policy=policy if family == "score" else "first_fit", n=n, d=d,
        interpret=not _use_pallas(impl))
    slot = out["itemi"][0, 0, ITEMI_PLACE]
    found = out["si"][0, SI_OPENED] == 0
    return slot, found
