"""Jit'd public wrappers with backend dispatch for every Pallas kernel.

On TPU backends the Pallas kernels run natively; elsewhere the pure-jnp
references (ref.py) run so the whole framework works identically on CPU
(dry-run, tests).  ``impl="pallas_interpret"`` forces the kernel body in
interpret mode (the correctness harness used by tests/test_kernels.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .fitscore import fitscore as _fitscore_pallas
from .flash_attention import flash_attention as _flash_pallas
from .rwkv6_scan import rwkv6_chunked as _rwkv6_pallas


def _use_pallas(impl: str) -> bool:
    if impl == "pallas":
        return True
    if impl == "auto":
        return jax.default_backend() == "tpu"
    return False


@partial(jax.jit, static_argnames=("causal", "window", "impl"))
def flash_attention(q, k, v, *, causal=True, window=0, impl="auto"):
    if _use_pallas(impl):
        return _flash_pallas(q, k, v, causal=causal, window=window)
    if impl == "pallas_interpret":
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=True)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("impl",))
def decode_attention(q, k, v, kv_len, *, impl="auto"):
    if _use_pallas(impl):
        return _decode_pallas(q, k, v, kv_len)
    if impl == "pallas_interpret":
        return _decode_pallas(q, k, v, kv_len, interpret=True)
    return ref.decode_attention_ref(q, k, v, kv_len)


@partial(jax.jit, static_argnames=("chunk", "impl"))
def rwkv6(r, k, v, logw, u, *, chunk=16, impl="auto"):
    if _use_pallas(impl):
        return _rwkv6_pallas(r, k, v, logw, u, chunk=chunk)
    if impl == "pallas_interpret":
        return _rwkv6_pallas(r, k, v, logw, u, chunk=chunk, interpret=True)
    return ref.rwkv6_ref(r, k, v, jnp.clip(logw, -4.0, 0.0), u)


@partial(jax.jit, static_argnames=("norm", "impl"))
def fitscore(remaining, alive, item, *, norm="linf", impl="auto"):
    if _use_pallas(impl):
        return _fitscore_pallas(remaining, alive, item, norm=norm)
    if impl == "pallas_interpret":
        return _fitscore_pallas(remaining, alive, item, norm=norm,
                                interpret=True)
    if norm == "first_fit":
        n = remaining.shape[0]
        feasible = jnp.all(remaining - item[None, :] >= -1e-9, axis=1) & \
            (alive > 0)
        scores = jnp.where(feasible, jnp.arange(n, dtype=jnp.float32),
                           jnp.inf)
    else:
        scores, feasible = ref.fitscore_ref(remaining, alive > 0, item,
                                            norm=norm)
    best = jnp.where(jnp.isinf(scores).all(), -1, jnp.argmin(scores))
    return scores, best.astype(jnp.int32)
