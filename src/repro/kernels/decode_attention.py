"""Single-token GQA decode attention Pallas TPU kernel.

Decode attention is HBM-bandwidth-bound: the whole KV cache streams through
VMEM once per step.  Grid (B, KV, nS) with the cache-length axis innermost;
the G query heads that share one KV head form the row dim of the MXU tiles
(rows = G, a natural fit for GQA).  Running softmax in fp32 VMEM scratch,
kv_len masking for partially-filled caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, bs: int, ns: int, skv: int):
    b, si = pl.program_id(0), pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (bs, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = kpos < jnp.minimum(len_ref[b], skv)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    v = jnp.where(valid.reshape(bs, 1), v_ref[0, 0].astype(jnp.float32), 0.0)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(si == ns - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_len, *, scale=None, bs: int = 512,
                     interpret: bool = False):
    """q: (B,H,hd) one new token; k,v: (B,S,KV,hd); kv_len: (B,) int32.
    Returns (B,H,hd)."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    bs_ = min(bs, S)
    ns = -(-S // bs_)
    qg = q.reshape(B, KV, G, hd)
    kt = k.transpose(0, 2, 1, 3)      # (B,KV,S,hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, bs=bs_, ns=ns, skv=S)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # kv_len (B,)
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs_, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bs_, hd), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, qg, kt, vt)
    return out.reshape(B, H, hd)
