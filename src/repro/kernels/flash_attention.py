"""Blockwise (flash) attention Pallas TPU kernel.

Grid (B, H, nQ, nK) with the KV axis innermost ("arbitrary" semantics so the
running-softmax scratch carries across KV steps).  Q/K/V tiles live in VMEM
via BlockSpecs; accumulation in fp32 scratch; one output tile written on the
last KV step.  Supports causal + sliding-window masks and GQA (the K/V block
index maps q-head -> kv-head).

VMEM working set per step (bq=bk=128, hd<=256, fp32 acc):
  q(128x256x2) + k,v(2x128x256x2) + acc(128x256x4) + p(128x128x4) ~ 0.5 MiB,
comfortably under the ~16 MiB VMEM budget; MXU dims are 128-multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int, sq: int, skv: int):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (qpos < sq) & (kpos < skv)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0, 0].astype(jnp.float32)           # (bk, hd)
    # zero OOB-padded V rows: p is 0 there but 0 * garbage may be NaN
    kvalid = (ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)) < skv
    v = jnp.where(kvalid, v, 0.0)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    bq_ = min(bq, max(Sq, 8))
    bk_ = min(bk, max(Skv, 8))
    nq = -(-Sq // bq_)
    nk = -(-Skv // bk_)
    # head-major layout for clean (bq, hd) tiles
    qt = q.transpose(0, 2, 1, 3)     # (B,H,Sq,hd)
    kt = k.transpose(0, 2, 1, 3)     # (B,KV,Skv,hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq_, bk=bk_, nk=nk,
                               sq=Sq, skv=Skv)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk_, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk_, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            _vmem((bq_, hd)),   # fp32 output accumulator
            _vmem((bq_, 1)),    # running max
            _vmem((bq_, 1)),    # running denominator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
