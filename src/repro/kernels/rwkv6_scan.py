"""RWKV6 chunked linear-attention Pallas TPU kernel.

Grid (B*H, N chunks) with the chunk axis innermost and "arbitrary" semantics:
the (K,V) recurrent state lives in fp32 VMEM scratch and carries across chunk
steps.  Per chunk: two MXU matmuls for the pairwise intra-chunk term (with
the factorized decay trick from models/linear_scan.py), one matmul against
the carried state, one state update.  Per-step log-decay is clamped at
LOG_DECAY_MIN so the factorized exponentials stay in fp32 range for L<=16.

VMEM per step (L=16, K=V=64): r,k,v,lw tiles 4x16x64x4B + state 64x64x4
+ pair matrix 16x16x4 ~ 33 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LOG_DECAY_MIN = -4.0


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, state_out_ref,
            state_ref, *, L: int, nk: int):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0].astype(jnp.float32)       # (L, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)       # (L, V)
    lw = jnp.clip(lw_ref[0, 0].astype(jnp.float32), LOG_DECAY_MIN, 0.0)
    u = u_ref[0].astype(jnp.float32)          # (1, K)

    cum = jnp.cumsum(lw, axis=0)              # inclusive (L, K)
    cum_exc = cum - lw                        # exclusive
    tot = cum[-1:, :]                         # (1, K)

    r_dec = r * jnp.exp(cum_exc)              # query side (pre-update)
    k_idec = k * jnp.exp(-cum)
    # pairwise A[i,j] = sum_k r_i e^{cum_exc_i} * k_j e^{-cum_j},  j < i
    A = jax.lax.dot_general(r_dec, k_idec, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    A = jnp.where(j_idx < i_idx, A, 0.0)
    y = jax.lax.dot(A, v, preferred_element_type=jnp.float32)
    # cross-chunk: r_dec @ state ; bonus diagonal with u
    y += jax.lax.dot(r_dec, state_ref[...],
                     preferred_element_type=jnp.float32)
    y += jnp.sum(r * u * k, axis=1, keepdims=True) * v
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S = diag(e^{tot}) S + sum_j (k_j e^{tot-cum_j}) v_j^T
    k_dec = k * jnp.exp(tot - cum)
    state_ref[...] = jnp.exp(tot).T * state_ref[...] + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(n == nk - 1)
    def _emit():
        state_out_ref[0] = state_ref[...]


def rwkv6_chunked(r, k, v, logw, u, *, chunk: int = 16,
                  interpret: bool = False):
    """r,k,logw: (B,S,H,K); v: (B,S,H,V); u: (H,K).
    Returns (y (B,S,H,V) fp32, final_state (B,H,K,V) fp32)."""
    B, S, H, K = k.shape
    V = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    N = S // L
    # (B*H, N, L, feat) layout
    def lay(x, F):
        return x.transpose(0, 2, 1, 3).reshape(B * H, N, L, F)
    rt, kt, lt = lay(r, K), lay(k, K), lay(logw, K)
    vt = lay(v, V)
    ut = jnp.broadcast_to(u[None, :, None, :], (B, H, 1, K)).reshape(
        B * H, 1, K)

    kernel = functools.partial(_kernel, L=L, nk=N)
    y, state = pl.pallas_call(
        kernel,
        grid=(B * H, N),
        in_specs=[
            pl.BlockSpec((1, 1, L, K), lambda b, n: (b, n, 0, 0)),
            pl.BlockSpec((1, 1, L, K), lambda b, n: (b, n, 0, 0)),
            pl.BlockSpec((1, 1, L, V), lambda b, n: (b, n, 0, 0)),
            pl.BlockSpec((1, 1, L, K), lambda b, n: (b, n, 0, 0)),
            pl.BlockSpec((1, 1, K), lambda b, n: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, V), lambda b, n: (b, n, 0, 0)),
            pl.BlockSpec((1, K, V), lambda b, n: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, N, L, V), jnp.float32),
            jax.ShapeDtypeStruct((B * H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, lt, ut)
    y = y.reshape(B, H, S, V).transpose(0, 2, 1, 3)
    return y, state.reshape(B, H, K, V)
