"""ConsolidationSpec - the consolidation scenario knobs.

A frozen value object so it can ride inside ``SweepSpec`` / ``Setting``
and enter the sweep store hash.  ``kind`` controls *when* the planner
runs (never / at every planning boundary / when Δt elapsed); the
load-fraction ``threshold`` controls *what* drains; ``budget`` bounds
per-lane churn; ``cost`` is the reported per-migration price (it never
changes decisions); ``every`` is the planning cadence in replay events
(the scan chunk size between planner invocations).
"""
from __future__ import annotations

import dataclasses

KINDS = ("none", "underload", "periodic")


@dataclasses.dataclass(frozen=True)
class ConsolidationSpec:
    kind: str = "none"        # none | underload | periodic
    threshold: float = 0.25   # drain candidates: max-dim load <= threshold
    dt: float = 0.0           # periodic sweep interval (periodic only)
    budget: int = -1          # max migrations per lane; -1 = unlimited
    cost: float = 0.0         # reported per-migration cost (never decides)
    every: int = 256          # planning cadence in events (chunk size)

    def __post_init__(self):
        assert self.kind in KINDS, \
            f"unknown consolidation kind {self.kind!r}; known: {KINDS}"
        assert self.every >= 1, "planning cadence must be >= 1 event"
        if self.kind == "periodic":
            assert self.dt > 0, "periodic consolidation needs dt > 0"
        if self.enabled:
            assert 0.0 < self.threshold <= 1.0, \
                "drain threshold is a load fraction in (0, 1]"
        assert self.cost >= 0.0

    @property
    def enabled(self) -> bool:
        return self.kind != "none"

    def canonical(self) -> str:
        """Stable string form - the piece that enters the sweep store
        hash.  ``"none"`` stays literally ``"none"`` so pre-consolidation
        spec hashes are unchanged when the axis is off."""
        if not self.enabled:
            return "none"
        parts = [self.kind]
        if self.kind == "periodic":
            parts.append(f"dt{self.dt:g}")
        parts.append(f"t{self.threshold:g}")
        parts.append(f"b{self.budget}")
        parts.append(f"e{self.every}")
        if self.cost:
            parts.append(f"c{self.cost:g}")
        return ":".join(parts)

    def __str__(self) -> str:
        return self.canonical()

    @classmethod
    def parse(cls, s: str, **overrides) -> "ConsolidationSpec":
        """Parse a CLI flag value.

        Grammar (fields optional left-to-right, ``key``-prefixed fields
        accepted anywhere after the kind):

          none
          underload[:THRESHOLD[:BUDGET]]
          periodic:DT[:THRESHOLD[:BUDGET]]
          underload:t0.25:b64:e128:c0.5   (tagged form)
        """
        parts = [p for p in s.strip().split(":") if p]
        assert parts, "empty consolidation spec"
        kind = parts[0]
        kw = dict(kind=kind)
        pos = []
        for p in parts[1:]:
            tag, rest = p[0], p[1:]
            if tag == "t" and _floatable(rest):
                kw["threshold"] = float(rest)
            elif tag == "b" and _intable(rest):
                kw["budget"] = int(rest)
            elif tag == "e" and _intable(rest):
                kw["every"] = int(rest)
            elif tag == "c" and _floatable(rest):
                kw["cost"] = float(rest)
            elif p[:2] == "dt" and _floatable(p[2:]):
                kw["dt"] = float(p[2:])
            else:
                pos.append(p)
        order = ("dt", "threshold", "budget") if kind == "periodic" \
            else ("threshold", "budget")
        for name, val in zip(order, pos):
            kw[name] = int(val) if name == "budget" else float(val)
        kw.update(overrides)
        return cls(**kw)


def _floatable(s: str) -> bool:
    try:
        float(s)
        return True
    except ValueError:
        return False


def _intable(s: str) -> bool:
    try:
        int(s)
        return True
    except ValueError:
        return False
