"""The consolidation planner - ONE numpy implementation shared verbatim
by the batched driver (reading the device carry between scan chunks) and
the sequential host oracle (reading its ``BinPool``), so the MIGRATE
events the two emit are identical by construction.

Plan shape: *whole-bin-or-skip* underload drain.

  * candidates = alive bins holding items whose max-dim load is at or
    below the threshold, ordered (load fraction ascending, open order
    ascending) - emptiest-first, oldest breaking ties,
  * a candidate drains only if ALL of its live items fit (sequential
    First Fit by bin open order) into non-candidate alive bins; partial
    drains would leave the source open and gain nothing,
  * destination simulation is a feasibility pre-check only: the emitted
    events carry just ``(item)`` and the replay policy re-places each
    migrant through its own select (category policies may route a
    migrant into a fresh bin - that is the policy's decision to make),
  * a per-lane migration ``budget`` is enforced whole-bin-wise; a
    candidate whose item count exceeds the remaining budget is skipped
    (``budget_exhausted``), smaller candidates later in the order may
    still drain.

All arithmetic is float64 on both sides; parity tests pin fp32-exact
instances (1/64-grid sizes) so the driver's float32 carry view is exact.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

import numpy as np

# Same feasibility tolerance as the host engine (core.types.EPS).
PLAN_EPS = 1e-9


@dataclasses.dataclass
class PlanResult:
    items: List[int]          # migrant item indices, in emission order
    bins_closed: int          # candidate bins accepted for draining
    budget_exhausted: int     # candidates skipped for lack of budget


def plan_migrations(loads: np.ndarray, counts: np.ndarray,
                    alive: np.ndarray, open_seq: np.ndarray,
                    bin_items: Dict[int, Sequence[int]],
                    sizes: np.ndarray, *, threshold: float,
                    budget: int = -1) -> PlanResult:
    """Plan one consolidation pass over a pool snapshot.

    ``loads`` (B, d) per-bin load, ``counts`` / ``alive`` / ``open_seq``
    (B,), ``bin_items`` maps a bin row to its live item indices
    (ascending), ``sizes`` (n, d) item demands.  ``budget < 0`` means
    unlimited.  Returns the migrant items in emission order (candidate
    bins in drain order, items ascending within a bin).
    """
    loads = np.asarray(loads, np.float64)
    sizes = np.asarray(sizes, np.float64)
    occupied = np.asarray(alive, bool) & (np.asarray(counts) > 0)
    rows = np.where(occupied)[0]
    if not len(rows):
        return PlanResult([], 0, 0)
    frac = loads[rows].max(axis=1)
    cand = rows[frac <= threshold + PLAN_EPS]
    is_cand = np.zeros(len(loads), bool)
    is_cand[cand] = True
    # emptiest first, oldest (First Fit order) breaking ties
    cand = cand[np.lexsort((open_seq[cand], loads[cand].max(axis=1)))]
    # drain targets: occupied NON-candidate bins, in open order
    targets = rows[~is_cand[rows]]
    targets = list(targets[np.argsort(open_seq[targets], kind="stable")])

    scratch = {int(t): loads[t].copy() for t in targets}
    items_out: List[int] = []
    closed = 0
    exhausted = 0
    left = math.inf if budget < 0 else int(budget)
    for src in cand:
        members = list(bin_items.get(int(src), ()))
        if not members:
            continue
        if len(members) > left:
            exhausted += 1
            continue
        # whole-bin-or-skip: simulate a First Fit drain on a scratch copy
        trial = {t: v.copy() for t, v in scratch.items()}
        ok = True
        for item in members:
            s = sizes[item]
            for t in targets:
                if np.all(s <= 1.0 - trial[t] + PLAN_EPS):
                    trial[t] = trial[t] + s
                    break
            else:
                ok = False
                break
        if not ok:
            continue
        scratch = trial
        items_out.extend(int(i) for i in members)
        closed += 1
        left -= len(members)
    return PlanResult(items_out, closed, exhausted)


def should_plan(spec, t: float, t_next: float):
    """Shared cadence gate: (run planner now?, next periodic deadline).

    ``underload`` plans at every boundary; ``periodic`` only once the
    lane clock crossed ``t_next``, then re-arms to the next Δt multiple.
    """
    if spec.kind == "none":
        return False, t_next
    if spec.kind == "periodic":
        if t < t_next:
            return False, t_next
        return True, (math.floor(t / spec.dt) + 1) * spec.dt
    return True, t_next
