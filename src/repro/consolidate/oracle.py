"""The sequential consolidating host oracle.

``core.engine.run`` replays one instance under one ``Algorithm``;
``run_consolidating`` is its consolidation-aware twin and the parity
reference for ``consolidate.driver.consolidated_replay``: it walks the
exact event order the scan sees (``core.jaxsim.event_sequence``), runs
the SAME planner on the same cadence, and applies each migration as a
removal (``on_migrated_out`` - no learning observation) followed by a
policy re-place with the source bin masked infeasible for the select.

Category policies re-categorize a migrant from its *original* arrival
clock (``types.MigrantArrival``): an item's duration class was fixed at
first arrival, mirroring the scan's per-item category constants.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.bins import BinPool
from ..core.jaxsim import event_sequence
from ..core.types import Arrival, Instance, MigrantArrival, PackingResult
from ..kernels.fitscore import ARRIVAL_KIND, DEPARTURE_KIND
from .planner import plan_migrations, should_plan
from .spec import ConsolidationSpec


def run_consolidating(instance: Instance, algorithm,
                      spec: ConsolidationSpec,
                      predicted_durations: Optional[np.ndarray] = None,
                      clairvoyant: Optional[bool] = None):
    """Replay ``instance`` under ``algorithm`` with consolidation.

    Returns ``(PackingResult, stats)``; ``stats`` mirrors the driver's:
    ``migrations``, ``bins_closed``, ``budget_exhausted``,
    ``migration_cost`` and the emitted ``events`` (``(t, item)`` pairs in
    emission order).  With ``spec.kind == "none"`` the replay is exactly
    ``core.engine.run`` (the planner never fires).
    """
    inst = instance
    n = inst.n_items
    reveal = algorithm.requires_predictions if clairvoyant is None \
        else clairvoyant
    if predicted_durations is not None:
        pdeps = inst.arrivals + predicted_durations
        reveal = True
    else:
        pdeps = inst.departures

    pool = BinPool(inst.d)
    algorithm.bind(pool, inst)

    placements = np.full(n, -1, np.int64)
    opened_at: Dict[int, float] = {}
    usage = 0.0
    span = 0.0
    span_start: Optional[float] = None
    peak_open = 0
    live: Dict[int, int] = {}          # item -> current bin
    events: List[Tuple[float, int]] = []
    bins_closed = 0
    budget_exh = 0
    budget_left = spec.budget          # < 0 = unlimited
    t_next = 0.0

    def remove_item(item: int, idx: int, t: float, migrated: bool):
        nonlocal usage, span, span_start
        size = inst.sizes[item]
        pool.remove(idx, size)
        if migrated:
            algorithm.on_migrated_out(item, idx, t, size)
        else:
            algorithm.on_departed(item, idx, t, size)
        if pool.n_active[idx] == 0:
            usage += t - opened_at.pop(idx)
            pool.close_bin(idx)
            algorithm.on_closed(idx, t)
            if not pool._open_list:
                span += t - span_start
                span_start = None

    def place_item(item: int, arr: Arrival, excl: Optional[int] = None):
        nonlocal span_start, peak_open
        saved = None
        if excl is not None and pool.alive[excl]:
            # the select must not re-pick the migration source: mask it
            # infeasible for the duration of the decision (the scan's
            # slot-exclusion twin)
            saved = pool.used[excl].copy()
            pool.used[excl] = 2.0
        idx = algorithm.select_bin(arr)
        if saved is not None:
            pool.used[excl] = saved
        opened = idx < 0
        if opened:
            if span_start is None and not pool._open_list:
                span_start = arr.now
            idx = pool.open_bin(arr.now)
            opened_at[idx] = arr.now
        else:
            assert pool.alive[idx], f"algorithm chose closed bin {idx}"
            assert idx != excl, "select returned the migration source"
        pool.place(idx, arr.size, float(pdeps[item]), arr.now)
        algorithm.on_placed(arr, idx, opened)
        placements[item] = idx
        live[item] = idx
        peak_open = max(peak_open, len(pool._open_list))

    times, kinds, items = event_sequence(inst)
    E = len(times)
    K = int(spec.every)
    for e in range(E):
        t, kind, item = float(times[e]), int(kinds[e]), int(items[e])
        if kind == DEPARTURE_KIND:
            remove_item(item, live.pop(item), t, migrated=False)
        else:
            assert kind == ARRIVAL_KIND
            place_item(item, Arrival(item, inst.sizes[item], t,
                                     float(pdeps[item]) if reveal else None))
        # planning boundary: same cadence as the driver's chunk grid
        if not spec.enabled or (e + 1) % K or e + 1 >= E:
            continue
        run, t_next = should_plan(spec, t, t_next)
        if not run or not live:
            continue
        nb = pool.n_bins
        bin_items: Dict[int, List[int]] = {}
        for it in sorted(live):
            bin_items.setdefault(live[it], []).append(it)
        plan = plan_migrations(
            pool.used[:nb], pool.n_active[:nb], pool.alive[:nb],
            pool.open_seq[:nb], bin_items, inst.sizes,
            threshold=spec.threshold, budget=budget_left)
        bins_closed += plan.bins_closed
        budget_exh += plan.budget_exhausted
        if budget_left >= 0:
            budget_left -= len(plan.items)
        for it in plan.items:
            src = live.pop(it)
            remove_item(it, src, t, migrated=True)
            place_item(
                it, MigrantArrival(it, inst.sizes[it], t,
                                   float(pdeps[it]) if reveal else None,
                                   orig_now=float(inst.arrivals[it])),
                excl=src)
            events.append((t, it))

    assert not pool._open_list, "all bins must close once every item departed"
    result = PackingResult(
        usage_time=usage, n_bins_opened=pool.n_bins,
        peak_open_bins=peak_open, placements=placements,
        algorithm=algorithm.name, instance=inst.name, span=span)
    stats = {"migrations": len(events), "bins_closed": bins_closed,
             "budget_exhausted": budget_exh,
             "migration_cost": spec.cost * len(events), "events": events}
    return result, stats
