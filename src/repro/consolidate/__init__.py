"""repro.consolidate - threshold-triggered consolidation as a scenario axis.

The paper evaluates placement-only MinUsageTime DVBP policies; real
operators also *repack*: items can be migrated off nearly-empty bins so
those bins close earlier, trading migration churn for usage time (bounded
recourse, cf. Murhekar et al.; repeated repacking of the live set, cf.
Bellur et al.).  This package makes that a first-class axis over the whole
replay stack:

  * a third event kind ``MIGRATE`` (``kernels.fitscore.MIGRATE_KIND``)
    understood by both the jnp reference scan and the event-blocked
    megakernel: a full departure application (learning updates skipped)
    followed by the arrival machinery on the post-departure carry, with
    the source slot excluded from the select,
  * a host-side planner (:mod:`.planner`) that inspects the live carry
    between scan chunks and emits MIGRATE events - shared verbatim by the
    batched driver and the sequential oracle so the two stay
    decision-for-decision equal,
  * :class:`~repro.consolidate.spec.ConsolidationSpec` - the knob set
    (none / underload drain / periodic sweep, load-fraction threshold,
    per-lane migration budget, per-migration cost, planning cadence),
  * :func:`~repro.consolidate.driver.consolidated_replay` - chunked
    batched replay with interleaved planning,
  * :func:`~repro.consolidate.oracle.run_consolidating` - the sequential
    consolidating host oracle (parity reference).

Churn counters: ``consolidate.migrations``, ``consolidate.bins_closed``,
``consolidate.budget_exhausted`` (see ``repro.obs``).
"""
from .spec import ConsolidationSpec
from .planner import PlanResult, plan_migrations, should_plan
from .driver import consolidated_replay
from .oracle import run_consolidating

__all__ = ["ConsolidationSpec", "PlanResult", "plan_migrations",
           "should_plan", "consolidated_replay", "run_consolidating"]
