"""Chunked batched replay with interleaved consolidation planning.

The scan cannot decide migrations itself - the planner needs a global
look at the pool (which bins are nearly empty, where their items could
go) - so the driver alternates device and host:

    [K-event scan chunk] -> host planner on the carry -> [MIGRATE chunk]
        -> [next K-event chunk] -> ...

Each chunk threads the replay carry (``_replay_batch(...,
return_carry=True)``); MIGRATE chunks replay with ``migrate=True`` so
the MIGRATE branch is compiled only where migrations can occur, and the
base chunks keep the exact non-consolidating graph.  PAD no-ops make
ragged per-lane migration counts rectangular, exactly like the tail
padding of the base stream.

The planner input is the carry itself (loads / counts / alive /
open_seq / item placements), viewed in float64 - the same snapshot the
sequential oracle takes of its ``BinPool``, so with fp32-exact instances
both sides emit identical MIGRATE events and the replay stays
decision-for-decision equal (tests/test_consolidate.py).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List

import jax
import numpy as np

from .. import obs
from ..core.jaxsim import _replay_batch, replay_event_extras
from ..kernels import fitscore as _fk
from ..kernels.fitscore import ARRIVAL_KIND, DEPARTURE_KIND, MIGRATE_KIND, \
    PAD_KIND
from .planner import plan_migrations, should_plan
from .spec import ConsolidationSpec

# MIGRATE chunk widths round up to a multiple of this (PAD-filled) so the
# jitted segment retraces on a few width buckets, not every plan size.
_MIG_PAD = 8


@partial(jax.jit, static_argnames=("policy", "max_bins", "backend",
                                   "block_events", "migrate"))
def _segment(sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps,
             n_items, carry0, ev_extra, *, policy: str, max_bins: int,
             backend: str, block_events: int, migrate: bool):
    return _replay_batch(
        sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps, n_items,
        policy=policy, max_bins=max_bins, backend=backend,
        block_events=block_events, carry0=carry0, return_carry=True,
        ev_extra=ev_extra if ev_extra else None, migrate=migrate)


def _pool_view(carry, d: int) -> Dict[str, np.ndarray]:
    """Planner-facing float64 view of either replay carry layout: the
    packed kernel dict (event-blocked path) or the jnp core tuple."""
    if isinstance(carry, dict):
        sloti = np.asarray(carry["sloti"])
        return {"loads": np.asarray(carry["loads"])[..., :d]
                .astype(np.float64),
                "counts": sloti[..., _fk.SLOTI_COUNTS],
                "alive": sloti[..., _fk.SLOTI_ALIVE] > 0,
                "open_seq": sloti[..., _fk.SLOTI_OSEQ],
                "placements": np.asarray(carry["itemi"])
                [..., _fk.ITEMI_PLACE]}
    core, _cat = carry
    return {"loads": np.asarray(core[0])[..., :d].astype(np.float64),
            "counts": np.asarray(core[1]),
            "alive": np.asarray(core[2]),
            "open_seq": np.asarray(core[3]),
            "placements": np.asarray(core[7])}


def consolidated_replay(sizes, times, kinds, items, pdeps, dmask,
                        arrivals, rdeps, n_items, *, policy: str,
                        max_bins: int, backend: str = "jnp",
                        block_events: int = 0,
                        spec: ConsolidationSpec):
    """Batched replay of ``L`` lanes with consolidation interleaved.

    Same array contract as ``core.jaxsim._replay_batch``; returns
    ``(usage, opened, placements, overflow, stats)`` where ``stats``
    holds per-lane churn: ``migrations``, ``bins_closed``,
    ``budget_exhausted``, ``migration_cost`` and the emitted ``events``
    (per lane, ``(t, item)`` in emission order).
    """
    assert spec.enabled, "consolidated_replay needs an active spec; " \
        "disabled runs go straight through _replay_batch"
    sizes = np.asarray(sizes)
    L, n_max, d = sizes.shape
    E = int(times.shape[1])
    K = int(spec.every)
    times_np = np.asarray(times, np.float64)
    kinds_np = np.asarray(kinds)
    items_np = np.asarray(items)
    sizes64 = sizes.astype(np.float64)

    # full-event-axis per-event extras (RCP's distinct-category cumsum
    # must span chunks - same rule as checkpointed replay)
    extras = tuple(np.asarray(x) for x in replay_event_extras(
        policy, sizes, pdeps, dmask, arrivals, rdeps, n_items, times,
        kinds, items))

    seg = partial(_segment, policy=policy, max_bins=max_bins,
                  backend=backend, block_events=block_events)
    base = (pdeps, dmask, arrivals, rdeps, n_items)

    live: List[set] = [set() for _ in range(L)]
    last_t = np.zeros(L)
    budget_left = np.full(L, spec.budget, np.int64)
    t_next = np.zeros(L)
    migrations = np.zeros(L, np.int64)
    bins_closed = np.zeros(L, np.int64)
    budget_exh = np.zeros(L, np.int64)
    events: List[List] = [[] for _ in range(L)]

    carry = None
    out = None
    with obs.span("consolidate.replay", cat="consolidate", policy=policy,
                  spec=spec.canonical(), lanes=L):
        for s in range(0, E, K):
            e = min(s + K, E)
            ex = tuple(x[:, s:e] for x in extras)
            out = seg(sizes, times[:, s:e], kinds[:, s:e], items[:, s:e],
                      *base, carry, ex, migrate=False)
            carry = out[4]
            # host aliveness + lane clocks from the chunk's event prefix
            for lane in range(L):
                for i in range(s, e):
                    k = int(kinds_np[lane, i])
                    if k == ARRIVAL_KIND:
                        live[lane].add(int(items_np[lane, i]))
                    elif k == DEPARTURE_KIND:
                        live[lane].discard(int(items_np[lane, i]))
                    else:
                        continue
                    last_t[lane] = times_np[lane, i]
            if e >= E:
                break   # never plan after the final chunk
            view = _pool_view(carry, d)
            plans: List[List[int]] = []
            for lane in range(L):
                run, t_next[lane] = should_plan(
                    spec, float(last_t[lane]), float(t_next[lane]))
                if not run or not live[lane]:
                    plans.append([])
                    continue
                bin_items: Dict[int, List[int]] = {}
                for item in sorted(live[lane]):
                    bin_items.setdefault(
                        int(view["placements"][lane, item]), []).append(item)
                plan = plan_migrations(
                    view["loads"][lane], view["counts"][lane],
                    view["alive"][lane], view["open_seq"][lane],
                    bin_items, sizes64[lane], threshold=spec.threshold,
                    budget=int(budget_left[lane]))
                bins_closed[lane] += plan.bins_closed
                budget_exh[lane] += plan.budget_exhausted
                migrations[lane] += len(plan.items)
                if budget_left[lane] >= 0:
                    budget_left[lane] -= len(plan.items)
                events[lane].extend(
                    (float(last_t[lane]), it) for it in plan.items)
                plans.append(plan.items)
            w = max(len(p) for p in plans)
            if not w:
                continue
            wp = -(-w // _MIG_PAD) * _MIG_PAD
            m_times = np.repeat(last_t[:, None], wp, axis=1)
            m_kinds = np.full((L, wp), PAD_KIND, kinds_np.dtype)
            m_items = np.zeros((L, wp), items_np.dtype)
            for lane, p in enumerate(plans):
                m_kinds[lane, :len(p)] = MIGRATE_KIND
                m_items[lane, :len(p)] = p
            # extras at a migrate boundary: the running value as of the
            # chunk's last event (MIGRATE events never advance them)
            m_ex = tuple(np.repeat(x[:, e - 1:e], wp, axis=1)
                         for x in extras)
            out = seg(sizes, m_times.astype(times_np.dtype), m_kinds,
                      m_items, *base, carry, m_ex, migrate=True)
            carry = out[4]
            obs.instant("consolidate.plan", chunk_end=int(e),
                        migrations=int(sum(len(p) for p in plans)),
                        bins_closed=int(bins_closed.sum()))
    obs.counter_add("consolidate.migrations", int(migrations.sum()))
    obs.counter_add("consolidate.bins_closed", int(bins_closed.sum()))
    obs.counter_add("consolidate.budget_exhausted", int(budget_exh.sum()))
    usage, opened, placements, overflow = out[:4]
    stats = {"migrations": migrations, "bins_closed": bins_closed,
             "budget_exhausted": budget_exh,
             "migration_cost": spec.cost * migrations.astype(np.float64),
             "events": events}
    return usage, opened, placements, overflow, stats
