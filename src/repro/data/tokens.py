"""Deterministic, seekable token pipeline with straggler-tolerant prefetch.

batch = pure_fn(step): recovery after restart replays the exact stream (the
property ElasticTrainer relies on).  The synthetic corpus is a mixture of
Zipf unigrams and repeated n-gram "documents" so models actually learn
(loss decreases in examples/quickstart.py).

``PrefetchLoader`` issues every batch to a primary worker thread and - if it
misses a deadline - a backup (straggler mitigation at the data layer: the
same hedged-request trick the cluster scheduler uses for compute shards).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, Iterator, Optional

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, doc_len: int = 64):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.doc_len = doc_len
        # Zipf unigram table
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._p = (1.0 / ranks ** 1.1)
        self._p /= self._p.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.global_batch, self.seq_len
        toks = rng.choice(self.vocab, size=(B, S + 1), p=self._p)
        # paste periodic n-gram motifs so there is learnable structure
        dl = min(self.doc_len, (S + 1) // 2)
        motif = rng.choice(self.vocab, size=dl, p=self._p)
        reps = max(1, (S + 1) // (2 * dl))
        for b in range(B):
            for r in range(reps):
                at = (b * 131 + r * 2 * dl) % max(S + 1 - dl, 1)
                toks[b, at: at + dl] = motif
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class PrefetchLoader:
    """Hedged prefetch: a backup fetch fires if the primary is slow."""

    def __init__(self, stream: TokenStream, deadline_s: float = 5.0,
                 depth: int = 2, delay_fn=None):
        self.stream = stream
        self.deadline = deadline_s
        self.depth = depth
        self.delay_fn = delay_fn          # test hook: simulate stragglers
        self.hedged = 0

    def _fetch(self, step: int, out: "queue.Queue", tag: str):
        try:
            if self.delay_fn is not None:
                time.sleep(self.delay_fn(step, tag))
            out.put((tag, self.stream.batch(step)))
        except Exception as e:   # surface worker failures to the caller
            out.put((tag, e))

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        out: queue.Queue = queue.Queue()
        t1 = threading.Thread(target=self._fetch, args=(step, out, "primary"))
        t1.start()
        try:
            tag, batch = out.get(timeout=self.deadline)
        except queue.Empty:
            self.hedged += 1
            t2 = threading.Thread(target=self._fetch,
                                  args=(step, out, "backup"))
            t2.start()
            tag, batch = out.get()
        if isinstance(batch, Exception):
            raise batch
        return batch
