"""Data substrate: DVBP traces, token pipeline, sequence packing."""
from .traces import (DAY, HORIZON, load_azure_csv,  # noqa: F401
                     make_azure_like_suite, make_huawei_like_suite)
