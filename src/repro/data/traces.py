"""DVBP instance sources.

The paper evaluates on the Microsoft Azure Packing 2020 trace (5.56M VM
requests, 28 distinct instances after cleaning) and the Huawei-East-1 trace.
Neither is downloadable in this offline container, so we provide:

  * ``make_azure_like_suite``: a calibrated synthetic family reproducing the
    paper's §III exploratory statistics - log-normal VM lifetimes (Fig. 1),
    a 14-day horizon with items fully inside it, d=4/5 normalized resource
    dims with core/memory correlation, Zipf VM-type popularity, diurnal
    arrival intensity, and one instance per synthetic "PM type".
  * ``make_huawei_like_suite``: the d=2 (CPU, memory) analogue of Appendix D.
  * ``load_azure_csv``: loads the real trace when present (data/azure/*.csv
    with columns vmTypeId,starttime,endtime joined against a type table),
    so the benchmarks upgrade to the real dataset automatically.

All times are in seconds.
"""
from __future__ import annotations

import csv
import os
from typing import Iterator, List, Optional, Tuple

import numpy as np

from ..core.types import Instance

DAY = 86400.0
HORIZON = 14 * DAY


def _vm_type_table(rng: np.random.Generator, n_types: int, d: int,
                   pm_cores: int) -> np.ndarray:
    """Normalized size vectors for n_types VM flavors on one PM type.

    core: power-of-two flavors; memory: correlated GB/core ratio;
    ssd / nic (and optional hdd): sub-linear in cores with noise.
    """
    max_exp = int(np.log2(pm_cores))
    core_exp = rng.integers(0, max_exp, n_types)   # 1 .. pm_cores/2 cores
    cores = 2.0 ** core_exp
    gb_per_core = rng.choice([1.0, 2.0, 4.0, 8.0], n_types,
                             p=[0.15, 0.35, 0.35, 0.15])
    pm_mem = pm_cores * 4.0
    mem = cores * gb_per_core
    ssd = cores / pm_cores * rng.uniform(0.3, 1.5, n_types)
    nic = cores / pm_cores * rng.uniform(0.2, 1.2, n_types)
    cols = [cores / pm_cores, mem / pm_mem, ssd, nic]
    if d == 5:
        cols.append(cores / pm_cores * rng.uniform(0.0, 1.0, n_types))  # hdd
    sizes = np.stack(cols[:d], axis=1)
    return np.clip(sizes, 1e-4, 1.0)


def _one_instance(seed: int, n_items: int, d: int, pm_cores: int,
                  med_lifetime: float, sigma_lifetime: float,
                  name: str) -> Instance:
    rng = np.random.default_rng(seed)
    n_types = int(rng.integers(8, 30))
    table = _vm_type_table(rng, n_types, d, pm_cores)
    # Zipf popularity over VM types (heavier head, like Azure).
    pop = 1.0 / np.arange(1, n_types + 1) ** rng.uniform(0.8, 1.6)
    pop /= pop.sum()
    types = rng.choice(n_types, n_items, p=pop)
    sizes = table[types]

    # Diurnal arrival intensity: thin a uniform proposal by a sinusoid.
    proposals = rng.uniform(0, HORIZON, n_items * 2)
    phase = rng.uniform(0, 2 * np.pi)
    accept = rng.random(n_items * 2) < \
        0.55 + 0.45 * np.sin(2 * np.pi * proposals / DAY + phase)
    arrivals = np.sort(proposals[accept][:n_items])
    if len(arrivals) < n_items:   # extremely unlikely; pad uniformly
        extra = rng.uniform(0, HORIZON, n_items - len(arrivals))
        arrivals = np.sort(np.concatenate([arrivals, extra]))

    # Log-normal lifetimes (paper Fig. 1b), truncated inside the horizon the
    # same way the paper cleans the Azure trace (items must fully fit).
    mu_ln = np.log(med_lifetime)
    life = rng.lognormal(mu_ln, sigma_lifetime, n_items)
    life = np.clip(life, 30.0, None)
    life = np.minimum(life, np.maximum(HORIZON - arrivals, 60.0))
    life = np.minimum(life, HORIZON - arrivals + 1e-3)
    departures = arrivals + life
    return Instance(sizes, arrivals, departures, name).sorted_by_arrival()


def make_azure_like_suite(n_instances: int = 28, n_items: int = 5000,
                          seed: int = 2026) -> List[Instance]:
    """One instance per synthetic PM type, mirroring the paper's 28-instance
    Azure family: d in {4,5}, varied PM size, load, and lifetime spread."""
    rng = np.random.default_rng(seed)
    out = []
    for k in range(n_instances):
        d = 4 if k % 3 else 5
        pm_cores = int(rng.choice([32, 48, 64, 96, 128]))
        med = float(rng.choice([600.0, 1800.0, 3600.0, 10800.0]))
        sig = float(rng.uniform(1.2, 2.4))
        items = int(n_items * rng.uniform(0.6, 1.4))
        out.append(_one_instance(int(rng.integers(1 << 31)), items, d,
                                 pm_cores, med, sig, f"azure_like_{k:02d}"))
    return out


def make_huawei_like_suite(n_instances: int = 9, n_items: int = 4000,
                           seed: int = 77) -> List[Instance]:
    """Appendix D analogue: d=2 (CPU, memory), nine assumed PM capacities."""
    rng = np.random.default_rng(seed)
    out = []
    caps = [(64, 128), (64, 200), (64, 256), (100, 128), (100, 200),
            (100, 256), (128, 128), (128, 200), (128, 256)]
    for k in range(n_instances):
        cpu_cap, mem_cap = caps[k % len(caps)]
        sub = np.random.default_rng(seed + 1000 + k)
        n_types = int(sub.integers(6, 20))
        cores = 2.0 ** sub.integers(0, 7, n_types)        # up to 64 cores
        mem = cores * sub.choice([1.0, 2.0, 4.0], n_types)
        table = np.stack([cores / cpu_cap, mem / mem_cap], axis=1)
        table = np.clip(table, 1e-4, 1.0)
        pop = 1.0 / np.arange(1, n_types + 1) ** 1.2
        pop /= pop.sum()
        types = sub.choice(n_types, n_items, p=pop)
        arrivals = np.sort(sub.uniform(0, HORIZON, n_items))
        life = np.clip(sub.lognormal(np.log(1800.0), 1.8, n_items), 30.0, None)
        life = np.minimum(life, HORIZON - arrivals + 1e-3)
        out.append(Instance(table[types], arrivals, arrivals + life,
                            f"huawei_like_{k}").sorted_by_arrival())
    return out


def load_azure_csv(root: str = "data/azure") -> Optional[List[Instance]]:
    """Load the real AzureTracesForPacking2020 dataset if the user has placed
    it under ``root`` (vmtype.csv + vmrequest.csv).  Returns None if absent."""
    tpath, rpath = os.path.join(root, "vmtype.csv"), os.path.join(root, "vmrequest.csv")
    if not (os.path.exists(tpath) and os.path.exists(rpath)):
        return None
    # vmtype.csv: vmTypeId,machineId,core,memory,hdd,ssd,nic
    ttab = np.genfromtxt(tpath, delimiter=",", names=True)
    rtab = np.genfromtxt(rpath, delimiter=",", names=True)
    out = []
    for pm in np.unique(ttab["machineId"]):
        rows = ttab[ttab["machineId"] == pm]
        dims = ["core", "memory", "hdd", "ssd", "nic"]
        cols = [np.nan_to_num(rows[c]) for c in dims]
        keep = [i for i, c in enumerate(cols) if np.any(c > 0)]
        table = {int(v): np.array([cols[i][j] for i in keep])
                 for j, v in enumerate(rows["vmTypeId"])}
        mask = np.isin(rtab["vmTypeId"], list(table))
        req = rtab[mask]
        ok = (req["starttime"] >= 0) & np.isfinite(req["endtime"]) & \
             (req["endtime"] <= 14.0)
        req = req[ok]
        if not len(req):
            continue
        sizes = np.stack([table[int(v)] for v in req["vmTypeId"]])
        arr = req["starttime"] * DAY
        dep = req["endtime"] * DAY
        good = dep > arr
        out.append(Instance(np.clip(sizes[good], 1e-6, 1.0), arr[good],
                            dep[good], f"azure_pm{int(pm)}").sorted_by_arrival())
    return out or None


def _azure_type_table(root: str, machine_id: int):
    """The (clipped) size-vector table for one machineId, with the same
    keep-nonzero-dims / clip cleaning as ``load_azure_csv``."""
    tpath = os.path.join(root, "vmtype.csv")
    ttab = np.genfromtxt(tpath, delimiter=",", names=True)
    rows = ttab[ttab["machineId"] == machine_id]
    if not len(rows):
        raise ValueError(f"no machineId {machine_id} in {tpath}")
    dims = ["core", "memory", "hdd", "ssd", "nic"]
    cols = [np.nan_to_num(rows[c]) for c in dims]
    keep = [i for i, c in enumerate(cols) if np.any(c > 0)]
    return {int(v): np.clip(np.array([cols[i][j] for i in keep]),
                            1e-6, 1.0)
            for j, v in enumerate(rows["vmTypeId"])}


def azure_stream_meta(root: str, machine_id: int) -> int:
    """Dimension count of one machineId's cleaned size vectors (the
    streaming reader's only up-front fact - no request scan needed)."""
    table = _azure_type_table(root, machine_id)
    return len(next(iter(table.values())))


def iter_azure_requests(root: str = "data/azure", machine_id: int = 0) \
        -> Iterator[Tuple[np.ndarray, float, float]]:
    """Stream one machineId's ``(size_vec, arrival_s, departure_s)``
    requests from an Azure-format trace without materializing it: only the
    (small) vmtype table is loaded; vmrequest.csv is read line by line.

    Applies exactly ``load_azure_csv``'s cleaning - requests joined
    against the type table, ``starttime >= 0``, finite ``endtime <= 14``
    days, strictly positive duration, times scaled to seconds - and
    yields in file order, which for the published trace is arrival order.
    Raises ``ValueError`` on a ``starttime`` regression rather than
    buffering for a sort (a sorted spill would defeat the bounded-memory
    contract; pre-sort the CSV once if yours is unordered)."""
    table = _azure_type_table(root, machine_id)
    rpath = os.path.join(root, "vmrequest.csv")
    last = -np.inf
    with open(rpath, newline="") as fh:
        for row in csv.DictReader(fh):
            try:
                vmtype = int(float(row["vmTypeId"]))
                start = float(row["starttime"])
                end = float(row["endtime"])
            except (KeyError, TypeError, ValueError):
                continue            # genfromtxt turns bad cells into nan
            size = table.get(vmtype)
            if size is None or not (start >= 0) or not np.isfinite(end) \
                    or end > 14.0:
                continue
            arr, dep = start * DAY, end * DAY
            if dep <= arr:
                continue
            if arr < last:
                raise ValueError(
                    f"vmrequest.csv is not arrival-sorted: starttime "
                    f"{start} after {last / DAY}; sort it once up front")
            last = arr
            yield size, arr, dep
