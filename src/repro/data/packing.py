"""Document -> training-sequence packing: the static bin-packing special case
of the paper's problem, applied to the data pipeline.

Documents are items whose single dimension is token count; sequences are
bins of capacity seq_len.  Any-Fit heuristics (First/Best Fit, and their
decreasing variants for offline batches) minimize the number of sequences
== padding waste.  Returns pack assignments + achieved token efficiency.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def pack_documents(lengths: List[int], seq_len: int,
                   policy: str = "first_fit_decreasing"
                   ) -> Tuple[List[List[int]], float]:
    order = np.argsort(lengths)[::-1] if policy.endswith("decreasing") \
        else np.arange(len(lengths))
    bins: List[List[int]] = []
    space: List[int] = []
    for i in order:
        li = lengths[i]
        if li > seq_len:
            continue   # caller chunks over-length docs first
        choice = -1
        if policy.startswith("first_fit"):
            for b, s in enumerate(space):
                if s >= li:
                    choice = b
                    break
        else:   # best fit: tightest remaining space
            feas = [(s - li, b) for b, s in enumerate(space) if s >= li]
            if feas:
                choice = min(feas)[1]
        if choice < 0:
            bins.append([int(i)])
            space.append(seq_len - li)
        else:
            bins[choice].append(int(i))
            space[choice] -= li
    used = sum(lengths[i] for b in bins for i in b)
    efficiency = used / (len(bins) * seq_len) if bins else 1.0
    return bins, float(efficiency)
