"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") - the
"pod" axis is pure data parallelism so only the gradient all-reduce crosses
the inter-pod DCI links.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a (data=N, model=1) mesh (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
