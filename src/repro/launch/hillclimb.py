import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Perf hillclimbing driver (§Perf): lower+compile a cell under named
variants and report the roofline-term deltas.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen_train
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.config import SHAPES
from . import roofline as R
from .mesh import make_production_mesh
from .specs import build_cell, make_rules


def measure(arch, shape_name, *, cfg_patch=None, rules_patch=None,
            build_kw=None, label="baseline"):
    cfg = get_config(arch)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    rules = make_rules(cfg, shape, False)
    if rules_patch:
        rules = dataclasses.replace(rules, **rules_patch)
    cell = build_cell(cfg, shape_name, mesh, False, rules=rules,
                      **(build_kw or {}))
    with mesh:
        compiled = cell.lower().compile()
    hlo = compiled.as_text()
    roof = R.analyze(compiled, n_devices=mesh.devices.size,
                     model_flops=R.model_flops_for(cfg, shape), hlo_text=hlo)
    row = roof.table_row()
    row["label"] = label
    print(f"{label:28s} compute={row['compute_s']*1e3:9.2f}ms "
          f"mem={row['memory_s']*1e3:9.2f}ms "
          f"coll={row['collective_s']*1e3:9.2f}ms dom={row['dominant']:10s} "
          f"useful={row['useful_ratio']:.3f}", flush=True)
    return row


# Final variant sets matching the EXPERIMENTS.md §Perf iteration logs.
# NOTE: the it1 kv-head-replication fix for qwen graduated into the baseline
# code (models/params.py), so "baseline" here already includes it; the
# pre-fix numbers are recorded in EXPERIMENTS.md.
CELLS = {
    # -------- worst-roofline-fraction cell: qwen2.5-14b train_4k
    "qwen_train": [
        ("baseline(kv_repl)", {}),
        ("mb8", {"build_kw": {"microbatches": 8}}),
        ("mb8+SP[refuted]", {"build_kw": {"microbatches": 8},
                             "rules_patch": {"seq_parallel": True}}),
        ("mb8+flash_xla[refuted]", {"cfg_patch": {"attn_kv_chunk": 512},
                                    "build_kw": {"microbatches": 8}}),
    ],
    # -------- most collective-bound cell: nemotron-4-340b train_4k
    "nemotron_train": [
        ("baseline(mb16,SP,int8)", {}),
        ("mb8[refuted]", {"build_kw": {"microbatches": 8}}),
        ("mb4", {"build_kw": {"microbatches": 4}}),
        ("flash_xla[refuted]", {"cfg_patch": {"attn_kv_chunk": 512},
                                "build_kw": {"microbatches": 8}}),
    ],
    # -------- paper-representative serving cell: deepseek decode_32k
    "deepseek_decode": [
        ("baseline(naive MLA,fsdp)", {}),
        ("mla_absorb", {"build_kw": {"mla_absorb": True}}),
        ("tp_only_weights", {"rules_patch": {"fsdp": False}}),
        ("absorb+tp_only", {"rules_patch": {"fsdp": False},
                            "build_kw": {"mla_absorb": True}}),
    ],
}

CELL_TARGETS = {"qwen_train": ("qwen2.5-14b", "train_4k"),
                "nemotron_train": ("nemotron-4-340b", "train_4k"),
                "deepseek_decode": ("deepseek-v2-lite-16b", "decode_32k")}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(CELLS), required=True)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args(argv)
    arch, shape = CELL_TARGETS[args.cell]
    print(f"== hillclimb {args.cell}: {arch} x {shape}")
    rows = []
    for label, kw in CELLS[args.cell]:
        try:
            rows.append(measure(arch, shape, label=label, **kw))
        except Exception as e:
            print(f"{label:28s} FAILED: {e}", flush=True)
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"{args.cell}.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
