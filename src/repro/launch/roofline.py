"""Roofline terms from a compiled dry-run artifact (no hardware needed).

Hardware model (TPU v5e class, per chip):
    peak bf16 compute 197 TFLOP/s | HBM bandwidth 819 GB/s | ICI ~50 GB/s/link

`cost_analysis()` FLOPs / bytes are for the *per-device* SPMD module, so
    compute_term = flops / PEAK ;  memory_term = bytes / HBM_BW.
Collective bytes are not in cost_analysis: we parse the compiled HLO text and
sum wire bytes per device for every collective, with ring-algorithm factors:
    all-gather      out_bytes * (n-1)/n
    reduce-scatter  out_bytes * (n-1)
    all-reduce      2 * bytes * (n-1)/n
    all-to-all      bytes * (n-1)/n
    collective-permute  bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
CHIP_HBM = 16 * 1024 ** 3  # v5e HBM capacity

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:   # iota form [num_groups,group_size]
        return int(m.group(2))
    return 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Wire bytes per device, by collective kind, from HLO text."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts: Dict[str, int] = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in line:     # async pair: count only the -start
            continue
        size = _shape_bytes(type_str)
        n = _group_size(line)
        if kind == "all-gather":
            wire = size * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = size * (n - 1)
        elif kind == "all-reduce":
            wire = 2 * size * (n - 1) / n
        elif kind == "all-to-all":
            wire = size * (n - 1) / n
        else:
            wire = size
        out[kind] += wire
        counts[kind] += 1
    out["_counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops: float               # per device
    bytes_hbm: float           # per device
    bytes_wire: float          # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float         # 6ND / 2ND (whole step, all devices)
    useful_ratio: float        # model_flops / (flops * n_devices)
    coll_detail: Dict[str, float]
    peak_bytes: Optional[int] = None

    def table_row(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.bytes_hbm,
            "wire_bytes_per_dev": self.bytes_wire,
            "peak_bytes": self.peak_bytes,
        }


def _cost_value(cost, key: str) -> float:
    if cost is None:
        return 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get(key, 0.0))


def analyze(compiled, *, n_devices: int, model_flops: float,
            hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms via the trip-count-aware HLO walker (hlo_cost.py).
    NOTE: compiled.cost_analysis() counts while-loop bodies once and is only
    kept as a cross-check; module_cost multiplies by trip counts."""
    from . import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    mc = hlo_cost.module_cost(text)
    flops = mc.flops
    nbytes = mc.bytes
    coll = dict(mc.wire)
    coll["_counts"] = mc.coll_counts
    wire = mc.wire_total
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = wire / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    peak = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                    - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    except Exception:
        pass
    useful = model_flops / (flops * n_devices) if flops else 0.0
    return Roofline(flops, nbytes, wire, compute_s, memory_s, collective_s,
                    dominant, model_flops, useful, coll, peak)


def model_flops_for(cfg, shape) -> float:
    """6ND (train) / 2ND (inference) with N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.frontend == "audio_stub":
            tokens = shape.global_batch * (shape.seq_len + shape.seq_len // 4)
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch   # decode: one token per sequence
