"""Render the EXPERIMENTS.md roofline/dry-run tables from
experiments/dryrun/*.json artifacts.

    PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md
"""
from __future__ import annotations

import glob
import json
import sys


def rows(mesh: str):
    out = []
    for path in sorted(glob.glob(f"experiments/dryrun/*_{mesh}.json")):
        with open(path) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def main():
    print("### Single-pod (16x16 = 256 chips) roofline - all baseline cells\n")
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
          "dominant | useful (6ND/HLO) | est peak (GiB) | fits 16GB |")
    print("|---|---|---:|---:|---:|---|---:|---:|---|")
    for rec in rows("16x16"):
        r = rec["roofline"]
        m = rec["memory"]
        print(f"| {rec['arch']} | {rec['shape']} "
              f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
              f"| {r['collective_s']*1e3:.2f} | {r['dominant']} "
              f"| {r['useful_ratio']:.2f} | {fmt_bytes(m['est_peak_bytes'])} "
              f"| {'yes' if m['fits_16GB'] else 'NO'} |")
    print("\n### Multi-pod (2x16x16 = 512 chips) - compile + memory proof\n")
    print("| arch | shape | compile (s) | est peak (GiB) | fits | dominant |")
    print("|---|---|---:|---:|---|---|")
    for rec in rows("2x16x16"):
        r = rec["roofline"]
        m = rec["memory"]
        print(f"| {rec['arch']} | {rec['shape']} | {rec['compile_s']:.0f} "
              f"| {fmt_bytes(m['est_peak_bytes'])} "
              f"| {'yes' if m['fits_16GB'] else 'NO'} | {r['dominant']} |")
    print("\n### Collective mix (single-pod, wire GB/device)\n")
    print("| arch | shape | all-gather | all-reduce | reduce-scatter | "
          "all-to-all | permute |")
    print("|---|---|---:|---:|---:|---:|---:|")
    for rec in rows("16x16"):
        c = rec["collectives"]
        g = lambda k: f"{c.get(k, 0.0)/1e9:.1f}"
        print(f"| {rec['arch']} | {rec['shape']} | {g('all-gather')} "
              f"| {g('all-reduce')} | {g('reduce-scatter')} "
              f"| {g('all-to-all')} | {g('collective-permute')} |")


if __name__ == "__main__":
    main()
