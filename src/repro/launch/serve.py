"""Serving driver: a replica fleet with DVBP placement (the paper's
technique as the serving control plane).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --requests 40 --policy nrt_prioritized --sigma 0.5

Runs real ReplicaEngines (reduced config) driven by the DVBPScheduler and
reports replica-occupancy seconds (the minimized objective) next to a
round-robin fleet baseline.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_reduced_config
from ..models import params as P_
from ..serving.engine import ReplicaEngine
from ..serving.fleet import attach_predictions, simulate_fleet, synth_requests
from ..serving.scheduler import DVBPScheduler, ReplicaCapacity, Request


def serve_real(cfg, params, reqs, policy: str, slots: int = 4,
               max_len: int = 96):
    """Clock-stepped fleet of real engines; one decode tick per time unit."""
    caps = ReplicaCapacity(slots=slots, kv_tokens=slots * max_len,
                           prefill_budget=1e9)
    sched = DVBPScheduler(policy, caps, tokens_per_second=1.0)
    engines = {}
    pending = sorted(reqs, key=lambda r: r.arrival)
    t = 0.0
    done = 0
    while done < len(reqs):
        while pending and pending[0].arrival <= t:
            r = pending.pop(0)
            rep = sched.place(r, t)
            if rep not in engines:
                engines[rep] = ReplicaEngine(cfg, params, slots=slots,
                                             max_len=max_len, eos_id=-1)
            prompt = list(np.random.default_rng(r.rid).integers(
                2, cfg.vocab, r.prompt_len))
            engines[rep].admit(r.rid, prompt, r.decode_len)
        for rep, eng in list(engines.items()):
            for rid in eng.step():
                sched.finish(rid, t)
                done += 1
        t += 1.0
    return sched.stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2.5-14b")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--policy", default="greedy")
    ap.add_argument("--sigma", type=float, default=0.0,
                    help="log-normal prediction error for learned policies")
    ap.add_argument("--real", action="store_true",
                    help="run real reduced-model engines (slower)")
    args = ap.parse_args(argv)

    reqs = synth_requests(args.requests)
    if args.sigma >= 0:
        reqs = attach_predictions(reqs, args.sigma)

    print("fleet simulation (replica-occupancy seconds; lower is better):")
    for pol in ["round_robin", "first_fit", "best_fit_linf", "greedy",
                "nrt_prioritized", args.policy]:
        kw = {"norm": "linf"} if pol == "best_fit_linf" else None
        name = "best_fit" if pol == "best_fit_linf" else pol
        r = simulate_fleet(reqs, name if pol != "round_robin" else pol,
                           policy_kwargs=kw)
        print(f"  {pol:18s} replica_s={r['replica_seconds']:10.1f} "
              f"opened={r['replicas_opened']:3d} peak={r['peak_replicas']}")

    if args.real:
        cfg = get_reduced_config(args.arch)
        params = P_.init_params(jax.random.PRNGKey(0), cfg,
                                dtype=jnp.float32)
        small = [Request(r.rid, r.arrival, min(r.prompt_len, 16),
                         min(r.decode_len, 32), r.predicted_decode_len)
                 for r in reqs[: min(args.requests, 12)]]
        stats = serve_real(cfg, params, small, args.policy)
        print(f"real engines ({args.policy}): replica_s="
              f"{stats.replica_seconds:.0f} opened={stats.replicas_opened} "
              f"peak={stats.peak_replicas}")


if __name__ == "__main__":
    main()
