"""Trip-count-aware cost model over compiled (post-optimization) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers / microbatch-accumulation model is undercounted by the trip
count (verified: a 10x scanned matmul reports 1 matmul of FLOPs).  This
module re-derives FLOPs / HBM bytes / collective wire bytes by walking the
per-device HLO module, multiplying each computation's cost by the product of
enclosing loop trip counts.

Cost conventions (per device):
  * dot: 2 * numel(result) * contracted_size          (exact)
  * elementwise/reduce at fusion granularity: numel    (minor next to dots)
  * bytes: at top-level-op granularity only (fusion interiors do not touch
    HBM): sum(operand bytes) + result bytes, with slicing ops special-cased
    (dynamic-slice/gather read only the slice, dynamic-update-slice/scatter
    write only the update).
  * collectives: ring-algorithm wire bytes (see roofline.py docstring).
  * while trip count: the largest integer constant in the condition
    computation (lax.scan lowers to compare(iv, constant(N))).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*")


def _parse_def(line: str):
    """Parse '%name = TYPE kind(args...), attrs' robustly (tuple types may
    contain /*index=N*/ comments).  Returns (name, type, kind, rest)."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    s = line[m.end():]
    if s.startswith("("):   # balanced-paren tuple type
        depth, i = 0, 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, s = s[: i + 1], s[i + 1:].lstrip()
    else:
        sp = s.find(" ")
        if sp < 0:
            return None
        type_str, s = s[:sp], s[sp + 1:].lstrip()
    km = re.match(r"([\w\-]+)\(", s)
    if not km:
        return None
    return name, type_str, km.group(1), s[km.end():]
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                        r"\{?(%?[\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_numel(type_str: str) -> int:
    total = 0
    for _, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    rest: str          # args + attrs tail of the line


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]   # op name -> result type string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.wire.items():
            self.wire[k] = self.wire.get(k, 0.0) + v
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.wire.items()},
                    {k: v * f for k, v in self.coll_counts.items()})

    @property
    def wire_total(self) -> float:
        return sum(self.wire.values())


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.endswith("{") and "->" in stripped and \
                (stripped.startswith("%") or stripped.startswith("ENTRY")):
            # computation header: "%name (params) -> type {" / "ENTRY %name ..."
            m = re.search(r"(%[\w.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    comps["ENTRY"] = cur
            continue
        if cur is None:
            continue
        parsed = _parse_def(line)
        if parsed:
            name, type_str, kind, rest = parsed
            cur.ops.append(Op(name, type_str, kind, rest))
            cur.shapes[name] = type_str
    return comps


def _trip_count(cond: Computation) -> int:
    """lax.scan lowers to compare(iv, constant(N)): N is the largest integer
    constant defined in the condition computation."""
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.match(r"(\d+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
        for c in re.findall(r"constant\((\d+)\)", op.rest):
            best = max(best, int(c))
    return best


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_numel = _type_numel(op.type_str)
    m = _CONTRACT_RE.search(op.rest)
    contracted = 1
    # The lhs operand is printed either bare ("dot(%x, ...") or - on newer
    # XLA - with its type inline ("dot(f32[256,256]{1,0} %x, ...").  Prefer
    # the inline type; fall back to the computation's shape table.
    lhs = re.match(r"\s*(?:([a-z0-9]+\[[\d,]*\])(?:\{[\d,]*\})?\s+)?"
                   r"(%[\w.\-]+)", op.rest)
    if m and lhs:
        type_str = lhs.group(1) or shapes.get(lhs.group(2), "")
        dims = _dims(type_str)
        if dims:
            shape = dims[0][1]
            for d in m.group(1).split(","):
                if d:
                    contracted *= shape[int(d)]
    return 2.0 * out_numel * contracted


def _operand_bytes(op: Op, shapes: Dict[str, str]) -> float:
    total = 0.0
    args = op.rest.split("),")[0]
    for name in re.findall(r"(%[\w.\-]+)", args):
        if name in shapes:
            total += _type_bytes(shapes[name])
    return total


def _fusion_hbm_bytes(op: Op, comp: Computation,
                      inner: Optional[Computation]) -> float:
    """HBM bytes touched by a fusion call, including its result.

    Refinements over naive operands+result accounting (both essential for
    scan-over-layers modules):
      * operands consumed only via dynamic-slice / gather count at slice size
        (stacked-weights pattern);
      * a root dynamic-update-slice aliases its big buffer in place: the
        buffer operand and the result both count at *update* size.
    """
    arg_str = op.rest.split("), ")[0]
    operands = re.findall(r"(%[\w.\-]+)", arg_str)
    result_bytes = _type_bytes(op.type_str)
    if inner is None:
        return sum(_type_bytes(comp.shapes.get(nm, ""))
                   for nm in operands) + result_bytes
    params: Dict[int, str] = {}
    for iop in inner.ops:
        if iop.kind == "parameter":
            m = re.match(r"(\d+)\)", iop.rest)
            if m:
                params[int(m.group(1))] = iop.name
    # interior DUS: big-buffer param -> update bytes; shrink result charge
    # (numel comparison: CPU float normalization may change dtypes between
    # the DUS and the fusion root convert)
    result_numel = _type_numel(op.type_str)
    dus_buf_params = {}
    for iop in inner.ops:
        if iop.kind == "dynamic-update-slice":
            names = re.findall(r"(%[\w.\-]+)", iop.rest)
            if len(names) >= 2:
                upd_bytes = _type_bytes(inner.shapes.get(names[1], ""))
                dus_buf_params[names[0]] = upd_bytes
                if _type_numel(iop.type_str) == result_numel:
                    result_bytes = min(result_bytes, upd_bytes)
    total = float(result_bytes)
    for idx, nm in enumerate(operands):
        full = _type_bytes(comp.shapes.get(nm, ""))
        pname = params.get(idx)
        if pname is None:
            total += full
            continue
        pat = re.compile(re.escape(pname) + r"(?![\w.\-])")
        consumers = [iop for iop in inner.ops
                     if iop.kind != "parameter" and pat.search(iop.rest)]
        kinds = {c.kind for c in consumers}
        # "convert" is tolerated in the slice-only consumer sets: XLA:CPU's
        # float normalization inserts full-buffer bf16<->f32 converts that do
        # not exist in the TPU pipeline we are modeling.
        if consumers and kinds <= {"dynamic-slice", "gather", "convert"} \
                and kinds & {"dynamic-slice", "gather"}:
            total += sum(_type_bytes(c.type_str) for c in consumers
                         if c.kind in ("dynamic-slice", "gather"))
        elif pname in dus_buf_params and kinds <= {"dynamic-update-slice",
                                                   "bitcast", "copy",
                                                   "convert"}:
            total += dus_buf_params[pname]
        else:
            total += full
    return total


def _collective_wire(op: Op) -> Tuple[str, float]:
    size = _type_bytes(op.type_str)
    line = op.rest
    m = _GROUPS_RE.search(line)
    if m:
        n = len(m.group(1).split(","))
    else:
        m = _GROUPS_IOTA_RE.search(line)
        n = int(m.group(2)) if m else 2
    kind = next(k for k in COLLECTIVES if op.kind.startswith(k))
    if kind == "all-gather":
        wire = size * (n - 1) / n
    elif kind == "reduce-scatter":
        wire = size * (n - 1)
    elif kind == "all-reduce":
        wire = 2 * size * (n - 1) / n
    elif kind == "all-to-all":
        wire = size * (n - 1) / n
    else:
        wire = size
    return kind, wire


_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "custom-call"}
_SLICE_READ = {"dynamic-slice", "gather"}
_SLICE_WRITE = {"dynamic-update-slice", "scatter"}


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               memo: Dict[Tuple[str, bool], Cost], top_level: bool,
               charge_custom_calls: bool = False) -> Cost:
    key = (comp.name, top_level)
    if key in memo:
        return memo[key]
    memo[key] = Cost()   # break recursion defensively
    total = Cost()
    for op in comp.ops:
        kind = op.kind
        if kind == "custom-call" and charge_custom_calls:
            # opaque calls (e.g. Pallas kernels) read their operands and
            # write their result from/to HBM once per invocation - charge
            # that boundary traffic (interior FLOPs stay unknown).  Off by
            # default: the roofline models count kernel interiors via
            # their own cost estimates.
            if top_level:
                total += Cost(0.0, _operand_bytes(op, comp.shapes) +
                              _type_bytes(op.type_str))
            continue
        called = {}
        for m in _CALLED_RE.finditer(op.rest):
            for nm in m.group(1).split(","):
                nm = nm.strip()
                called[nm if nm.startswith("%") else "%" + nm] = True
        if kind == "while":
            body = cond = None
            bm = re.search(r"body=(%?[\w.\-]+)", op.rest)
            cm = re.search(r"condition=(%?[\w.\-]+)", op.rest)
            if bm:
                body = bm.group(1) if bm.group(1).startswith("%") \
                    else "%" + bm.group(1)
            if cm:
                cond = cm.group(1) if cm.group(1).startswith("%") \
                    else "%" + cm.group(1)
            trip = _trip_count(comps[cond]) if cond in comps else 1
            if body in comps:
                total += _comp_cost(
                    comps[body], comps, memo, top_level,
                    charge_custom_calls).scaled(trip)
            continue
        if kind == "fusion":
            inner = Cost()
            inner_comp = None
            for nm in called:
                if nm in comps:
                    inner_comp = comps[nm]
                    inner += _comp_cost(inner_comp, comps, memo, False,
                                        charge_custom_calls)
            total += Cost(inner.flops, 0.0, inner.wire, inner.coll_counts)
            if top_level:
                total += Cost(0.0, _fusion_hbm_bytes(op, comp, inner_comp))
            continue
        if any(kind.startswith(c) for c in COLLECTIVES):
            if kind.endswith("-done"):
                continue
            ckind, wire = _collective_wire(op)
            total += Cost(0.0,
                          (_type_bytes(op.type_str) * 2 if top_level else 0.0),
                          {ckind: wire}, {ckind: 1.0})
            continue
        if kind in ("call", "conditional"):
            for nm in called:
                if nm in comps:
                    total += _comp_cost(comps[nm], comps, memo,
                                        top_level, charge_custom_calls)
        if kind in _FREE:
            continue
        # flops
        if kind in ("dot", "convolution"):
            total += Cost(_dot_flops(op, comp.shapes), 0.0)
        else:
            total += Cost(float(_type_numel(op.type_str)), 0.0)
        # bytes (top-level granularity only)
        if top_level:
            total += Cost(0.0, _plain_op_bytes(op, comp))
    memo[key] = total
    return total


def _plain_op_bytes(op: Op, comp: Computation) -> float:
    """HBM bytes for a standalone (non-fusion) op: slicing ops touch only
    the slice/update, everything else operands + result."""
    if op.kind in _SLICE_READ:
        return 2.0 * _type_bytes(op.type_str)
    if op.kind in _SLICE_WRITE:
        upd = 0.0
        names = re.findall(r"(%[\w.\-]+)", op.rest.split(")")[0])
        if len(names) >= 2 and names[1] in comp.shapes:
            upd = _type_bytes(comp.shapes[names[1]])
        return 2.0 * upd + 64.0
    return _operand_bytes(op, comp.shapes) + _type_bytes(op.type_str)


def module_cost(hlo_text: str,
                charge_custom_calls: bool = False) -> Cost:
    """Whole-module cost.  ``charge_custom_calls=True`` additionally
    counts each custom-call's operand+result bytes (x enclosing trip
    counts) - the HBM boundary traffic of opaque kernels such as Pallas
    calls, used by the ``perf/replay_block_bytes_*`` benchmark rows."""
    comps = parse_module(hlo_text)
    if "ENTRY" not in comps:
        raise ValueError("no ENTRY computation found in HLO text")
    memo: Dict[Tuple[str, bool], Cost] = {}
    return _comp_cost(comps["ENTRY"], comps, memo, True,
                      charge_custom_calls)


_CARRYISH = {"parameter", "tuple", "get-tuple-element", "while", "constant",
             "conditional", "call", "bitcast", "after-all"}


def max_transient(hlo_text: str) -> float:
    """Largest single-op working set (operands+result) outside loop carries.

    Used for the analytic TPU peak-memory estimate: XLA's CPU buffer
    assignment does not alias while-loop carries in place (TPU does), so the
    CPU `temp_size` wildly overstates the real device peak for scanned
    models.  Estimated TPU peak ~= persistent state + 2 * max_transient.
    """
    comps = parse_module(hlo_text)
    best = 0.0
    coll_cap = 2 * 256 * 1024 * 1024   # TPU collective-combiner bound (in+out)
    for comp in comps.values():
        for op in comp.ops:
            if op.kind in _CARRYISH:
                continue
            if op.kind == "fusion":
                called = re.search(r"calls=(%?[\w.\-]+)", op.rest)
                inner = comps.get("%" + called.group(1).lstrip("%")) \
                    if called else None
                ws = _fusion_hbm_bytes(op, comp, inner)
            else:
                ws = _plain_op_bytes(op, comp)
            if any(op.kind.startswith(c) for c in COLLECTIVES):
                # XLA:CPU's combiner bundles collectives without a size cap;
                # the TPU pipeline bounds bundles (~tens-hundreds of MB), so
                # a 6.7GB fused all-reduce is a CPU-compile artifact.
                ws = min(ws, coll_cap)
            best = max(best, ws)
    return best
