"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --reduced \
        --steps 100 --batch 8 --seq 128

Full-scale configs target the production mesh (see dryrun.py for the
compile-only path); --reduced runs the same code end-to-end on host devices
with checkpointing, deterministic data, and metrics.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, get_reduced_config
from ..data.tokens import TokenStream
from ..models import params as P_
from ..models.sharding import ShardingRules, tree_shardings
from ..models.transformer import Runtime
from ..train.checkpoint import CheckpointManager
from ..train.optimizer import OptConfig, init_opt_state
from ..train.train_step import make_train_step
from .mesh import make_host_mesh


def build(arch: str, reduced: bool, batch: int, seq: int, microbatches: int,
          lr: float, steps: int):
    cfg = get_reduced_config(arch) if reduced else get_config(arch)
    mesh = make_host_mesh()
    rules = ShardingRules(fsdp=False, data_axes=("data",))
    rt = Runtime(mesh=mesh, rules=rules)
    opt = OptConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                    total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, rt, opt,
                                      microbatches=microbatches),
                      donate_argnums=(0, 1))
    params = P_.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt_state = init_opt_state(params, opt)
    stream = TokenStream(cfg.vocab, seq, batch)
    return cfg, mesh, step_fn, params, opt_state, stream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2.5-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, mesh, step_fn, params, opt_state, stream = build(
        args.arch, args.reduced, args.batch, args.seq, args.microbatches,
        args.lr, args.steps)
    ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        like = jax.eval_shape(lambda: (params, opt_state))
        start, (params, opt_state) = ckpt.restore(like)
        print(f"resumed from step {start}")
    with mesh:
        t0 = time.time()
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, stream.batch(step))
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.tree.map(float, metrics)
                print(f"step {step:5d} loss={m['loss']:.4f} "
                      f"ce={m['ce']:.4f} gnorm={m['grad_norm']:.3f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
            if ckpt and step and step % 50 == 0:
                ckpt.save(step, (params, opt_state))
        if ckpt:
            ckpt.save(args.steps, (params, opt_state))
            ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()
