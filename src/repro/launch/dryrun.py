import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
production shardings on 512 placeholder host devices, record
memory_analysis / cost_analysis / collective schedule, and emit the roofline
terms (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

The XLA_FLAGS line above MUST stay the first statement in this module (jax
locks the device count at first init).
"""
import argparse
import json
import sys
import time
import traceback

import jax

from ..configs import ARCHS, get_config
from ..models.config import SHAPES, shapes_for
from . import roofline as R
from .mesh import make_production_mesh
from .specs import build_cell


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "experiments/dryrun", verbose: bool = True,
             **cell_kw) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    cell = build_cell(cfg, shape_name, mesh, multi_pod, **cell_kw)
    lowered = cell.lower()
    t_lower = time.time() - t0
    with cell.mesh:
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    roof = R.analyze(compiled, n_devices=n_dev,
                     model_flops=R.model_flops_for(cfg, shape), hlo_text=hlo)
    from . import hlo_cost
    transient = hlo_cost.max_transient(hlo)
    # persistent per-device state (sharded args; outputs alias via donation)
    persistent = ma.argument_size_in_bytes + max(
        ma.output_size_in_bytes - ma.alias_size_in_bytes, 0)
    # CPU buffer assignment neither aliases while carries in place nor keeps
    # bf16 buffers bf16 (float normalization promotes them to f32), so
    # cpu_peak is a loose upper bound.  The TPU estimate: exact persistent
    # sharded state (+15% runtime slack) plus the largest transient working
    # set capped at 2GiB (TPU collective-combiner / fusion granularity keeps
    # single working sets far below the CPU pipeline's unbounded fusions;
    # budgets hand-validated for the 340B cells in EXPERIMENTS.md §Dry-run).
    est_peak = 1.15 * persistent + min(transient, 2 * 2 ** 30)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "cpu_peak_bytes": roof.peak_bytes,
            "max_transient_bytes": transient,
            "est_peak_bytes": est_peak,
            "fits_16GB": est_peak <= R.CHIP_HBM,
        },
        "roofline": roof.table_row(),
        "collectives": roof.coll_detail,
        "model_flops": roof.model_flops,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{rec['mesh']}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        m = rec["memory"]
        r = rec["roofline"]
        print(f"[OK] {arch} x {shape_name} x {rec['mesh']}  "
              f"compile={t_compile:.0f}s  "
              f"est_peak={m['est_peak_bytes']/2**30:.2f}GiB "
              f"(cpuBA={(m['cpu_peak_bytes'] or 0)/2**30:.1f}) "
              f"fits={m['fits_16GB']}  dominant={r['dominant']}  "
              f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
              f"coll={r['collective_s']*1e3:.2f}ms useful={r['useful_ratio']:.2f}",
              flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in shapes_for(arch):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, multi_pod=mp, out_dir=args.out)
            except Exception as e:
                failures.append((arch, shape, mp, repr(e)))
                print(f"[FAIL] {arch} x {shape} x "
                      f"{'2x16x16' if mp else '16x16'}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} cell(s) failed")
        sys.exit(1)
    print("\nall cells passed")


if __name__ == "__main__":
    main()
