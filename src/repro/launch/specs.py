"""Per-(arch x shape) abstract inputs + shardings + step builders.

Everything here is ShapeDtypeStruct-based: no device allocation.  These specs
drive the multi-pod dry-run (lower + compile), the roofline analysis, and
they document exactly what tensor travels where for every cell.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import params as P_
from ..models.config import SHAPES, ModelConfig, ShapeConfig
from ..models.sharding import ShardingRules, tree_pspecs
from ..models.transformer import Runtime, forward, init_cache
from ..train.optimizer import OptConfig, init_opt_state, opt_state_pspecs
from ..train.train_step import make_train_step

# global-batch microbatch count for train_4k (per-device micro batch of 1-2)
MICROBATCHES = {
    "nemotron-4-340b": 16, "qwen2.5-14b": 16, "gemma3-12b": 16,
    "minitron-8b": 16, "pixtral-12b": 16, "deepseek-v2-lite-16b": 8,
    "granite-moe-3b-a800m": 8, "rwkv6-1.6b": 8, "hymba-1.5b": 8,
    "whisper-medium": 4,
}
# sequence parallelism: required for nemotron's 18k residual to fit 16GB
SEQ_PARALLEL = {"nemotron-4-340b"}
# int8 optimizer states: required for 340B x AdamW on a 16GB chip
INT8_OPT = {"nemotron-4-340b"}
# bf16 gradient accumulator (Megatron-style): 340B fp32 grads don't fit
BF16_ACCUM = {"nemotron-4-340b"}


def make_rules(cfg: ModelConfig, shape: ShapeConfig, multi_pod: bool,
               *, fsdp: Optional[bool] = None,
               seq_parallel: Optional[bool] = None) -> ShardingRules:
    dp = ("pod", "data") if multi_pod else ("data",)
    if fsdp is None:
        fsdp = True   # params 2D-sharded everywhere (340B must; others cheap)
    if seq_parallel is None:
        seq_parallel = shape.kind in ("train", "prefill") \
            and cfg.name in SEQ_PARALLEL
    return ShardingRules(fsdp=fsdp, expert_parallel=True,
                         seq_parallel=seq_parallel, data_axes=dp,
                         fsdp_vocab_tables=shape.is_train)


def opt_config(cfg: ModelConfig) -> OptConfig:
    return OptConfig(state_dtype="int8" if cfg.name in INT8_OPT else "float32")


def _maybe(axis, size: int, mesh: Mesh):
    """axis name if the dim divides the mesh axis size, else None."""
    if axis is None:
        return None
    n = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        n *= mesh.shape[a]
    return axis if size % n == 0 else None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Dict, Dict]:
    """Training/prefill batch: abstract arrays + PartitionSpecs."""
    B, S = shape.global_batch, shape.seq_len
    bdt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio_stub":
        # seq_len counts encoder frames; decoder runs S/4 text tokens
        Sd = S // 4
        arrs = {"tokens": _sds((B, Sd), jnp.int32),
                "labels": _sds((B, Sd), jnp.int32),
                "enc_embeds": _sds((B, S, cfg.d_model), bdt)}
        specs = {"tokens": P(("dp",), None), "labels": P(("dp",), None),
                 "enc_embeds": P(("dp",), None, None)}
    elif cfg.frontend == "vision_stub":
        arrs = {"tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32),
                "frontend_embeds": _sds((B, cfg.n_frontend_tokens,
                                         cfg.d_model), bdt)}
        specs = {"tokens": P(("dp",), None), "labels": P(("dp",), None),
                 "frontend_embeds": P(("dp",), None, None)}
    else:
        arrs = {"tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32)}
        specs = {"tokens": P(("dp",), None), "labels": P(("dp",), None)}
    return arrs, specs


def _resolve_dp(spec: P, dp: Tuple[str, ...]) -> P:
    """Replace the "dp" placeholder with the actual data axes."""
    out = []
    for e in spec:
        if e == "dp" or e == ("dp",):
            out.append(dp)
        else:
            out.append(e)
    return P(*out)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                dp: Tuple[str, ...], dtype) -> Tuple[Dict, Dict]:
    """Abstract decode cache + PartitionSpecs.

    KV sequence dim shards over "model" (plus "data" too when batch=1, the
    long_500k case) so multi-hundred-GB caches spread across the pod; GSPMD
    turns the softmax over the sharded length into a cheap all-reduce.
    """
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S, dtype=dtype))
    b_ax = _maybe(dp, B, mesh)
    seq_ax = ("data", "model") if b_ax is None else "model"
    h_ax = _maybe("model", cfg.n_heads, mesh)

    def spec_for(path, leaf):
        name = path[-1].key
        if name in ("k", "v", "k_q", "v_q", "k_s", "v_s"):  # (L,B,S,KV,*)
            return P(None, b_ax, _maybe(seq_ax, S, mesh), None, None)
        if name == "lat":               # (L,B,S,lora+r)
            return P(None, b_ax, _maybe(seq_ax, S, mesh), None)
        if name in ("state", "ssm"):    # (L,B,H,K,V)
            return P(None, b_ax, h_ax, None, None)
        if name in ("shift_a", "shift_f"):   # (L,B,d)
            return P(None, b_ax, None)
        if name == "enc_out":           # (B,Se,d)
            return P(b_ax, None, None)
        raise KeyError(name)

    if cfg.arch_kind == "encdec":
        enc_len = 1500 if not shape.is_train else shape.seq_len
        cache = dict(cache)
        cache["enc_out"] = _sds((B, enc_len, cfg.d_model), dtype)
    specs = jax.tree_util.tree_map_with_path(spec_for, cache)
    return cache, specs


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch x shape x mesh) dry-run cell."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    multi_pod: bool
    fn: object                  # the python callable to jit
    abstract_args: tuple        # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: object
    donate: tuple = ()

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate)
        with self.mesh:
            return jitted.lower(*self.abstract_args)


def build_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh,
               multi_pod: bool, *, rules: Optional[ShardingRules] = None,
               opt: Optional[OptConfig] = None,
               microbatches: Optional[int] = None,
               mla_absorb: bool = False) -> Cell:
    shape = SHAPES[shape_name]
    rules = rules or make_rules(cfg, shape, multi_pod)
    dp = rules.data_axes
    ns = lambda s: NamedSharding(mesh, s)
    pspec_tree = tree_pspecs(cfg, mesh, rules)
    rt = Runtime(mesh=mesh, rules=rules, mla_absorb=mla_absorb and
                 shape.kind == "decode")

    if shape.is_train:
        opt = opt or opt_config(cfg)
        mb = microbatches or MICROBATCHES.get(cfg.name, 8)
        params = P_.abstract_params(cfg, dtype=jnp.float32)
        opt_state = jax.eval_shape(lambda: init_opt_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params), opt))
        ospecs = opt_state_pspecs(pspec_tree, opt)
        batch, bspecs = batch_struct(cfg, shape)
        bspecs = jax.tree.map(lambda s: _resolve_dp(s, dp), bspecs,
                              is_leaf=lambda x: isinstance(x, P))
        accum = jnp.bfloat16 if cfg.name in BF16_ACCUM else jnp.float32
        step = make_train_step(cfg, rt, opt, microbatches=mb,
                               accum_dtype=accum)
        in_sh = (jax.tree.map(ns, pspec_tree,
                              is_leaf=lambda x: isinstance(x, P)),
                 jax.tree.map(ns, ospecs,
                              is_leaf=lambda x: isinstance(x, P)),
                 jax.tree.map(ns, bspecs,
                              is_leaf=lambda x: isinstance(x, P)))
        out_sh = (in_sh[0], in_sh[1], None)
        return Cell(cfg, shape, mesh, multi_pod, step,
                    (params, opt_state, batch), in_sh, out_sh, donate=(0, 1))

    # ---- inference cells: params in bf16
    bdt = jnp.dtype(cfg.dtype)
    params = P_.abstract_params(cfg, dtype=bdt)
    psh = jax.tree.map(ns, pspec_tree, is_leaf=lambda x: isinstance(x, P))
    B, S = shape.global_batch, shape.seq_len
    b_ax = _maybe(dp, B, mesh)

    if shape.kind == "prefill":
        batch, bspecs = batch_struct(cfg, shape)
        bspecs = jax.tree.map(lambda s: _resolve_dp(s, dp), bspecs,
                              is_leaf=lambda x: isinstance(x, P))
        cache_sh_tree, cspecs = cache_specs(cfg, shape, mesh, dp, bdt)

        def prefill(params, batch):
            extras = {k: v for k, v in batch.items()
                      if k in ("enc_embeds", "frontend_embeds")}
            toks = batch["tokens"]
            smax = S + cfg.n_frontend_tokens   # vision prefix extends seq
            if cfg.frontend == "audio_stub":
                smax = S // 4                  # decoder tokens
            cache = init_cache(cfg, toks.shape[0], smax, dtype=bdt)
            if cfg.arch_kind == "encdec":
                cache["enc_out"] = None
                cache = {k: v for k, v in cache.items() if v is not None}
            logits, cache, _ = forward(params, cfg, rt, toks, mode="prefill",
                                       cache=cache, cache_pos=0, **extras)
            return logits[:, -1], cache
        in_sh = (psh, jax.tree.map(ns, bspecs,
                                   is_leaf=lambda x: isinstance(x, P)))
        out_sh = (ns(P(b_ax, None)),
                  jax.tree.map(ns, cspecs, is_leaf=lambda x: isinstance(x, P)))
        return Cell(cfg, shape, mesh, multi_pod, prefill, (params, batch),
                    in_sh, out_sh)

    # ---- decode: one new token against a seq_len cache
    cache, cspecs = cache_specs(cfg, shape, mesh, dp, bdt)
    toks = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)

    def decode(params, tokens, cache, cache_pos):
        logits, cache, _ = forward(params, cfg, rt, tokens, mode="decode",
                                   cache=cache, cache_pos=cache_pos)
        return logits[:, 0], cache

    csh = jax.tree.map(ns, cspecs, is_leaf=lambda x: isinstance(x, P))
    in_sh = (psh, ns(P(b_ax, None)), csh, ns(P()))
    out_sh = (ns(P(b_ax, None)), csh)
    return Cell(cfg, shape, mesh, multi_pod, decode,
                (params, toks, cache, pos), in_sh, out_sh, donate=(2,))
