"""``Workload`` and ``Setting``: what gets packed, and what the policy is
told about durations.

A ``Workload`` is anything that yields a labeled list of DVBP
``Instance``s plus the mapping from an information ``Setting`` to the
prediction model replayed on-device:

  * ``synthetic("azure" | "huawei", ...)`` - the paper's generated suites
    (wraps ``sweep.grid.SuiteSpec``, so store keys are unchanged).
  * ``azure_trace(trace_root, ...)`` - the real Azure Packing2020 dump.
  * ``instances([...])`` - prebuilt ``Instance`` lists (what the
    benchmarks feed in).
  * ``serving_requests(requests, caps, tps)`` - the serving adapter: a
    ``serving.Request`` stream becomes one DVBP instance whose items are
    requests (size = <slot, KV, prefill> demand vector from
    ``Request.size(caps)``, duration = decode_len / tps, predicted
    duration = predicted_decode_len / tps), so fleet capacity planning
    replays through the same padded ``InstanceBatch`` lanes / batched
    scan as the experiment grids and lands in the same sweep store.

A ``Setting`` makes the paper's three information regimes explicit
instead of smuggling them through pdeps conventions:

  * ``Setting.nonclairvoyant()`` - durations hidden.  For serving
    workloads this replays with pdep == arrival, exactly the
    ``DVBPScheduler`` behavior when no prediction is attached.  Suite
    workloads cannot hide durations from policies that read the
    predicted-departure clock, so ``Experiment`` rejects that
    combination instead of returning clairvoyant numbers under a
    nonclairvoyant label.
  * ``Setting.clairvoyant()`` - real durations revealed.
  * ``Setting.predicted(model)`` - learning-augmented: a
    ``sweep.grid.PredModel`` ("lognormal"/"uniform" + parameter), or -
    for serving workloads - ``model=None`` to replay the predictions
    already attached to the requests (``fleet.attach_predictions``).

Workloads that cannot be rebuilt from a declarative spec (request
streams, prebuilt instances) register their payload in a process-local
registry keyed by a content digest; the digest is part of the workload's
frozen spec, so store caching stays sound: identical content hits the
same store file, and fully-cached runs never need the registry at all.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..consolidate import ConsolidationSpec
from ..core.types import Instance
from ..serving.scheduler import ReplicaCapacity, Request
from ..sweep.grid import PredModel, SuiteSpec

# ---------------------------------------------------------------------------
# Settings
# ---------------------------------------------------------------------------

SETTING_KINDS = ("nonclairvoyant", "clairvoyant", "predicted")


@dataclasses.dataclass(frozen=True)
class Setting:
    """One information regime (see module docstring), optionally with a
    consolidation scenario attached (``with_consolidation``): the same
    information regime replayed with threshold-triggered migrations."""

    kind: str = "clairvoyant"
    model: Optional[PredModel] = None   # predicted only; None == attached
    consolidation: ConsolidationSpec = ConsolidationSpec()

    def __post_init__(self):
        assert self.kind in SETTING_KINDS, self.kind
        if self.model is not None:
            assert self.kind == "predicted", \
                f"only Setting.predicted takes a model (got {self.kind})"
            assert self.model.noisy, \
                "Setting.predicted needs a noisy PredModel " \
                "(lognormal/uniform); use clairvoyant()/nonclairvoyant() " \
                "for the exact settings"

    def with_consolidation(self,
                           cons: "ConsolidationSpec | str") -> "Setting":
        """The same setting with consolidation enabled, e.g.
        ``Setting.clairvoyant().with_consolidation("underload:t0.25")``."""
        if isinstance(cons, str):
            cons = ConsolidationSpec.parse(cons)
        return dataclasses.replace(self, consolidation=cons)

    @classmethod
    def nonclairvoyant(cls) -> "Setting":
        return cls("nonclairvoyant")

    @classmethod
    def clairvoyant(cls) -> "Setting":
        return cls("clairvoyant")

    @classmethod
    def predicted(cls, model: Optional[Union[PredModel, str]] = None,
                  param: float = 0.0) -> "Setting":
        """``model``: a PredModel, a kind string ("lognormal"/"uniform",
        with ``param``), or None = the workload's own attached
        predictions (serving request streams)."""
        if isinstance(model, str):
            model = PredModel(model, param)
        return cls("predicted", model)

    @classmethod
    def parse(cls, s: "Setting | str") -> "Setting":
        if isinstance(s, Setting):
            return s
        base, _, cons = s.partition("+")
        if base in ("nonclairvoyant", "clairvoyant"):
            out = cls(base)
        elif base == "predicted":
            out = cls.predicted()
        else:
            raise KeyError(f"unknown setting {s!r}; known: {SETTING_KINDS} "
                           "(predicted variants need Setting.predicted(...); "
                           "'+consspec' attaches consolidation)")
        return out.with_consolidation(cons) if cons else out

    def label(self) -> str:
        base = self.kind if self.kind != "predicted" else \
            "predicted:" + (self.model.label() if self.model else
                            "attached")
        if self.consolidation.enabled:
            base += f"+{self.consolidation.canonical()}"
        return base


# ---------------------------------------------------------------------------
# Duck-typed prediction models (run_sweep only reads .noisy / .label() /
# .durations(inst, seeds))
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ZeroPredictions:
    """pdep == arrival for every item: the serving scheduler's
    non-clairvoyant replay (``DVBPScheduler`` feeds ``now`` into the
    indicated-close clock when no prediction is attached)."""

    kind: str = "nonclairvoyant"

    noisy = False

    def label(self) -> str:
        return "nonclairvoyant"

    def durations(self, inst: Instance, seeds) -> np.ndarray:
        return np.zeros(inst.n_items)


@dataclasses.dataclass(frozen=True)
class AttachedPredictions:
    """The predicted durations carried by the workload's own payload
    (e.g. ``Request.predicted_decode_len`` / ``fleet.attach_predictions``),
    resolved per instance from the workload registry."""

    digest: str
    kind: str = "attached"

    noisy = False

    def label(self) -> str:
        return "attached"

    def durations(self, inst: Instance, seeds) -> np.ndarray:
        pdur = _REGISTRY[self.digest].attached.get(inst.name)
        assert pdur is not None, \
            f"workload {self.digest} carries no attached predictions for " \
            f"{inst.name!r} (did you attach_predictions / set " \
            "predicted_decode_len?)"
        return pdur


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Workload:
    """Base: maps to one duck-``SuiteSpec`` (anything with ``build()`` /
    ``label()`` / ``n_instances`` that is a dataclass hashes canonically)
    plus the Setting -> prediction-model mapping."""

    def suite(self):
        raise NotImplementedError

    def label(self) -> str:
        return self.suite().label()

    def pred_model(self, setting: Setting):
        setting = Setting.parse(setting)
        if setting.kind == "nonclairvoyant":
            return PredModel("none")
        if setting.kind == "clairvoyant":
            return PredModel("clairvoyant")
        assert setting.model is not None, \
            f"{type(self).__name__} has no attached predictions; " \
            "Setting.predicted needs an explicit PredModel " \
            "(e.g. Setting.predicted('lognormal', 1.0))"
        return setting.model


@dataclasses.dataclass(frozen=True)
class SuiteWorkload(Workload):
    """A declarative suite family (synthetic generators or the real
    trace): delegates to ``sweep.grid.SuiteSpec`` unchanged, so result
    keys and store files are identical to legacy ``run_sweep`` runs."""

    spec: SuiteSpec = SuiteSpec()

    def suite(self) -> SuiteSpec:
        return self.spec


def synthetic(family: str = "azure", n_instances: int = 6,
              n_items: int = 500, seed: int = 2026) -> SuiteWorkload:
    return SuiteWorkload(SuiteSpec(family, n_instances, n_items, seed))


def azure_trace(trace_root: str = "data/azure", n_instances: int = 0,
                n_items: int = 0) -> SuiteWorkload:
    return SuiteWorkload(SuiteSpec("azure_trace", n_instances, n_items,
                                   seed=0, trace_root=trace_root))


# ---- runtime-payload workloads (instances / request streams) --------------

@dataclasses.dataclass(frozen=True)
class _Payload:
    instances: Tuple[Instance, ...]
    attached: Dict[str, np.ndarray]   # instance name -> predicted durations


_REGISTRY: Dict[str, _Payload] = {}


def _digest_arrays(parts, names=()) -> str:
    """Content digest over arrays AND instance names - records are keyed
    by instance name, so same-array/different-name workloads must not
    collide in the registry."""
    h = hashlib.sha256()
    for n in names:
        h.update(str(n).encode() + b"\0")
    for p in parts:
        a = np.ascontiguousarray(np.asarray(p, np.float64))
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class RuntimeWorkload(Workload):
    """A workload whose instances exist only in this process, pinned to a
    content digest.  Doubles as its own duck-``SuiteSpec``."""

    family: str = "instances"
    name: str = "instances"
    digest: str = ""
    n_instances: int = 0

    def suite(self):
        return self

    def build(self) -> List[Instance]:
        payload = _REGISTRY.get(self.digest)
        assert payload is not None, \
            f"workload {self.label()} is not registered in this process " \
            "(runtime workloads rebuild from their in-memory payload; " \
            "fully store-cached runs do not need it)"
        return list(payload.instances)

    def label(self) -> str:
        return f"{self.family}-{self.name}-{self.digest[:8]}"

    def pred_model(self, setting: Setting):
        setting = Setting.parse(setting)
        if setting.kind == "predicted" and setting.model is None:
            assert _REGISTRY[self.digest].attached, \
                f"{self.label()} carries no attached predictions"
            return AttachedPredictions(self.digest)
        if setting.kind == "nonclairvoyant" and self.family == "serving":
            return ZeroPredictions()
        return Workload.pred_model(self, setting)


def instances(insts: Sequence[Instance], name: str = "adhoc",
              attached: Optional[Dict[str, np.ndarray]] = None
              ) -> RuntimeWorkload:
    """Wrap prebuilt ``Instance``s as a workload (the benchmarks' path)."""
    insts = tuple(insts)
    assert insts, "instances() needs at least one Instance"
    attached = dict(attached or {})
    digest = _digest_arrays(
        [a for i in insts for a in (i.sizes, i.arrivals, i.departures)] +
        [attached[k] for k in sorted(attached)],
        names=[i.name for i in insts] + sorted(attached))
    _REGISTRY.setdefault(digest, _Payload(insts, attached))
    return RuntimeWorkload("instances", name, digest, len(insts))


def requests_to_instance(reqs: Sequence[Request],
                         caps: ReplicaCapacity = ReplicaCapacity(),
                         tps: float = 50.0, name: str = "requests"
                         ) -> Tuple[Instance, Optional[np.ndarray]]:
    """Convert one request stream to (Instance, attached predicted
    durations or None): item size = ``Request.size(caps)``, interval =
    [arrival, arrival + decode_len / tps), predicted duration =
    predicted_decode_len / tps when every request carries one.  The
    stable arrival sort matches ``simulate_fleet``'s processing order."""
    assert len(reqs) > 0, "empty request stream"
    order = np.argsort([r.arrival for r in reqs], kind="stable")
    reqs = [reqs[i] for i in order]
    sizes = np.stack([r.size(caps) for r in reqs])
    arr = np.asarray([r.arrival for r in reqs], float)
    dur = np.asarray([r.decode_len for r in reqs], float) / tps
    inst = Instance(sizes, arr, arr + dur, name)
    pred = None
    if all(r.predicted_decode_len is not None for r in reqs):
        pred = np.asarray([r.predicted_decode_len for r in reqs],
                          float) / tps
    return inst, pred


def serving_requests(streams: Union[Sequence[Request],
                                    Sequence[Sequence[Request]]],
                     caps: ReplicaCapacity = ReplicaCapacity(),
                     tps: float = 50.0, name: str = "serving"
                     ) -> RuntimeWorkload:
    """The serving adapter: one or more ``Request`` streams become DVBP
    instances (one lane each) that replay through the batched scan -
    fleet capacity planning on the sweep engine, results in the sweep
    store.  ``Experiment`` over this workload reproduces
    ``serving.fleet.simulate_fleet`` usage/bins decision-for-decision
    (tests/test_api.py)."""
    assert len(streams) > 0, "serving_requests needs at least one stream"
    if isinstance(streams[0], Request):
        streams = [list(streams)]
    insts, attached = [], {}
    for k, stream in enumerate(streams):
        iname = f"{name}_{k:02d}" if len(streams) > 1 else name
        inst, pred = requests_to_instance(stream, caps, tps, iname)
        insts.append(inst)
        if pred is not None:
            attached[iname] = pred
    digest = _digest_arrays(
        [a for i in insts for a in (i.sizes, i.arrivals, i.departures)] +
        ([attached[i.name] for i in insts if i.name in attached]) +
        [np.asarray([caps.slots, caps.kv_tokens, caps.prefill_budget, tps])],
        names=[i.name for i in insts] + sorted(attached))
    _REGISTRY.setdefault(digest, _Payload(tuple(insts), attached))
    return RuntimeWorkload("serving", name, digest, len(insts))


def workload(kind: str = "azure", **kw) -> Workload:
    """String-dispatch convenience: ``workload("azure", n_items=500)``."""
    if kind in ("azure", "huawei"):
        return synthetic(kind, **kw)
    if kind == "azure_trace":
        return azure_trace(**kw)
    raise KeyError(f"unknown workload kind {kind!r}; use synthetic / "
                   "azure_trace / instances / serving_requests")


def stream_source(wl: Union[Workload, Instance], instance: Union[int, str] = 0,
                  setting: "Setting | str" = "clairvoyant", seed: int = 0):
    """One instance of a workload as a bounded-memory request source for
    ``repro.stream.replay_stream`` - the API-level on-ramp to streamed
    full-trace replay.

    ``instance`` selects by build index or instance name; ``setting``
    resolves predicted departures exactly as ``Experiment`` would
    (clairvoyant -> real departures, predicted -> the workload's model at
    ``seed``).  Note the source wraps a *built* instance: for traces too
    large to materialize at all, construct ``repro.stream.CsvSource``
    directly on the raw CSV instead."""
    from ..stream import InstanceSource
    if isinstance(wl, Instance):
        return InstanceSource(wl)
    insts = wl.suite().build()
    if isinstance(instance, str):
        picked = [i for i in insts if i.name == instance]
        assert picked, f"no instance {instance!r} in {wl.label()}: " \
                       f"{[i.name for i in insts]}"
        inst = picked[0]
    else:
        inst = insts[int(instance)]
    model = wl.pred_model(Setting.parse(setting))
    pdur = None if model is None else model.durations(inst, (seed,))
    if pdur is None:                # exact settings: real departures
        return InstanceSource(inst)
    pdur = np.asarray(pdur)
    if pdur.ndim == 2:              # (n_seeds, n_items) noisy models
        pdur = pdur[0]
    return InstanceSource(inst, predicted_durations=pdur)


__all__ = ["Setting", "Workload", "SuiteWorkload", "RuntimeWorkload",
           "synthetic", "azure_trace", "instances", "serving_requests",
           "requests_to_instance", "stream_source", "workload",
           "ZeroPredictions", "AttachedPredictions"]
