"""One-line, once-per-process deprecation breadcrumbs for legacy entry
points that now shim onto ``repro.api``.

Kept import-free (stdlib only) so legacy modules can call it without
creating an import cycle with the api package.  Every message carries the
grep-able ``REPRO_API_MIGRATION`` tag.
"""
from __future__ import annotations

import warnings
from typing import Set

_WARNED: Set[str] = set()


def warn_legacy(old: str, new: str) -> None:
    """Emit the migration warning for ``old`` once per process."""
    if old in _WARNED:
        return
    _WARNED.add(old)
    warnings.warn(
        f"REPRO_API_MIGRATION: {old} is a legacy entry point kept as a "
        f"thin shim; use {new} (see repro.api)",
        DeprecationWarning, stacklevel=3)
