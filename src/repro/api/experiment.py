"""``Experiment`` / ``Results``: the single way to run anything.

One workload x one policy x one information setting -> one comparable
usage-time ratio (the paper's Eq. (1) performance ratio).  ``Experiment``
is a facade over the batched sweep engine (``sweep.runner.run_batch`` via
``sweep.grid.run_sweep``): it expands (workloads x policies x settings x
seeds), replays every cell as batched scan lanes on the selected backend,
caches per-(instance, policy, prediction, seed) records in the
``SweepStore`` (legacy ``result_key`` strings are preserved, so existing
store files keep resolving), and returns tidy records plus box-stat
summaries.

    from repro import api
    exp = api.Experiment(api.synthetic("azure", 6, 500),
                         policies=("first_fit", "greedy", "cbd_beta2"),
                         settings=(api.Setting.clairvoyant(),
                                   api.Setting.predicted("lognormal", 1.0)),
                         seeds=(0, 1))
    res = exp.run(store="experiments/sweeps")
    for row in res.summary_rows():
        print(row)
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .. import obs
from ..core.jaxsim import CapacityError
from ..core.metrics import BoxStats
from ..obs.trace import ReplayTrace
from ..sweep.grid import SweepSpec, run_sweep, summarize_sweep
from ..sweep.store import SweepStore
from .policy import Policy
from .workload import Setting, Workload

DEFAULT_STORE = "experiments/sweeps"


@dataclasses.dataclass
class Results:
    """Per-(workload, policy, setting, instance, seed) records.

    ``records`` keeps the legacy ``result_key`` -> record mapping (the
    sweep-store schema); ``rows()`` returns the tidy per-record view with
    explicit ``workload`` / ``setting`` columns; ``summary()`` aggregates
    Eq. (1) ratios into box stats per (workload, policy, setting).

    ``metrics`` is the obs-counter delta of the producing ``run()`` (cache
    hits/misses, jit retraces, device-transfer bytes, ... - see the
    glossary in sweep/README.md); ``traces`` maps ``result_key`` ->
    single-lane ``obs.ReplayTrace`` when the run asked for
    ``trace_level >= 1``."""

    records: Dict[str, Dict]
    _workload_by_suite: Dict[str, str]
    _setting_by_pred: Dict[Tuple[str, str, str], str]
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    traces: Dict[str, ReplayTrace] = dataclasses.field(default_factory=dict)

    def rows(self) -> List[Dict]:
        out = []
        for key in sorted(self.records):
            r = dict(self.records[key])
            r["workload"] = self._workload_by_suite.get(r["suite"],
                                                        r["suite"])
            r["setting"] = self._setting_by_pred.get(
                (r["suite"], r["pred"], r.get("consolidate", "none")),
                r["pred"])
            out.append(r)
        return out

    def summary(self) -> Dict[Tuple[str, str, str], BoxStats]:
        """(workload, policy, setting) -> BoxStats over ratios."""
        groups: Dict[Tuple[str, str, str], List[float]] = {}
        for r in self.rows():
            groups.setdefault((r["workload"], r["policy"], r["setting"]),
                              []).append(r["ratio"])
        return {k: BoxStats.from_ratios(v) for k, v in
                sorted(groups.items())}

    def summary_rows(self) -> List[str]:
        return [f"{w:<24} {p:<18} {s:<22} n={st.n:<4} mean={st.mean:.4f} "
                f"median={st.median:.4f} q1={st.q1:.4f} q3={st.q3:.4f}"
                for (w, p, s), st in self.summary().items()]

    def ratios(self, policy: Optional[str] = None,
               workload: Optional[str] = None,
               setting: Optional[str] = None,
               instance: Optional[str] = None) -> List[float]:
        return [r["ratio"] for r in self.rows()
                if (policy is None or r["policy"] == policy)
                and (workload is None or r["workload"] == workload)
                and (setting is None or r["setting"] == setting)
                and (instance is None or r["instance"] == instance)]

    def usage_total(self, **filters) -> float:
        keep = {k: v for k, v in filters.items() if v is not None}
        return sum(r["usage_time"] for r in self.rows()
                   if all(r[k] == v for k, v in keep.items()))

    def merge(self, other: "Results") -> "Results":
        self.records.update(other.records)
        self._workload_by_suite.update(other._workload_by_suite)
        self._setting_by_pred.update(other._setting_by_pred)
        for k, v in other.metrics.items():
            self.metrics[k] = self.metrics.get(k, 0) + v
        self.traces.update(other.traces)
        return self


@dataclasses.dataclass(frozen=True)
class Experiment:
    """The declarative experiment: workloads x policies x settings."""

    workloads: Union[Workload, Sequence[Workload]]
    policies: Sequence[Union[Policy, str]] = ("first_fit",)
    settings: Sequence[Union[Setting, str]] = (Setting.clairvoyant(),)
    seeds: Sequence[int] = (0,)
    max_bins: int = 64
    max_bins_cap: int = 8192

    def __post_init__(self):
        wl = self.workloads
        if isinstance(wl, Workload):
            wl = (wl,)
        object.__setattr__(self, "workloads", tuple(wl))
        object.__setattr__(self, "policies",
                           tuple(Policy.parse(p) for p in self.policies))
        object.__setattr__(self, "settings",
                           tuple(Setting.parse(s) for s in self.settings))
        object.__setattr__(self, "seeds", tuple(int(s) for s in self.seeds))
        for p in self.policies:
            assert p.scan, \
                f"{p.name!r} has no batched scan lane (host-only); run it " \
                "through core.run / the oracle engine instead"
        # Suite workloads have no way to hide durations from policies that
        # read the predicted-departure clock (the engine's "none" model
        # feeds them the real departures, i.e. clairvoyant numbers), so a
        # nonclairvoyant cell with such a policy is an error, not a
        # silently mislabeled result.  Serving workloads are exempt: they
        # replay nonclairvoyant with pdep == arrival (the scheduler's
        # actual no-prediction behavior).
        for wl in self.workloads:
            for s in self.settings:
                if getattr(wl.pred_model(s), "kind", "") == "none":
                    bad = [p.name for p in self.policies
                           if p.needs_predictions]
                    if bad:
                        raise ValueError(
                            f"Setting.nonclairvoyant() hides durations, "
                            f"but {bad} read the predicted-departure "
                            f"clock on {wl.label()!r}; use "
                            "Setting.clairvoyant() or Setting.predicted()")

    def spec_for(self, *workloads: Workload) -> SweepSpec:
        """The engine-level SweepSpec the given workloads expand to
        (suites and prediction models are the workloads' own duck types,
        so legacy suites hashes / result keys are preserved for
        SuiteSpec-backed workloads).  All workloads must map the
        experiment's settings to the same prediction models."""
        preds = {tuple(wl.pred_model(s) for s in self.settings)
                 for wl in workloads}
        assert len(preds) == 1, "workloads disagree on prediction models"
        # dedup prediction models AND consolidation scenarios, preserving
        # order; settings mixing both axes expand to the cross product in
        # run_sweep and run()'s keep-filter trims back to the requested
        # (pred, consolidation) pairs
        pred_list = list(OrderedDict.fromkeys(preds.pop()))
        cons = tuple(OrderedDict.fromkeys(
            s.consolidation for s in self.settings))
        return SweepSpec(
            suites=tuple(wl.suite() for wl in workloads),
            policies=tuple(p.name for p in self.policies),
            predictions=tuple(pred_list),
            seeds=self.seeds, max_bins=self.max_bins,
            max_bins_cap=self.max_bins_cap,
            consolidations=cons)

    def _spec_groups(self):
        """Workloads sharing prediction models run as ONE multi-suite
        SweepSpec - the same spec (and therefore the same store file /
        suites hash) a legacy multi-suite ``run_sweep`` produced, so
        stores written by either entry point resolve for the other.
        Workloads with their own prediction mapping (e.g. serving streams
        with attached predictions) get their own spec."""
        groups: "OrderedDict[Tuple, List[Workload]]" = OrderedDict()
        for wl in self.workloads:
            key = tuple(wl.pred_model(s) for s in self.settings)
            groups.setdefault(key, []).append(wl)
        return [(self.spec_for(*wls), wls) for wls in groups.values()]

    def run(self, store: Union[None, str, SweepStore] = None,
            force: bool = False, progress=None,
            backend: Optional[str] = None, shard: str = "auto",
            block_events: int = 0, trace_level: int = 0,
            checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 2048) -> Results:
        """Run (or resolve from the store) every cell of the grid.

        ``store``: a ``SweepStore``, a directory path, or None (no
        persistence).  ``backend`` / ``shard`` / ``block_events`` pick the
        replay engine, lane sharding and event-block size exactly as in
        ``run_batch`` - execution arguments, never part of the cached
        identity.  ``trace_level`` >= 1 replays every cell with per-event
        decision traces captured into ``Results.traces`` (cells recompute
        even when cached - the trace only exists by replaying).

        ``checkpoint_dir`` enables mid-replay checkpoint/resume
        (``resilience.checkpoint``): the scan carry is snapshotted every
        ``checkpoint_every`` events so a killed run resumes bit-identically.

        The returned ``Results.metrics`` holds the obs-counter deltas of
        this call (always on - no ``obs.enable()`` needed)."""
        if isinstance(store, str):
            store = SweepStore(store)
        res = Results({}, {}, {})
        polnames = {p.name for p in self.policies}
        counters0 = obs.counters()
        with obs.span("experiment.run", cells=len(self.workloads) *
                      len(self.policies) * len(self.settings)):
            for spec, wls in self._spec_groups():
                traces: Dict[str, ReplayTrace] = {}
                records = run_sweep(spec, store=store, force=force,
                                    progress=progress, backend=backend,
                                    shard=shard, block_events=block_events,
                                    trace_level=trace_level, traces=traces,
                                    checkpoint_dir=checkpoint_dir,
                                    checkpoint_every=checkpoint_every)
                # run_sweep returns everything the shared store file holds
                # for these suites; Results only reports THIS experiment's
                # cells - exactly the requested (pred, consolidation)
                # pairs, not the engine's cross product
                want = {(wl.suite().label(), wl.pred_model(s).label(),
                         s.consolidation.canonical())
                        for wl in wls for s in self.settings}
                keep = lambda r: ((r["suite"], r["pred"],
                                   r.get("consolidate", "none")) in want
                                  and r["policy"] in polnames
                                  and r["seed"] in self.seeds)
                records = {k: r for k, r in records.items() if keep(r)}
                wlmap = {wl.suite().label(): wl.label() for wl in wls}
                for r in records.values():
                    if r["overflowed"]:
                        raise CapacityError(
                            f"slot pool exhausted at max_bins="
                            f"{r['max_bins']} (cap {self.max_bins_cap}) "
                            f"for workload "
                            f"{wlmap.get(r['suite'], r['suite'])!r} "
                            f"instance {r['instance']!r}, policy "
                            f"{r['policy']!r}, setting {r['pred']!r}"
                            + (f"+{r['consolidate']}"
                               if "consolidate" in r else "")
                            + "; raise max_bins_cap or shrink the "
                            "workload",
                            policy=r["policy"], max_bins=r["max_bins"],
                            instance=r["instance"])
                res.merge(Results(
                    records,
                    wlmap,
                    {(wl.suite().label(), wl.pred_model(s).label(),
                      s.consolidation.canonical()): s.label()
                     for wl in wls for s in self.settings},
                    traces={k: t for k, t in traces.items()
                            if k in records}))
        res.metrics = obs.counter_deltas(counters0)
        return res


def run_experiment(workloads, policies, settings=(Setting.clairvoyant(),),
                   seeds=(0,), store: Union[None, str, SweepStore] = None,
                   **run_kw) -> Results:
    """One-call convenience wrapper around ``Experiment(...).run(...)``."""
    return Experiment(workloads, policies, settings, seeds).run(
        store=store, **run_kw)


__all__ = ["Experiment", "Results", "run_experiment", "summarize_sweep",
           "DEFAULT_STORE"]
