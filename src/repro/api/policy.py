"""``Policy``: first-class policy objects replacing stringly-typed names.

Every placement policy the repo knows - the 8 score-based Any Fit
policies, the category-structured families (CBD/CBDT, Hybrid variants,
RCP/PPE, Lifetime Alignment, the adaptive switch, parametric variants
included) and the host-only extras (``next_fit``, ``rr_next_fit``) - is
one frozen ``Policy`` value:

  * ``Policy.parse("cbd_beta4")`` / ``str(policy)`` round-trip the
    canonical scan-policy string, with the parameter range validated at
    parse time (``core.jaxsim.policy_spec`` raises ValueError for
    "cbd_beta-1", "cbdt_rho0", "adaptive_8_2", ...).
  * Structured parameters (``beta``, ``rho``, adaptive ``low``/``high``,
    best-fit ``norm``, lifetime-alignment ``mode``) are fields, not
    substrings.
  * Capability flags say where the policy can run: ``scan`` (batched
    replay lanes on any ``jaxsim.BACKENDS`` backend), ``category``
    (carries category state in the scan), ``device_select`` (the serving
    scheduler's fused on-device select), ``needs_predictions`` (reads the
    predicted-departure clock).
  * ``Policy.from_registry(name, **kwargs)`` maps an algorithm-zoo
    registry entry to its scan lane (or None when only the host oracle
    can run it) - the single mapping ``benchmarks/common.py`` and the
    serving scheduler used to each re-implement.

``policies()`` enumerates the registry for introspection.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from ..core.jaxsim import (CATEGORY_POLICIES, POLICIES, SCAN_POLICIES,
                           policy_spec)

# Host-only registry policies: no batched scan lane, the oracle engine is
# their only executor.
HOST_ONLY_POLICIES = ("next_fit", "rr_next_fit")

# Scan policies whose serving-scheduler decision can run through the fused
# on-device select (kernels.ops.fitscore_select): the whole score family
# plus the class-masked First Fit of CBD/CBDT.
_DEVICE_FAMILIES = ("score", "cbd", "cbdt")


@dataclasses.dataclass(frozen=True)
class Policy:
    """One placement policy: canonical name + structured parameters +
    capability flags.  Construct via ``parse``/``from_registry``."""

    name: str                       # canonical string; round-trips parse()
    family: str                     # score|cbd|cbdt|hybrid|rcp|la|adaptive|host
    norm: Optional[str] = None      # best_fit residual norm
    beta: Optional[float] = None    # cbd duration base
    rho: Optional[float] = None     # cbdt window width (seconds)
    low: Optional[float] = None     # adaptive regime thresholds
    high: Optional[float] = None
    mode: Optional[str] = None      # lifetime-alignment class structure
    scan: bool = True               # replays as batched scan lanes
    category: bool = False          # category-structured (carried state)
    device_select: bool = False     # serving on-device fused select
    needs_predictions: bool = False  # reads the predicted-departure clock

    def __str__(self) -> str:
        return self.name

    @classmethod
    def parse(cls, name: "Policy | str") -> "Policy":
        """Parse a policy name (parametric variants included).  Raises
        KeyError for unknown names and ValueError - naming the valid
        range - for out-of-range parameters.  Idempotent on ``Policy``."""
        if isinstance(name, Policy):
            return name
        if name in HOST_ONLY_POLICIES:
            return cls(name, "host", scan=False)
        spec = policy_spec(name)   # validates; raises KeyError/ValueError
        kw: Dict = {}
        if spec.family == "score":
            if name.startswith("best_fit_"):
                kw["norm"] = name.split("_")[-1]
            kw["needs_predictions"] = name in ("greedy", "nrt_standard",
                                               "nrt_prioritized")
        elif spec.family == "cbd":
            kw.update(beta=spec.beta, needs_predictions=True)
        elif spec.family == "cbdt":
            kw.update(rho=spec.rho, needs_predictions=True)
        elif spec.family == "la":
            kw.update(mode=spec.la_mode, needs_predictions=True)
        elif spec.family == "adaptive":
            kw.update(low=spec.low, high=spec.high, needs_predictions=True)
        else:   # hybrid / rcp: parameterless names
            kw["needs_predictions"] = True
        return cls(name, spec.family,
                   category=spec.family != "score",
                   device_select=spec.family in _DEVICE_FAMILIES, **kw)

    # ------------------------------------------------- host-registry bridge
    def registry_args(self) -> Tuple[str, Dict]:
        """(algorithm-zoo registry name, kwargs) for the equivalent host
        oracle algorithm - the parity reference."""
        if self.family == "score" and self.norm is not None:
            return "best_fit", {"norm": self.norm}
        if self.family == "cbd":
            return "cbd", {"beta": self.beta}
        if self.family == "cbdt":
            return "cbdt", {"rho": self.rho}
        if self.family == "la":
            return "lifetime_alignment", {"mode": self.mode}
        if self.family == "adaptive":
            return "adaptive", {"low": self.low, "high": self.high}
        return self.name, {}

    def host_algorithm(self):
        """A fresh host oracle algorithm instance for this policy."""
        from ..core.algorithms import get_algorithm
        name, kw = self.registry_args()
        return get_algorithm(name, **kw)

    @classmethod
    def from_registry(cls, name: str, **kwargs) -> Optional["Policy"]:
        """The inverse bridge: scan ``Policy`` for an algorithm-registry
        (name, kwargs) pair, or None when the combination has no batched
        lane (host-only policies and exotic kwargs stay on the oracle)."""
        if name == "best_fit" and set(kwargs) <= {"norm"}:
            return cls.parse(f"best_fit_{kwargs.get('norm', 'linf')}")
        if name == "cbd" and set(kwargs) <= {"beta"}:
            return cls.parse(f"cbd_beta{kwargs.get('beta', 2.0):g}")
        if name == "cbdt" and set(kwargs) <= {"rho"} and "rho" in kwargs:
            return cls.parse(f"cbdt_rho{kwargs['rho']:g}")
        if name == "lifetime_alignment" and set(kwargs) <= {"mode"}:
            return cls.parse(f"la_{kwargs.get('mode', 'binary')}")
        if name == "adaptive" and set(kwargs) <= {"low", "high"}:
            if kwargs:
                return cls.parse(f"adaptive_{kwargs.get('low', 2.0):g}"
                                 f"_{kwargs.get('high', 16.0):g}")
            return cls.parse("adaptive")
        if not kwargs:
            try:
                return cls.parse(name)
            except KeyError:
                return None
        return None


def policies(include_host_only: bool = True) -> Tuple[Policy, ...]:
    """The policy registry: every non-parametric policy the repo ships
    (parametric variants - cbd_beta4, cbdt_rho3600, adaptive_2_8 - parse
    on demand via ``Policy.parse``)."""
    names = SCAN_POLICIES + (HOST_ONLY_POLICIES if include_host_only else ())
    return tuple(Policy.parse(n) for n in names)


def policy_names(include_host_only: bool = False) -> Tuple[str, ...]:
    return tuple(p.name for p in policies(include_host_only))


__all__ = ["Policy", "policies", "policy_names", "HOST_ONLY_POLICIES",
           "POLICIES", "CATEGORY_POLICIES", "SCAN_POLICIES"]
