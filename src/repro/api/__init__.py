"""repro.api - the one experiment API.

The paper's whole empirical matrix is (workload x policy x information
setting) -> usage-time ratio; this package is the single public surface
for running any cell of it, batched, on any backend:

  * ``Policy`` - first-class policy objects (``Policy.parse`` /
    ``str(policy)`` round-trip, structured params, capability flags,
    ``policies()`` registry introspection).
  * ``Workload`` - synthetic suites (``synthetic``), the real Azure trace
    (``azure_trace``), prebuilt instances (``instances``), and serving
    request streams (``serving_requests`` - fleet capacity planning on
    the sweep engine).
  * ``Setting`` - nonclairvoyant / clairvoyant / predicted, made explicit;
    ``Setting.with_consolidation("underload:t0.25")`` attaches a
    ``ConsolidationSpec`` so the same regime replays with
    threshold-triggered migrations as a scenario axis.
  * ``Experiment`` / ``Results`` - the facade over the batched sweep
    engine with store-backed caching and Eq. (1) ratio summaries.

CLI: ``python -m repro {sweep,serve,bench}``.  Legacy entry points
(``sweep.grid.run_sweep``, ``serving.fleet.simulate_fleet``,
``python -m repro.sweep``) remain as thin shims; grep REPRO_API_MIGRATION
for their breadcrumbs.
"""
from ..consolidate import ConsolidationSpec  # noqa: F401
from .policy import (CATEGORY_POLICIES, HOST_ONLY_POLICIES,  # noqa: F401
                     POLICIES, SCAN_POLICIES, Policy, policies,
                     policy_names)
from .workload import (AttachedPredictions, RuntimeWorkload,  # noqa: F401
                       Setting, SuiteWorkload, Workload, ZeroPredictions,
                       azure_trace, instances, requests_to_instance,
                       serving_requests, synthetic, workload)
from .experiment import (DEFAULT_STORE, Experiment, Results,  # noqa: F401
                         run_experiment, summarize_sweep)
from ._migration import warn_legacy  # noqa: F401
