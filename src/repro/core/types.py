"""Core data types for MinUsageTime Dynamic Vector Bin Packing (DVBP).

The paper (Lee & Tang, 2026) defines an instance as a set of items r with
d-dimensional size vectors s(r) in (0, 1]^d and active intervals
I(r) = [arrival, departure).  Bins have unit capacity <1,...,1>.

We store instances as struct-of-arrays (numpy) so that the Python oracle
engine can vectorize feasibility checks over open bins and the JAX replayer
(`core.jaxsim`) can consume the same arrays directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Feasibility tolerance: sizes come from normalized fractional resource
# demands; exact-fit placements (sum == capacity) must be accepted despite
# float rounding.  The same epsilon is used by every algorithm and by the
# engine's post-placement capacity assertion.
EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Instance:
    """A MinUsageTime DVBP instance (struct of arrays, sorted by arrival)."""

    sizes: np.ndarray      # (n, d) float64, each component in (0, 1]
    arrivals: np.ndarray   # (n,) float64
    departures: np.ndarray  # (n,) float64, departures > arrivals
    name: str = "instance"

    def __post_init__(self):
        n, d = self.sizes.shape
        assert self.arrivals.shape == (n,)
        assert self.departures.shape == (n,)
        if n:
            assert np.all(self.departures > self.arrivals), "empty intervals"
            assert np.all(self.sizes > 0), "item sizes must be positive"
            assert np.all(self.sizes <= 1 + EPS), "item sizes must be <= capacity"
            assert np.all(np.diff(self.arrivals) >= 0), "must be sorted by arrival"

    @property
    def n_items(self) -> int:
        return self.sizes.shape[0]

    @property
    def d(self) -> int:
        return self.sizes.shape[1]

    @property
    def durations(self) -> np.ndarray:
        return self.departures - self.arrivals

    @property
    def mu(self) -> float:
        """Max/min item duration ratio (the paper's competitive parameter)."""
        dur = self.durations
        return float(dur.max() / dur.min()) if len(dur) else 1.0

    def sorted_by_arrival(self) -> "Instance":
        order = np.argsort(self.arrivals, kind="stable")
        return Instance(self.sizes[order], self.arrivals[order],
                        self.departures[order], self.name)

    def subset(self, mask: np.ndarray, name: Optional[str] = None) -> "Instance":
        return Instance(self.sizes[mask], self.arrivals[mask],
                        self.departures[mask], name or self.name)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """The information revealed to an online algorithm when an item arrives.

    ``pdep`` is the *predicted* departure time (clairvoyant setting: equal to
    the real departure; learning-augmented: arrival + predicted duration;
    non-clairvoyant: None and algorithms must not read it).
    """

    idx: int
    size: np.ndarray      # (d,)
    now: float            # == arrival time
    pdep: Optional[float]  # predicted departure time, or None

    @property
    def pdur(self) -> Optional[float]:
        return None if self.pdep is None else self.pdep - self.now


@dataclasses.dataclass(frozen=True)
class MigrantArrival(Arrival):
    """A consolidation re-place: an already-known item leaving its bin.

    ``now`` is the migration time (scoring and bin bookkeeping happen on the
    current clock), but categorization must stay anchored to the item's
    original arrival - its duration class was fixed when it first arrived -
    so ``pdur`` derives from ``orig_now``, not ``now``.  Mirrors the batched
    scan, whose per-item category constants are computed once from the
    original arrivals (``core.jaxsim._category_setup``).
    """

    orig_now: float = 0.0

    @property
    def pdur(self) -> Optional[float]:
        return None if self.pdep is None else self.pdep - self.orig_now


@dataclasses.dataclass
class PackingResult:
    """Outcome of one engine run."""

    usage_time: float            # accumulated bin usage time (the objective)
    n_bins_opened: int
    peak_open_bins: int
    placements: np.ndarray       # (n,) absolute bin index per item
    algorithm: str
    instance: str
    span: float                  # duration during which >=1 item is active

    def ratio(self, lower_bound: float) -> float:
        return self.usage_time / lower_bound if lower_bound > 0 else float("inf")
