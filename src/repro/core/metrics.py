"""Evaluation metrics: performance ratio and box-plot statistics (paper §III)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass
class BoxStats:
    """The paper's box-plot summary across instances."""

    mean: float
    median: float
    q1: float
    q3: float
    lo_whisker: float
    hi_whisker: float
    n: int

    @classmethod
    def from_ratios(cls, ratios: Sequence[float]) -> "BoxStats":
        r = np.asarray(sorted(ratios), float)
        q1, med, q3 = np.percentile(r, [25, 50, 75])
        iqr = q3 - q1
        lo = float(r[r >= q1 - 1.5 * iqr].min())
        hi = float(r[r <= q3 + 1.5 * iqr].max())
        return cls(float(r.mean()), float(med), float(q1), float(q3),
                   lo, hi, len(r))

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def summarize(per_instance_ratios: Dict[str, List[float]]) -> Dict[str, BoxStats]:
    """algorithm name -> BoxStats over its per-instance performance ratios."""
    return {name: BoxStats.from_ratios(r)
            for name, r in per_instance_ratios.items() if len(r)}
