"""Learning-augmented policies: RCP, PPE, their modified (no-large-bin)
variants (new, paper §VI-A), and Lifetime Alignment (binary / geometric).

Item categories use *predicted* durations with absolute geometric ranges
X_0 = [0,1)s, X_i = [2^(i-1), 2^i)s.  Thresholds: RCP 1/sqrt(x); PPE
alpha/sqrt(x) with alpha a guess-and-double online estimate of the maximum
multiplicative prediction error observed on departed items (the shared
``adaptive.DepartureErrorEstimator``).

The categorization functions (``geo_class`` / ``la_class`` and their jnp
twins) are pure and shared with the batched scan
(``core.jaxsim._replay_batch``), which replays every policy in this module
as category-structured lanes with decision-for-decision parity.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..types import EPS, Arrival
from .adaptive import DepartureErrorEstimator
from .base import Algorithm, register
from .duration import dur_exponent, dur_exponent_jnp

# bin roles (stored in pool.tag as negative numbers; category tags are >= 0)
_GENERAL, _BASE, _LARGE = -2, -3, -4

LA_BINARY_SPLIT = 7200.0   # 120 min, as deployed at Azure


def geo_class(dur):
    """0 if dur < 1s else i with dur in [2^(i-1), 2^i) seconds, vectorized
    (exact at power-of-two boundaries via frexp)."""
    return np.where(np.asarray(dur) < 1.0, 0, dur_exponent(dur))


def geo_class_jnp(dur):
    """jnp twin of :func:`geo_class`."""
    import jax.numpy as jnp
    return jnp.where(dur < 1.0, 0, dur_exponent_jnp(dur)).astype(jnp.int32)


def la_class(dur, mode: str = "binary"):
    """Lifetime Alignment class of a (predicted or remaining) duration."""
    if mode == "binary":
        return (np.asarray(dur) >= LA_BINARY_SPLIT).astype(np.int64)
    return geo_class(dur)


def la_class_jnp(dur, mode: str = "binary"):
    """jnp twin of :func:`la_class`."""
    import jax.numpy as jnp
    if mode == "binary":
        return (dur >= LA_BINARY_SPLIT).astype(jnp.int32)
    return geo_class_jnp(dur)


def _geo_cat(dur: float) -> int:
    return int(geo_class(dur))


class _RCPBase(Algorithm):
    """Shared machinery for RCP / PPE and the modified variants.

    Bin roles: general (First Fit, all categories below threshold), at most
    one *base* bin (overflow items of OFF categories), per-category bins
    (First Fit within the category once it is ON), and - original variants
    only - one *large* bin per item of size > 1/2.

    A category turns ON when the base bin exceeds total size 1/2 and is
    converted into a category bin (of its dominant category), or - modified
    variants - when a large item opens a category bin directly.  It turns OFF
    when the aggregate active size in its category bins falls below 1/2.
    """

    requires_predictions = True
    large_bins = True      # original RCP/PPE; modified variants set False
    adaptive_alpha = False  # PPE

    def bind(self, pool, inst):
        super().bind(pool, inst)
        self._seen_cats = set()
        self._on: Dict[int, bool] = {}
        self._agg_general: Dict[int, np.ndarray] = {}
        self._agg_catbins: Dict[int, np.ndarray] = {}
        self._agg_base = np.zeros(pool.d)
        self._base_idx = -1
        # item idx -> (category, location, predicted duration)
        self._items: Dict[int, tuple] = {}
        # alpha == pow2_ceiling(max observed error): the guess-and-double
        # estimate, backed by the shared departure-error estimator
        self._estimator = DepartureErrorEstimator()
        # category tags: cat -> tag id (>= 0)
        self._cat_tag: Dict[int, int] = {}
        self._next_tag = 0

    # ---------------------------------------------------------------- helpers
    def _tag_of(self, cat: int) -> int:
        if cat not in self._cat_tag:
            self._cat_tag[cat] = self._next_tag
            self._next_tag += 1
        return self._cat_tag[cat]

    def _threshold(self) -> float:
        x = max(len(self._seen_cats), 1)
        alpha = self._estimator.pow2_alpha() if self.adaptive_alpha else 1.0
        return alpha / np.sqrt(x)

    def _ff_tag(self, arr: Arrival, tag: int) -> int:
        open_idx = self.pool.open_indices()
        same = open_idx[self.pool.tag[open_idx] == tag]
        feas = same[self.pool.fits_mask(same, arr.size)]
        return int(feas[0]) if len(feas) else -1

    def _base_fits(self, size: np.ndarray) -> bool:
        if self._base_idx < 0 or not self.pool.alive[self._base_idx]:
            return True   # a fresh base bin always fits any item
        return bool(self.pool.fits_mask(np.array([self._base_idx]), size)[0])

    # -------------------------------------------------------------- placement
    def select_bin(self, arr: Arrival) -> int:
        cat = _geo_cat(max(arr.pdur, 0.0))
        self._seen_cats.add(cat)
        thr = self._threshold()
        large = float(arr.size.max()) > 0.5
        agg = self._agg_general.get(cat, np.zeros(self.pool.d))

        if self.large_bins and large:
            self._dest = ("L", cat)
            return -1   # one dedicated large bin per large item

        if float((agg + arr.size).max()) <= thr + EPS:
            self._dest = ("G", cat)
            return self._ff_tag(arr, _GENERAL)

        if self._on.get(cat, False):
            self._dest = ("C", cat)
            return self._ff_tag(arr, self._tag_of(cat))

        if self._base_fits(arr.size):
            self._dest = ("B", cat)
            if self._base_idx >= 0 and self.pool.alive[self._base_idx]:
                return self._base_idx
            return -1
        # modified variants only: a large item that cannot join the base bin
        # opens a category bin directly and turns its category ON.
        self._dest = ("C!", cat)
        return -1

    def on_placed(self, arr: Arrival, idx: int, opened: bool):
        kind, cat = self._dest
        if kind == "L":
            self.pool.tag[idx] = _LARGE
            self._items[arr.idx] = (cat, "L", arr.pdur, arr.size)
        elif kind == "G":
            if opened:
                self.pool.tag[idx] = _GENERAL
            self._agg_general[cat] = self._agg_general.get(
                cat, np.zeros(self.pool.d)) + arr.size
            self._items[arr.idx] = (cat, "G", arr.pdur, arr.size)
        elif kind in ("C", "C!"):
            if opened:
                self.pool.tag[idx] = self._tag_of(cat)
            if kind == "C!":
                self._on[cat] = True
            self._agg_catbins[cat] = self._agg_catbins.get(
                cat, np.zeros(self.pool.d)) + arr.size
            self._items[arr.idx] = (cat, "C", arr.pdur, arr.size)
        else:  # base bin
            if opened:
                self.pool.tag[idx] = _BASE
                self._base_idx = idx
                self._agg_base = np.zeros(self.pool.d)
            self._agg_base = self._agg_base + arr.size
            self._items[arr.idx] = (cat, "B", arr.pdur, arr.size)
            if float(self._agg_base.max()) > 0.5:
                self._convert_base(idx)

    def _convert_base(self, idx: int):
        """Base bin exceeded 1/2: convert to a category bin of its dominant
        category and turn that category ON (paper §VI-A).  Member sizes come
        from the per-item record (not ``inst.sizes``), so the conversion
        also works on open-ended streams (serving request ids)."""
        members = {c: np.zeros(self.pool.d) for c in self._seen_cats}
        for item, (cat, loc, _, sz) in self._items.items():
            if loc == "B":
                members[cat] = members[cat] + sz
        chosen = max(self._seen_cats, key=lambda c: float(members[c].max()))
        self.pool.tag[idx] = self._tag_of(chosen)
        self._on[chosen] = True
        for item, (cat, loc, pd, sz) in list(self._items.items()):
            if loc == "B":
                self._items[item] = (cat, "C", pd, sz)
                self._agg_catbins[cat] = self._agg_catbins.get(
                    cat, np.zeros(self.pool.d)) + sz
        self._agg_base = np.zeros(self.pool.d)
        self._base_idx = -1

    def _remove_item(self, item: int, size: np.ndarray):
        """Aggregate bookkeeping for an item leaving its bin (departure or
        migration): location decrements and the category turn-OFF check."""
        cat, loc, pdur, _ = self._items.pop(item)
        if loc == "G":
            self._agg_general[cat] = np.maximum(
                self._agg_general[cat] - size, 0.0)
        elif loc == "B":
            self._agg_base = np.maximum(self._agg_base - size, 0.0)
        elif loc == "C":
            self._agg_catbins[cat] = np.maximum(
                self._agg_catbins.get(cat, np.zeros(self.pool.d)) - size, 0.0)
            if self._on.get(cat, False) and \
                    float(self._agg_catbins[cat].max()) < 0.5:
                self._on[cat] = False   # category load fell low: turn OFF
        return pdur

    def on_departed(self, item: int, idx: int, now: float, size: np.ndarray):
        pdur = self._remove_item(item, size)
        if self.adaptive_alpha and pdur is not None:
            # guess-and-double (PPE, [14]): alpha = pow2_ceiling(max err)
            rdur = float(self.inst.departures[item] - self.inst.arrivals[item])
            self._estimator.observe(rdur, pdur)

    def on_migrated_out(self, item: int, idx: int, now: float,
                        size: np.ndarray):
        # no error observation: the item has not actually departed
        self._remove_item(item, size)

    def on_closed(self, idx: int, now: float):
        if idx == self._base_idx:
            self._base_idx = -1
            self._agg_base = np.zeros(self.pool.d)


@register("rcp")
class RCP(_RCPBase):
    """Robust & Consistent Packing [13]: O(mu) consistency,
    O(sqrt(log mu)) robustness."""

    name = "rcp"


@register("ppe")
class PPE(_RCPBase):
    """Packing with Prediction Error [14]: threshold alpha/sqrt(x); tight
    O(min{max{eps sqrt(log mu), eps^2}, mu}) over the error spectrum."""

    name = "ppe"
    adaptive_alpha = True


@register("rcp_modified")
class ModifiedRCP(_RCPBase):
    """NEW (paper §VI-A): RCP without dedicated large bins - large items share
    general/base/category bins, improving utilization."""

    name = "rcp_modified"
    large_bins = False


@register("ppe_modified")
class ModifiedPPE(_RCPBase):
    """NEW (paper §VI-A): PPE without dedicated large bins.  Best performer at
    high prediction error alongside First Fit (paper Fig. 12)."""

    name = "ppe_modified"
    large_bins = False
    adaptive_alpha = True


@register("lifetime_alignment")
class LifetimeAlignment(Algorithm):
    """Barbalho et al. [23]: Classify-By-(predicted)-Duration for items plus
    *dynamic* bin categories = predicted remaining usage time, Best Fit (l_inf)
    within the preferred class.  Any Fit; unbounded CR.

    mode="binary":    X0=[0,120min), X1=[120min,inf)   (as deployed at Azure)
    mode="geometric": X0=[0,1s), Xi=[2^(i-1),2^i)s     (as in RCP/PPE)
    """

    requires_predictions = True

    def __init__(self, mode: str = "binary"):
        assert mode in ("binary", "geometric")
        self.mode = mode
        self.name = f"la_{mode}"

    def _cat(self, dur: float) -> int:
        return int(la_class(dur, self.mode))

    def _best_fit(self, cand: np.ndarray, size: np.ndarray) -> int:
        feas = cand[self.pool.fits_mask(cand, size)]
        if not len(feas):
            return -1
        rem = self.pool.remaining(feas) - size
        return int(feas[np.argmin(rem.max(axis=1))])

    def select_bin(self, arr: Arrival) -> int:
        open_idx = self.pool.open_indices()
        if not len(open_idx):
            return -1
        cat = self._cat(max(arr.pdur, 0.0))
        if cat == 0:
            # shortest items fill leftover capacity anywhere
            return self._best_fit(open_idx, arr.size)
        remaining = self.pool.effective_close(open_idx, arr.now) - arr.now
        bin_cats = np.array([self._cat(r) for r in remaining])
        same = open_idx[bin_cats == cat]
        chosen = self._best_fit(same, arr.size)
        if chosen >= 0:
            return chosen
        other = open_idx[bin_cats != cat]
        return self._best_fit(other, arr.size)
