"""DVBP algorithm zoo.  Importing this package populates the registry."""
from .base import REGISTRY, Algorithm, get_algorithm, register  # noqa: F401
from . import adaptive, anyfit, departure, duration, learned  # noqa: F401

ALL_ALGORITHMS = sorted(REGISTRY)

NON_CLAIRVOYANT = ["first_fit", "mru", "next_fit", "rr_next_fit", "best_fit"]
CLAIRVOYANT = ["cbdt", "nrt_standard", "nrt_prioritized", "greedy", "cbd",
               "hybrid", "reduced_hybrid", "hybrid_direct_sum",
               "reduced_hybrid_direct_sum"]
LEARNING_AUGMENTED = ["rcp", "ppe", "rcp_modified", "ppe_modified",
                      "lifetime_alignment"]
# Any Fit algorithms (never open a new bin when the item fits in an open bin)
ANY_FIT = ["first_fit", "mru", "rr_next_fit", "best_fit_l1", "best_fit_l2",
           "best_fit_linf", "nrt_standard", "nrt_prioritized", "greedy",
           "la_binary", "la_geometric"]
