"""Clairvoyant policies driven by *duration* information:
Classify-By-Duration, Hybrid, Reduced Hybrid, and their direct-sum variants.

Multi-dimensional adaptation follows the paper: the "total size" of a set of
items is the l_inf norm of their aggregate size vector (Theorem 4 gives
O(d sqrt(log mu)) for both hybrids under this adaptation).  The direct-sum
variant [17] instead splits items into d classes by their largest dimension
and runs an independent single-dimensional copy per class (within a class,
feasibility in the max dimension implies feasibility in all dimensions).

The *categorization* math (duration exponents, CBD duration classes, hybrid
thresholds) lives in pure functions with numpy and jnp twins so the host
oracle classes here and the batched scan (``core.jaxsim._replay_batch``)
share one definition and agree decision-for-decision.  Power-of-two class
boundaries are computed via ``frexp`` (exact for every representable float)
rather than ``log2`` (whose rounding can misclassify durations that are
exact powers of two).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import numpy as np

from ..types import EPS, Arrival
from .base import Algorithm, register


# ---------------------------------------------------------------- categories
def dur_exponent(dur):
    """j with dur in [2^(j-1), 2^j), vectorized; exact via frexp.

    ``frexp(d) = (m, e)`` with ``d = m 2^e``, ``m in [0.5, 1)``, so
    ``floor(log2 d) + 1 == e`` exactly - no log rounding at the class
    boundaries (``log2(2^k)`` may round to just under ``k`` in fp32)."""
    return np.frexp(np.maximum(dur, 1e-12))[1]


def dur_exponent_jnp(dur):
    """jnp twin of :func:`dur_exponent` (used inside the batched scan)."""
    import jax.numpy as jnp
    return jnp.frexp(jnp.maximum(dur, 1e-12))[1].astype(jnp.int32)


def duration_class(dur, beta: float = 2.0):
    """CBD class i with dur in [beta^(i-1), beta^i), vectorized.

    beta == 2 uses the exact frexp path (bit-exact in both twins at every
    precision).  Other bases fall back to the log ratio, where this f64
    host path and the scan's f32 jnp twin can round a duration sitting
    essentially on a power-of-beta boundary into adjacent classes - the
    decision-for-decision parity guarantee is only for beta == 2."""
    if beta == 2.0:
        return dur_exponent(dur)
    dur = np.maximum(dur, 1e-12)
    return (np.floor(np.log(dur) / math.log(beta)) + 1).astype(np.int64)


def duration_class_jnp(dur, beta: float = 2.0):
    """jnp twin of :func:`duration_class`."""
    import jax.numpy as jnp
    if beta == 2.0:
        return dur_exponent_jnp(dur)
    dur = jnp.maximum(dur, 1e-12)
    return (jnp.floor(jnp.log(dur) / math.log(beta)) + 1).astype(jnp.int32)


def hybrid_threshold(i):
    """General-vs-category routing threshold 1/(2 sqrt(i)), vectorized."""
    return 1.0 / (2.0 * np.sqrt(i))


def hybrid_threshold_jnp(i):
    """jnp twin of :func:`hybrid_threshold`."""
    import jax.numpy as jnp
    return 1.0 / (2.0 * jnp.sqrt(i.astype(jnp.float32)))


def _dur_exponent(dur: float) -> int:
    """Scalar j such that dur in [2^(j-1), 2^j)."""
    return int(dur_exponent(dur))


@register("cbd")
class ClassifyByDuration(Algorithm):
    """Items with durations in [beta^(i-1), beta^i) share a First-Fit bin
    class (paper §V-D).  O(log mu) competitive in 1-d.  Not Any Fit."""

    requires_predictions = True

    def __init__(self, beta: float = 2.0):
        assert beta > 1
        self.beta = beta
        self.name = f"cbd_beta{beta:g}"

    def select_bin(self, arr: Arrival) -> int:
        cat = int(duration_class(arr.pdur, self.beta))
        self._cat = cat
        open_idx = self.pool.open_indices()
        same = open_idx[self.pool.tag[open_idx] == cat]
        feas = same[self.pool.fits_mask(same, arr.size)]
        return int(feas[0]) if len(feas) else -1

    def on_placed(self, arr: Arrival, idx: int, opened: bool):
        if opened:
            self.pool.tag[idx] = self._cat


class _HybridBase(Algorithm):
    """Shared machinery for Hybrid / Reduced Hybrid (+ direct-sum variants).

    Bins carry an integer tag identifying either a per-class *general* pool or
    a specific item category's pool.  Per-category aggregate loads inside the
    general bins decide general-vs-category routing (threshold 1/(2 sqrt(i))).
    """

    requires_predictions = True
    reduced = False
    direct_sum = False

    def bind(self, pool, inst):
        super().bind(pool, inst)
        # Paper §V-E: rescale duration exponents so the minimum duration maps
        # to category i=1 (keeps sqrt(i) well defined).
        min_dur = float(inst.durations.min()) if inst.n_items else 1.0
        self._z = _dur_exponent(min_dur)
        self._tag_ids: Dict[Tuple, int] = {}
        self._agg: Dict[Tuple, np.ndarray] = {}      # key -> aggregate in general bins
        self._item_state: Dict[int, Tuple[Tuple, bool]] = {}

    # ------------------------------------------------------------- categories
    def _categorize(self, arr: Arrival) -> Tuple[Tuple, int, int]:
        """Return (category key, scaled index i>=1, class)."""
        cls = int(np.argmax(arr.size)) if self.direct_sum else 0
        j = _dur_exponent(max(arr.pdur, 1e-12))
        i = max(j - self._z + 1, 1)   # clamp: mispredictions below min duration
        if self.reduced:
            key = (cls, i)
        else:
            width = 2.0 ** j
            # consolidation re-places carry their original arrival clock
            # (``MigrantArrival.orig_now``): the arrival window was fixed
            # when the item first arrived
            c = int(math.floor(getattr(arr, "orig_now", arr.now) / width))
            key = (cls, i, c)
        return key, i, cls

    def _tag(self, key) -> int:
        if key not in self._tag_ids:
            self._tag_ids[key] = len(self._tag_ids)
        return self._tag_ids[key]

    def _norm(self, vec: np.ndarray, cls: int) -> float:
        # direct-sum sub-instances are single-dimensional in their max dim
        return float(vec[cls]) if self.direct_sum else float(vec.max())

    def _ff_among_tag(self, arr: Arrival, tag: int) -> int:
        open_idx = self.pool.open_indices()
        same = open_idx[self.pool.tag[open_idx] == tag]
        feas = same[self.pool.fits_mask(same, arr.size)]
        return int(feas[0]) if len(feas) else -1

    # -------------------------------------------------------------- placement
    def select_bin(self, arr: Arrival) -> int:
        key, i, cls = self._categorize(arr)
        agg = self._agg.get(key)
        after = arr.size if agg is None else agg + arr.size
        if self._norm(after, cls) <= hybrid_threshold(i) + EPS:
            self._dest = ("G", key, cls)
            return self._ff_among_tag(arr, self._tag(("G", cls)))
        self._dest = ("C", key, cls)
        return self._ff_among_tag(arr, self._tag(("C", key)))

    def on_placed(self, arr: Arrival, idx: int, opened: bool):
        kind, key, cls = self._dest
        if opened:
            tag_key = ("G", cls) if kind == "G" else ("C", key)
            self.pool.tag[idx] = self._tag(tag_key)
        if kind == "G":
            self._agg[key] = self._agg.get(key, np.zeros(self.pool.d)) + arr.size
            self._item_state[arr.idx] = (key, True)
        else:
            self._item_state[arr.idx] = (key, False)

    def on_departed(self, item: int, idx: int, now: float, size: np.ndarray):
        key, in_general = self._item_state.pop(item)
        if in_general:
            self._agg[key] = np.maximum(self._agg[key] - size, 0.0)


@register("hybrid")
class Hybrid(_HybridBase):
    """Azar & Vainstein's Hybrid [8]; categories (duration range, arrival
    window).  O(d sqrt(log mu)) with the l_inf adaptation (Theorem 4)."""

    name = "hybrid"


@register("reduced_hybrid")
class ReducedHybrid(_HybridBase):
    """Liu & Tang's simplification [13]: duration-only categories.
    Same O(d sqrt(log mu)) bound; empirically much better (paper Fig. 7)."""

    name = "reduced_hybrid"
    reduced = True


@register("hybrid_direct_sum")
class HybridDirectSum(_HybridBase):
    name = "hybrid_direct_sum"
    direct_sum = True


@register("reduced_hybrid_direct_sum")
class ReducedHybridDirectSum(_HybridBase):
    name = "reduced_hybrid_direct_sum"
    reduced = True
    direct_sum = True
