"""Adaptive policy switching - the paper's future direction (1), implemented.

The paper's §VI-C finding: Prioritized NRT wins near perfect predictions,
Greedy wins at medium error, and at high error modified PPE converges to
First Fit (its threshold alpha/sqrt(x) grows past every aggregate).
``AdaptiveSwitch`` monitors the maximum multiplicative prediction error over
departed items (the same online signal PPE's guess-and-double uses - no
extra information assumed) and routes each arrival to the strongest policy
for the current regime:

    err < low   (default 2)  -> nrt_prioritized  (aggressive; consistency)
    err < high  (default 16) -> greedy           (conservative closing times)
    else                     -> first_fit        (error-oblivious; what PPE
                                                  degenerates to anyway)

All three sub-policies are *pool-stateless* (they read bin state from the
shared BinPool and keep no private structures), so switching between them
mid-stream is exactly an Any Fit algorithm and inherits Greedy/NRT's
(mu+2)d+1 competitive bound in each regime.  Evaluated in
benchmarks/figures.py (fig15_adaptive); validated in tests/test_adaptive.py.
"""
from __future__ import annotations

import numpy as np

from ..types import Arrival
from .base import Algorithm, register
from .anyfit import FirstFit
from .departure import Greedy, PrioritizedNRT


@register("adaptive")
class AdaptiveSwitch(Algorithm):
    requires_predictions = True

    def __init__(self, low: float = 2.0, high: float = 16.0):
        assert 1.0 <= low <= high
        self.low = low
        self.high = high
        self.name = f"adaptive_{low:g}_{high:g}"
        self._subs = (PrioritizedNRT(), Greedy(), FirstFit())

    def bind(self, pool, inst):
        super().bind(pool, inst)
        for s in self._subs:
            s.bind(pool, inst)
        self._err = 1.0
        self._pdur = {}
        self.regime_switches = 0
        self._last = 0

    def _active_index(self) -> int:
        if self._err < self.low:
            return 0
        if self._err < self.high:
            return 1
        return 2

    def select_bin(self, arr: Arrival) -> int:
        self._pdur[arr.idx] = max(arr.pdur, 1e-12)
        k = self._active_index()
        if k != self._last:
            self.regime_switches += 1
            self._last = k
        return self._subs[k].select_bin(arr)

    def on_departed(self, item: int, idx: int, now: float, size: np.ndarray):
        pdur = self._pdur.pop(item, None)
        if pdur is not None:
            rdur = float(self.inst.departures[item]
                         - self.inst.arrivals[item])
            self._err = max(self._err, rdur / pdur, pdur / rdur)
