"""Adaptive policy switching - the paper's future direction (1), implemented.

The paper's §VI-C finding: Prioritized NRT wins near perfect predictions,
Greedy wins at medium error, and at high error modified PPE converges to
First Fit (its threshold alpha/sqrt(x) grows past every aggregate).
``AdaptiveSwitch`` monitors the maximum multiplicative prediction error over
departed items (the same online signal PPE's guess-and-double uses - no
extra information assumed) and routes each arrival to the strongest policy
for the current regime:

    err < low   (default 2)  -> nrt_prioritized  (aggressive; consistency)
    err < high  (default 16) -> greedy           (conservative closing times)
    else                     -> first_fit        (error-oblivious; what PPE
                                                  degenerates to anyway)

The error signal itself lives in ``DepartureErrorEstimator`` - one shared
running-max estimator consumed by AdaptiveSwitch, by PPE's guess-and-double
alpha (``learned._RCPBase``), and - via the pure ``prediction_error_jnp`` /
``pow2_ceiling_jnp`` twins - by the batched scan's carried err/alpha scalars
(``core.jaxsim._replay_batch``).  The estimator is updated once per
*departure*; arrivals only read it (O(1) per event: no per-arrival
recomputation and no per-item dict churn).

All three sub-policies are *pool-stateless* (they read bin state from the
shared BinPool and keep no private structures), so switching between them
mid-stream is exactly an Any Fit algorithm and inherits Greedy/NRT's
(mu+2)d+1 competitive bound in each regime.  Evaluated in
benchmarks/figures.py (fig15_adaptive); validated in tests/test_adaptive.py.
"""
from __future__ import annotations

import math

import numpy as np

from ..types import Arrival
from .base import Algorithm, register
from .anyfit import FirstFit
from .departure import Greedy, PrioritizedNRT


def prediction_error(rdur, pdur):
    """Multiplicative misprediction max(rdur/pdur, pdur/rdur), vectorized."""
    pdur = np.maximum(pdur, 1e-12)
    return np.maximum(rdur / pdur, pdur / rdur)


def prediction_error_jnp(rdur, pdur):
    """jnp twin of :func:`prediction_error` (the batched scan's per-item
    departure-error input)."""
    import jax.numpy as jnp
    pdur = jnp.maximum(pdur, 1e-12)
    return jnp.maximum(rdur / pdur, pdur / rdur)


def pow2_ceiling(x: float) -> float:
    """Smallest power of two >= x - the fixed point of guess-and-double
    starting from any power of two <= x.  Exact via frexp."""
    m, e = math.frexp(x)
    return math.ldexp(0.5 if m == 0.5 else 1.0, e)


def pow2_ceiling_jnp(x):
    """jnp twin of :func:`pow2_ceiling`, vectorized."""
    import jax.numpy as jnp
    m, e = jnp.frexp(x)
    return jnp.ldexp(jnp.where(m == 0.5, 0.5, 1.0).astype(x.dtype), e)


class DepartureErrorEstimator:
    """Running max multiplicative prediction error over departed items.

    The single online error signal the paper's §VI-C machinery consumes:
    PPE's guess-and-double alpha is ``pow2_ceiling(err)`` and
    AdaptiveSwitch's regime is a piecewise-constant function of ``err``.
    ``observe`` is called once per departure; reading ``err`` is O(1).
    """

    def __init__(self):
        self.err = 1.0

    def observe(self, rdur: float, pdur: float) -> float:
        self.err = max(self.err, float(prediction_error(rdur, pdur)))
        return self.err

    def pow2_alpha(self) -> float:
        """Guess-and-double alpha: smallest power of two >= err."""
        return pow2_ceiling(self.err)


@register("adaptive")
class AdaptiveSwitch(Algorithm):
    requires_predictions = True

    def __init__(self, low: float = 2.0, high: float = 16.0):
        assert 1.0 <= low <= high
        self.low = low
        self.high = high
        self.name = f"adaptive_{low:g}_{high:g}"
        self._subs = (PrioritizedNRT(), Greedy(), FirstFit())

    def bind(self, pool, inst):
        super().bind(pool, inst)
        for s in self._subs:
            s.bind(pool, inst)
        self.estimator = DepartureErrorEstimator()
        # predicted durations recorded at arrival (the estimator may only
        # use information the online algorithm has seen); dense array for
        # instance replays, dict overflow for open-ended streams whose
        # caller-chosen ids may be sparse (serving request ids)
        self._pdur = np.zeros(max(inst.n_items, 1))
        self._pdur_extra = {}
        self.regime_switches = 0
        self._last = 0

    @property
    def _err(self) -> float:   # kept for tests/introspection
        return self.estimator.err

    def _active_index(self) -> int:
        err = self.estimator.err
        if err < self.low:
            return 0
        if err < self.high:
            return 1
        return 2

    def select_bin(self, arr: Arrival) -> int:
        if arr.idx < len(self._pdur):
            self._pdur[arr.idx] = max(arr.pdur, 1e-12)
        else:                              # open-ended stream (serving)
            self._pdur_extra[arr.idx] = max(arr.pdur, 1e-12)
        k = self._active_index()
        if k != self._last:
            self.regime_switches += 1
            self._last = k
        return self._subs[k].select_bin(arr)

    def on_departed(self, item: int, idx: int, now: float, size: np.ndarray):
        if item >= len(self.inst.departures):
            self._pdur_extra.pop(item, None)
            return   # open-ended stream: no ground-truth duration to score
        rdur = float(self.inst.departures[item] - self.inst.arrivals[item])
        self.estimator.observe(rdur, self._pdur[item])

    def on_migrated_out(self, item: int, idx: int, now: float,
                        size: np.ndarray):
        pass   # a migration is not a departure: no error observation
