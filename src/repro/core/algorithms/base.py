"""Algorithm base class and registry for the DVBP zoo."""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from ..bins import BinPool
from ..types import Arrival, Instance


class Algorithm:
    """Online packing policy.  The engine owns bin state; the policy selects.

    Contract:
      * ``select_bin(arr)`` returns an *open, feasible* absolute bin index, or
        -1 to request a new bin.  The engine then calls ``on_placed``.
      * ``on_departed`` / ``on_closed`` keep policy-private structures in sync.
      * ``requires_predictions``: True for clairvoyant / learning-augmented
        policies (they read ``arr.pdep`` and ``pool.indicated_close``).
    """

    name = "abstract"
    requires_predictions = False

    def bind(self, pool: BinPool, inst: Instance):
        self.pool = pool
        self.inst = inst

    def select_bin(self, arr: Arrival) -> int:
        raise NotImplementedError

    def on_placed(self, arr: Arrival, idx: int, opened: bool):
        pass

    def on_departed(self, item: int, idx: int, now: float, size: np.ndarray):
        pass

    def on_migrated_out(self, item: int, idx: int, now: float,
                        size: np.ndarray):
        """Consolidation removed ``item`` from ``idx`` ahead of a re-place.

        Defaults to the departure bookkeeping; policies that *learn* from
        departures (prediction-error estimators) override, because a
        migration reveals nothing about the item's real duration.
        """
        self.on_departed(item, idx, now, size)

    def on_closed(self, idx: int, now: float):
        pass

    # -------- helpers shared by most policies
    def _feasible(self, arr: Arrival):
        open_idx = self.pool.open_indices()
        mask = self.pool.fits_mask(open_idx, arr.size)
        return open_idx[mask]


REGISTRY: Dict[str, Callable[..., Algorithm]] = {}


def register(name: str):
    def deco(factory):
        REGISTRY[name] = factory
        return factory
    return deco


def get_algorithm(name: str, **kwargs) -> Algorithm:
    if name not in REGISTRY:
        raise KeyError(f"unknown algorithm {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name](**kwargs)
