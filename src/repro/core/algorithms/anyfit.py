"""Non-clairvoyant policies: First Fit, MRU, Best Fit, Next Fit, RR Next Fit.

First Fit, MRU, Best Fit and Round-Robin Next Fit are Any Fit algorithms
(never open a new bin when the item fits in some open bin).  Next Fit is not.
"""
from __future__ import annotations

import numpy as np

from ..types import Arrival
from .base import Algorithm, register


@register("first_fit")
class FirstFit(Algorithm):
    """Place into the earliest-opened feasible bin.  CR = (mu+2)d + 1."""

    name = "first_fit"

    def select_bin(self, arr: Arrival) -> int:
        feas = self._feasible(arr)   # open_indices is already in opening order
        return int(feas[0]) if len(feas) else -1


@register("mru")
class MostRecentlyUsed(Algorithm):
    """Move-to-Front: most recently *accessed* feasible bin.  CR = (2mu+1)d+1."""

    name = "mru"

    def select_bin(self, arr: Arrival) -> int:
        feas = self._feasible(arr)
        if not len(feas):
            return -1
        return int(feas[np.argmax(self.pool.access_seq[feas])])


@register("best_fit")
class BestFit(Algorithm):
    """Least remaining capacity after placement, under an l_p norm fit score.

    norm in {"l1", "l2", "linf"} (paper §IV-C; linf is best on Azure data).
    Unbounded competitive ratio, strong empirically.
    """

    def __init__(self, norm: str = "linf"):
        assert norm in ("l1", "l2", "linf")
        self.norm = norm
        self.name = f"best_fit_{norm}"

    def select_bin(self, arr: Arrival) -> int:
        feas = self._feasible(arr)
        if not len(feas):
            return -1
        rem = self.pool.remaining(feas) - arr.size  # leftover after placement
        if self.norm == "l1":
            score = rem.sum(axis=1)
        elif self.norm == "l2":
            score = np.sqrt((rem * rem).sum(axis=1))
        else:
            score = rem.max(axis=1)
        return int(feas[np.argmin(score)])


@register("next_fit")
class NextFit(Algorithm):
    """Single receiving bin; on misfit the bin stops receiving forever.

    Not Any Fit.  CR = 2*mu*d + 1.
    """

    name = "next_fit"

    def bind(self, pool, inst):
        super().bind(pool, inst)
        self.current = -1   # absolute idx of the only receiving bin

    def select_bin(self, arr: Arrival) -> int:
        cur = self.current
        if cur >= 0 and self.pool.alive[cur]:
            if self.pool.fits_mask(np.array([cur]), arr.size)[0]:
                return cur
        return -1   # old bin (if any) is abandoned for future placements

    def on_placed(self, arr: Arrival, idx: int, opened: bool):
        self.current = idx


@register("rr_next_fit")
class RoundRobinNextFit(Algorithm):
    """NEW (paper §IV-B): Next Fit made Any Fit via round-robin search.

    Bins are kept in opening order; the cursor starts at the bin that received
    the last item and walks circularly; a new bin is opened only if no open
    bin fits.  CR <= (2mu+1)d + 1, and >= 2*mu*d (paper Appendix A).
    """

    name = "rr_next_fit"

    def bind(self, pool, inst):
        super().bind(pool, inst)
        self.cursor = -1   # absolute idx of bin that received the last item

    def select_bin(self, arr: Arrival) -> int:
        open_idx = self.pool.open_indices()
        if not len(open_idx):
            return -1
        mask = self.pool.fits_mask(open_idx, arr.size)
        if not mask.any():
            return -1
        # rotate so that the scan starts at the cursor bin (or the next open
        # bin after a closed cursor), preserving opening order.
        pos = np.searchsorted(open_idx, self.cursor)
        if pos == len(open_idx):
            pos = 0
        order = np.roll(np.arange(len(open_idx)), -pos)
        for j in order:
            if mask[j]:
                return int(open_idx[j])
        return -1  # unreachable

    def on_placed(self, arr: Arrival, idx: int, opened: bool):
        self.cursor = idx
