"""Clairvoyant policies driven by *departure time* information:
Classify-By-Departure-Time, Nearest Remaining Time (new), Greedy.

All read ``arr.pdep`` (real departure in the clairvoyant setting, predicted
in the learning-augmented setting) and the bins' indicated closing times,
clamped to >= now per the paper's §VI adaptation.
"""
from __future__ import annotations

import numpy as np

from ..types import Arrival
from .base import Algorithm, register


def departure_window(pdep, rho: float):
    """CBDT class: index of the rho-wide horizon window holding a
    (predicted) departure time, vectorized.  Shared by the host class, the
    batched scan (via the jnp twin) and the serving scheduler's category
    mask, so every path agrees on the window boundary."""
    return np.floor(np.asarray(pdep) / rho).astype(np.int64)


def departure_window_jnp(pdep, rho: float):
    """jnp twin of :func:`departure_window`."""
    import jax.numpy as jnp
    return jnp.floor(pdep / rho).astype(jnp.int32)


@register("cbdt")
class ClassifyByDepartureTime(Algorithm):
    """Partition the horizon into rho-wide windows; items whose departure
    falls in the same window share a dedicated First-Fit bin class (paper §V-A).
    Not Any Fit.  O(sqrt(mu)) competitive in 1-d with the optimal rho.
    """

    requires_predictions = True

    def __init__(self, rho: float):
        assert rho > 0
        self.rho = rho
        self.name = f"cbdt_rho{rho:g}"

    def select_bin(self, arr: Arrival) -> int:
        cat = int(departure_window(arr.pdep, self.rho))
        self._cat = cat
        open_idx = self.pool.open_indices()
        same = open_idx[self.pool.tag[open_idx] == cat]
        mask = self.pool.fits_mask(same, arr.size)
        feas = same[mask]
        return int(feas[0]) if len(feas) else -1

    def on_placed(self, arr: Arrival, idx: int, opened: bool):
        if opened:
            self.pool.tag[idx] = self._cat


class _NRTBase(Algorithm):
    requires_predictions = True

    def _closes(self, feas, now):
        return self.pool.effective_close(feas, now)


@register("nrt_standard")
class StandardNRT(_NRTBase):
    """NEW (paper §V-B): place into the feasible bin whose indicated closing
    time is nearest to the item's departure time.  Unbounded CR."""

    name = "nrt_standard"

    def select_bin(self, arr: Arrival) -> int:
        feas = self._feasible(arr)
        if not len(feas):
            return -1
        closes = self._closes(feas, arr.now)
        return int(feas[np.argmin(np.abs(closes - arr.pdep))])


@register("nrt_prioritized")
class PrioritizedNRT(_NRTBase):
    """NEW (paper §V-B): prefer bins that need no closing-time extension
    (indicated close >= item departure); nearest within each case.
    CR <= (mu+2)d + 1 (paper Appendix B).  Best clairvoyant performer."""

    name = "nrt_prioritized"

    def select_bin(self, arr: Arrival) -> int:
        feas = self._feasible(arr)
        if not len(feas):
            return -1
        closes = self._closes(feas, arr.now)
        gap = closes - arr.pdep
        case_a = gap >= 0
        if case_a.any():
            cand = feas[case_a]
            return int(cand[np.argmin(gap[case_a])])
        return int(feas[np.argmax(gap)])   # case b: least extension needed


@register("greedy")
class Greedy(Algorithm):
    """Li et al. [17]: place into the feasible bin with the *latest* indicated
    closing time.  CR <= (mu+2)d + 1 (improved analysis, paper Appendix B).
    Conservative; the most error-robust of the closing-time family (§VI-C)."""

    name = "greedy"
    requires_predictions = True

    def select_bin(self, arr: Arrival) -> int:
        feas = self._feasible(arr)
        if not len(feas):
            return -1
        closes = self.pool.effective_close(feas, arr.now)
        return int(feas[np.argmax(closes)])
