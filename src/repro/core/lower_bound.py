"""Lower bound (Eq. 1) on the optimal accumulated bin usage time.

    LB = integral over t of  ceil( || sum_{active r} s(r) ||_inf )  dt

computed exactly by a sweep line over arrival/departure events: between two
consecutive events the aggregate size vector is constant.  Also returns the
time span (a second lower bound used by the competitive analyses).
"""
from __future__ import annotations

import numpy as np

from .types import EPS, Instance


def lower_bound(inst: Instance) -> float:
    n, d = inst.sizes.shape
    if n == 0:
        return 0.0
    times = np.concatenate([inst.arrivals, inst.departures])
    deltas = np.concatenate([inst.sizes, -inst.sizes])
    order = np.argsort(times, kind="stable")
    times, deltas = times[order], deltas[order]
    # Aggregate load right after each event; collapse simultaneous events.
    agg = np.cumsum(deltas, axis=0)
    seg_start = times[:-1]
    seg_end = times[1:]
    load = np.max(agg[:-1], axis=1)            # ||aggregate||_inf per segment
    bins_needed = np.ceil(load - EPS)          # EPS kills float residue
    bins_needed = np.maximum(bins_needed, 0.0)
    return float(np.sum(bins_needed * (seg_end - seg_start)))


def span(inst: Instance) -> float:
    """Total duration in which at least one item is active."""
    if inst.n_items == 0:
        return 0.0
    times = np.concatenate([inst.arrivals, inst.departures])
    deltas = np.concatenate([np.ones(inst.n_items), -np.ones(inst.n_items)])
    order = np.argsort(times, kind="stable")
    times, deltas = times[order], deltas[order]
    count = np.cumsum(deltas)
    active = count[:-1] > 0
    return float(np.sum((times[1:] - times[:-1])[active]))
