"""Prediction-error models for the learning-augmented setting (paper §VI-C,
Appendix E).

Log-normal: delta ~ LogNormal(mu=0, sigma); Pdur = delta * Rdur.  sigma=0 is
perfect prediction.  Simulates rare-but-large ML prediction failures.

Uniform: delta ~ U[1, eps], fair coin for under/over-estimation;
Pdur = Rdur/delta or delta*Rdur.  eps=1 is perfect prediction.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .types import Instance


def lognormal_predictions(inst: Instance, sigma: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    delta = np.exp(rng.normal(0.0, sigma, inst.n_items)) if sigma > 0 else \
        np.ones(inst.n_items)
    return inst.durations * delta


def uniform_predictions(inst: Instance, eps: float, seed: int = 0) -> np.ndarray:
    assert eps >= 1
    rng = np.random.default_rng(seed)
    delta = rng.uniform(1.0, eps, inst.n_items)
    over = rng.random(inst.n_items) < 0.5
    return np.where(over, inst.durations * delta, inst.durations / delta)


def lognormal_predictions_batch(inst: Instance, sigma: float,
                                seeds: Sequence[int]) -> np.ndarray:
    """(n_seeds, n_items) predicted durations for the batched sweep runner.

    Row ``s`` is ``lognormal_predictions(inst, sigma, seed=seeds[s])``, so
    sweep results stay stable when the seed list grows."""
    return np.stack([lognormal_predictions(inst, sigma, seed=s)
                     for s in seeds])


def uniform_predictions_batch(inst: Instance, eps: float,
                              seeds: Sequence[int]) -> np.ndarray:
    """(n_seeds, n_items) stack of ``uniform_predictions``, one seed per
    row (same seed-stability guarantee as the log-normal variant)."""
    return np.stack([uniform_predictions(inst, eps, seed=s)
                     for s in seeds])
