"""Prediction-error models for the learning-augmented setting (paper §VI-C,
Appendix E).

Log-normal: delta ~ LogNormal(mu=0, sigma); Pdur = delta * Rdur.  sigma=0 is
perfect prediction.  Simulates rare-but-large ML prediction failures.

Uniform: delta ~ U[1, eps], fair coin for under/over-estimation;
Pdur = Rdur/delta or delta*Rdur.  eps=1 is perfect prediction.
"""
from __future__ import annotations

import numpy as np

from .types import Instance


def lognormal_predictions(inst: Instance, sigma: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    delta = np.exp(rng.normal(0.0, sigma, inst.n_items)) if sigma > 0 else \
        np.ones(inst.n_items)
    return inst.durations * delta


def uniform_predictions(inst: Instance, eps: float, seed: int = 0) -> np.ndarray:
    assert eps >= 1
    rng = np.random.default_rng(seed)
    delta = rng.uniform(1.0, eps, inst.n_items)
    over = rng.random(inst.n_items) < 0.5
    return np.where(over, inst.durations * delta, inst.durations / delta)
