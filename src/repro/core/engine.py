"""Event-driven oracle simulator for MinUsageTime DVBP.

This is the exact reference engine: a heap-driven replay of one instance under
one online packing algorithm.  It owns bin state (``BinPool``), drives real
arrivals/departures, accounts accumulated bin usage time, and verifies the
capacity invariant after every placement.

Departures at time t are processed before arrivals at time t because item
intervals are half-open [arrival, departure).
"""
from __future__ import annotations

import heapq
from typing import Optional

import numpy as np

from .bins import BinPool
from .types import Arrival, Instance, PackingResult


def run(instance: Instance, algorithm, predicted_durations: Optional[np.ndarray] = None,
        clairvoyant: Optional[bool] = None) -> PackingResult:
    """Replay ``instance`` under ``algorithm``.

    predicted_durations:
      * None and algorithm.requires_predictions  -> clairvoyant (pdep = real)
      * None otherwise                           -> non-clairvoyant (pdep hidden)
      * array (n,)                               -> learning-augmented
    ``clairvoyant`` forces pdep visibility regardless of the algorithm flag.
    """
    inst = instance
    n = inst.n_items
    reveal = algorithm.requires_predictions if clairvoyant is None else clairvoyant
    if predicted_durations is not None:
        pdeps = inst.arrivals + predicted_durations
        reveal = True
    else:
        pdeps = inst.departures  # perfect predictions == clairvoyant

    pool = BinPool(inst.d)
    algorithm.bind(pool, inst)

    placements = np.full(n, -1, np.int64)
    opened_at = {}
    usage = 0.0
    span = 0.0
    span_start = None
    peak_open = 0
    heap = []  # (real departure, tiebreak, item idx, bin idx)
    i = 0
    while i < n or heap:
        next_arr = inst.arrivals[i] if i < n else np.inf
        if heap and heap[0][0] <= next_arr:
            t, _, item, idx = heapq.heappop(heap)
            pool.remove(idx, inst.sizes[item])
            algorithm.on_departed(item, idx, t, inst.sizes[item])
            if pool.n_active[idx] == 0:
                usage += t - opened_at.pop(idx)
                pool.close_bin(idx)
                algorithm.on_closed(idx, t)
                if not pool._open_list:
                    span += t - span_start
                    span_start = None
            continue
        # --- arrival of item i
        now = float(inst.arrivals[i])
        arr = Arrival(i, inst.sizes[i], now, float(pdeps[i]) if reveal else None)
        idx = algorithm.select_bin(arr)
        opened = idx < 0
        if opened:
            if span_start is None and not pool._open_list:
                span_start = now
            idx = pool.open_bin(now)
            opened_at[idx] = now
        else:
            assert pool.alive[idx], f"algorithm chose closed bin {idx}"
        # indicated_close is always maintained from the prediction clock
        # (pdeps); non-clairvoyant algorithms never read it.
        pool.place(idx, arr.size, float(pdeps[i]), now)
        algorithm.on_placed(arr, idx, opened)
        placements[i] = idx
        heapq.heappush(heap, (float(inst.departures[i]), i, i, idx))
        peak_open = max(peak_open, len(pool._open_list))
        i += 1

    assert not pool._open_list, "all bins must close once every item departed"
    return PackingResult(usage_time=usage, n_bins_opened=pool.n_bins,
                         peak_open_bins=peak_open, placements=placements,
                         algorithm=algorithm.name, instance=inst.name, span=span)
