"""Vectorized DVBP trace replay as a jax.lax.scan - the TPU-native engine.

The CPU oracle (core.engine) walks a heap; on an accelerator the same replay
becomes a scan over the precomputed event sequence (2n events: departures
before arrivals at equal times) with a fixed pool of bin slots.  Each step is
an O(slots x d) vector op - the same feasibility+score math as the
kernels/fitscore Pallas kernel, which replaces the inline scoring on TPU.

Supported policies: the score-based Any Fit family (first_fit, best_fit l1 /
l2 / linf, mru, greedy, nrt_standard, nrt_prioritized) - exactly the family
the serving scheduler runs on-device.  Category-structured policies (hybrid,
RCP/PPE) stay on the host engine.

Closed slots are reused; usage time accrues per open episode, so results
match the paper's semantics exactly (validated against the oracle in
tests/test_jaxsim.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .types import EPS, Instance

POLICIES = ("first_fit", "best_fit_l1", "best_fit_l2", "best_fit_linf",
            "mru", "greedy", "nrt_standard", "nrt_prioritized")
NEG = -1e30
BIG = 1e30


@dataclasses.dataclass
class JaxSimResult:
    usage_time: float
    n_bins_opened: int
    placements: np.ndarray
    overflowed: bool


F32_EPS = 1e-6   # fp32-appropriate capacity tolerance (oracle uses 1e-9/f64)


def _score(policy: str, loads, alive, open_seq, access_seq, closes, size,
           pdep, now):
    """Lower is better; +BIG means infeasible."""
    feasible = jnp.all(size[None, :] <= 1.0 - loads + F32_EPS, axis=1) & alive
    if policy == "first_fit":
        s = open_seq.astype(jnp.float32)
    elif policy == "mru":
        s = -access_seq.astype(jnp.float32)
    elif policy.startswith("best_fit"):
        after = 1.0 - loads - size[None, :]
        if policy.endswith("l1"):
            s = after.sum(1)
        elif policy.endswith("l2"):
            s = jnp.sqrt(jnp.sum(after * after, 1))
        else:
            s = after.max(1)
    elif policy == "greedy":
        s = -jnp.maximum(closes, now)
    elif policy == "nrt_standard":
        s = jnp.abs(jnp.maximum(closes, now) - pdep)
    else:   # nrt_prioritized: case (a) bins strictly before case (b);
        # explicit two-stage select (a fp32 additive offset would absorb
        # the case-b ordering)
        eff = jnp.maximum(closes, now)
        gap = eff - pdep
        sa = jnp.where(feasible & (gap >= 0), gap, BIG)
        sb = jnp.where(feasible & (gap < 0), -gap, BIG)
        return jnp.where(jnp.any(sa < BIG), sa, sb)
    return jnp.where(feasible, s, BIG)


@partial(jax.jit, static_argnames=("policy", "max_bins"))
def _simulate(sizes, times, kinds, items, pdeps, *, policy: str,
              max_bins: int):
    n_slots = max_bins
    d = sizes.shape[1]

    def step(carry, ev):
        (loads, counts, alive, open_seq, access_seq, closes, open_time,
         placements, usage, seq, opened, overflow) = carry
        t, kind, j = ev
        j = j.astype(jnp.int32)
        size = sizes[j]
        is_arr = kind == 1

        # ---- departure branch data
        b_dep = placements[j]
        loads_dep = loads.at[b_dep].add(-size)
        counts_dep = counts.at[b_dep].add(-1)
        closing = counts_dep[b_dep] == 0
        usage_dep = usage + jnp.where(closing, t - open_time[b_dep], 0.0)
        alive_dep = alive.at[b_dep].set(jnp.where(closing, False,
                                                  alive[b_dep]))
        loads_dep = loads_dep.at[b_dep].set(
            jnp.where(closing, jnp.zeros(d), loads_dep[b_dep]))
        closes_dep = closes.at[b_dep].set(
            jnp.where(closing, NEG, closes[b_dep]))

        # ---- arrival branch data
        s = _score(policy, loads, alive, open_seq, access_seq, closes,
                   size, pdeps[j], t)
        # two-stage selection: min score, ties broken by opening order (the
        # oracle iterates open bins in opening order and takes the first)
        smin = jnp.min(s)
        tie = s <= smin
        best = jnp.argmin(jnp.where(tie, open_seq, jnp.int32(2 ** 30)))
        found = smin < BIG
        # open a fresh slot: smallest index with count==0 (closed/virgin)
        free = jnp.argmin(jnp.where(counts == 0, jnp.arange(n_slots),
                                    n_slots + 1))
        no_free = counts[free] != 0
        b = jnp.where(found, best, free).astype(jnp.int32)
        overflow_arr = overflow | (~found & no_free)
        loads_arr = loads.at[b].add(size)
        counts_arr = counts.at[b].add(1)
        alive_arr = alive.at[b].set(True)
        open_seq_arr = open_seq.at[b].set(
            jnp.where(found, open_seq[b], seq))
        open_time_arr = open_time.at[b].set(
            jnp.where(found, open_time[b], t))
        access_arr = access_seq.at[b].set(seq)
        closes_arr = closes.at[b].set(
            jnp.maximum(jnp.where(found, closes[b], NEG),
                        jnp.maximum(pdeps[j], t)))
        placements_arr = placements.at[j].set(b)
        opened_arr = opened + jnp.where(found, 0, 1)

        pick = lambda a_val, d_val: jax.tree.map(
            lambda x, y: jnp.where(is_arr, x, y), a_val, d_val)
        carry = pick(
            (loads_arr, counts_arr, alive_arr, open_seq_arr, access_arr,
             closes_arr, open_time_arr, placements_arr, usage, seq + 1,
             opened_arr, overflow_arr),
            (loads_dep, counts_dep, alive_dep, open_seq, access_seq,
             closes_dep, open_time, placements, usage_dep, seq, opened,
             overflow))
        return carry, None

    n = sizes.shape[0]
    init = (jnp.zeros((n_slots, d)), jnp.zeros(n_slots, jnp.int32),
            jnp.zeros(n_slots, bool), jnp.zeros(n_slots, jnp.int32),
            jnp.full(n_slots, -1, jnp.int32), jnp.full(n_slots, NEG),
            jnp.zeros(n_slots), jnp.full(n, -1, jnp.int32), 0.0,
            jnp.int32(0), jnp.int32(0), jnp.bool_(False))
    carry, _ = jax.lax.scan(step, init, (times, kinds, items))
    return carry[8], carry[10], carry[7], carry[11]


def simulate(inst: Instance, policy: str = "first_fit",
             predicted_durations: Optional[np.ndarray] = None,
             max_bins: int = 256) -> JaxSimResult:
    assert policy in POLICIES, policy
    n = inst.n_items
    pdeps = inst.departures if predicted_durations is None \
        else inst.arrivals + predicted_durations
    # event sequence: departures sort before arrivals at equal times
    times = np.concatenate([inst.arrivals, inst.departures])
    kinds = np.concatenate([np.ones(n, np.int32), np.zeros(n, np.int32)])
    items = np.concatenate([np.arange(n), np.arange(n)]).astype(np.int32)
    order = np.lexsort((np.arange(2 * n), kinds, times))
    usage, opened, placements, overflow = _simulate(
        jnp.asarray(inst.sizes), jnp.asarray(times[order]),
        jnp.asarray(kinds[order]), jnp.asarray(items[order]),
        jnp.asarray(pdeps), policy=policy, max_bins=max_bins)
    return JaxSimResult(float(usage), int(opened),
                        np.asarray(placements), bool(overflow))
