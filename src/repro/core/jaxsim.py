"""Vectorized DVBP trace replay as a jax.lax.scan - the TPU-native engine.

The CPU oracle (core.engine) walks a heap; on an accelerator the same replay
becomes a scan over the precomputed event sequence (2n events: departures
before arrivals at equal times) with a fixed pool of bin slots.  Each step is
an O(slots x d) vector op.

Supported policies: the score-based Any Fit family (first_fit, best_fit l1 /
l2 / linf, mru, greedy, nrt_standard, nrt_prioritized) - exactly the family
the serving scheduler runs on-device.  Category-structured policies (hybrid,
RCP/PPE) stay on the host engine.

Closed slots are reused; usage time accrues per open episode, so results
match the paper's semantics exactly (validated against the oracle in
tests/test_jaxsim.py).

Two replay cores share one step semantics:

  * ``_replay`` - one lane, ``jax.vmap``-able, inline jnp scoring
    (``_select_slot``).  ``repro.sweep`` vmaps it over a padded batch on
    the "jnp" backend.
  * ``_replay_batch`` - an explicit lane axis, one scan over the event
    *index* whose per-step placement decision is a single lane-batched op:
    the fused ``kernels.fitscore.fitscore_select_batch`` Pallas kernel on
    the "pallas" / "pallas_interpret" backends (feasibility + policy score
    + opening-order tie-break + free-slot selection in one VMEM-tiled pass,
    zero host round-trips per step), or the vmapped ``_select_slot`` on
    "jnp".

The backend switch (``BACKENDS`` / ``resolve_backend``; "auto" = Pallas on
TPU, jnp elsewhere, override with REPRO_FITSCORE_BACKEND) feeds
``simulate`` and ``repro.sweep.runner``.  Kernel and jnp paths are
bit-identical on fp32-exact instances - the scoring constants and policy
list are imported from ``kernels.fitscore`` so they cannot drift
(tests/test_fitscore_select.py).

Batch padding conventions (produced by ``repro.sweep.batching``):

  * events with ``kind == PAD_KIND`` are no-ops (the carry passes through
    unchanged), which is how shorter instances ride in a ``(B, 2 n_max)``
    event tensor;
  * an optional per-instance ``dmask`` marks which of the (padded) size
    dimensions are real, so best-fit scores ignore zero-padded dimensions
    (zero-size dims are always feasible but would otherwise poison the
    l_inf residual score).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.fitscore import (F32_EPS, IBIG, SCORE_BIG, SCORE_NEG,
                                SELECT_POLICIES, fitscore_select_batch)
from .types import EPS, Instance

# Scoring semantics are shared with the Pallas kernel (kernels/fitscore.py
# is the single definition site so the two paths cannot drift).
POLICIES = SELECT_POLICIES
NEG = SCORE_NEG
BIG = SCORE_BIG

# Event kinds in the precomputed sequence.
ARRIVAL_KIND = 1
DEPARTURE_KIND = 0
PAD_KIND = -1

# Slot-pool escalation schedule shared by simulate() and repro.sweep.runner.
MAX_BINS_CAP = 65536

# Scoring/selection backends.  "auto" resolves to the Pallas kernel on TPU
# and the inline jnp path elsewhere; "pallas_interpret" runs the kernel body
# in interpret mode (the CPU correctness harness).
BACKENDS = ("auto", "jnp", "pallas", "pallas_interpret")


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve a backend name (or REPRO_FITSCORE_BACKEND / "auto")."""
    import os
    backend = backend or os.environ.get("REPRO_FITSCORE_BACKEND", "auto")
    assert backend in BACKENDS, backend
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


def grow_max_bins(max_bins: int, cap: int = MAX_BINS_CAP) -> int:
    """Next rung of the overflow-escalation ladder (doubling, capped)."""
    return min(max(2 * max_bins, 1), cap)


@dataclasses.dataclass
class JaxSimResult:
    usage_time: float
    n_bins_opened: int
    placements: np.ndarray
    overflowed: bool
    max_bins: int = 0   # slot-pool size that produced this result


def _score(policy: str, loads, alive, open_seq, access_seq, closes, size,
           pdep, now, dmask=None):
    """Lower is better; +BIG means infeasible.

    ``dmask`` (d,) marks real dimensions when sizes are zero-padded to a
    common d; zero-size padded dims never affect feasibility but must be
    excluded from the best-fit residual norms.
    """
    feasible = jnp.all(size[None, :] <= 1.0 - loads + F32_EPS, axis=1) & alive
    if policy == "first_fit":
        s = open_seq.astype(jnp.float32)
    elif policy == "mru":
        s = -access_seq.astype(jnp.float32)
    elif policy.startswith("best_fit"):
        after = 1.0 - loads - size[None, :]
        if policy.endswith("l1"):
            after = after if dmask is None else after * dmask
            s = after.sum(1)
        elif policy.endswith("l2"):
            after = after if dmask is None else after * dmask
            s = jnp.sqrt(jnp.sum(after * after, 1))
        else:
            if dmask is not None:
                after = jnp.where(dmask > 0, after, NEG)
            s = after.max(1)
    elif policy == "greedy":
        s = -jnp.maximum(closes, now)
    elif policy == "nrt_standard":
        s = jnp.abs(jnp.maximum(closes, now) - pdep)
    else:   # nrt_prioritized: case (a) bins strictly before case (b);
        # explicit two-stage select (a fp32 additive offset would absorb
        # the case-b ordering)
        eff = jnp.maximum(closes, now)
        gap = eff - pdep
        sa = jnp.where(feasible & (gap >= 0), gap, BIG)
        sb = jnp.where(feasible & (gap < 0), -gap, BIG)
        return jnp.where(jnp.any(sa < BIG), sa, sb)
    return jnp.where(feasible, s, BIG)


def _select_slot(policy, loads, counts, alive, open_seq, access_seq, closes,
                 size, pdep, now, dmask):
    """The fused placement decision, inline-jnp flavor: min score with ties
    broken by opening order (the oracle iterates open bins in opening order
    and takes the first), falling back to the smallest closed/virgin slot.
    Returns (slot, found, no_free) - the contract the Pallas kernel
    (``kernels.fitscore.fitscore_select_batch``) reproduces bit-for-bit."""
    n_slots = loads.shape[0]
    s = _score(policy, loads, alive, open_seq, access_seq, closes, size,
               pdep, now, dmask)
    smin = jnp.min(s)
    tie = s <= smin
    best = jnp.argmin(jnp.where(tie, open_seq, jnp.int32(IBIG)))
    found = smin < BIG
    free = jnp.argmin(jnp.where(counts == 0, jnp.arange(n_slots),
                                n_slots + 1))
    no_free = counts[free] != 0
    b = jnp.where(found, best, free).astype(jnp.int32)
    return b, found, no_free


def _replay(sizes, times, kinds, items, pdeps, dmask, *, policy: str,
            max_bins: int):
    """One instance's event replay; pure function of its array arguments,
    safe to ``jax.vmap`` over a leading batch axis of every argument."""
    n_slots = max_bins
    d = sizes.shape[1]

    def step(carry, ev):
        (loads, counts, alive, open_seq, access_seq, closes, open_time,
         placements, usage, seq, opened, overflow) = carry
        t, kind, j = ev
        j = j.astype(jnp.int32)
        size = sizes[j]
        is_arr = kind == ARRIVAL_KIND
        is_pad = kind == PAD_KIND

        # ---- departure branch data
        b_dep = placements[j]
        loads_dep = loads.at[b_dep].add(-size)
        counts_dep = counts.at[b_dep].add(-1)
        closing = counts_dep[b_dep] == 0
        usage_dep = usage + jnp.where(closing, t - open_time[b_dep], 0.0)
        alive_dep = alive.at[b_dep].set(jnp.where(closing, False,
                                                  alive[b_dep]))
        loads_dep = loads_dep.at[b_dep].set(
            jnp.where(closing, jnp.zeros(d), loads_dep[b_dep]))
        closes_dep = closes.at[b_dep].set(
            jnp.where(closing, NEG, closes[b_dep]))

        # ---- arrival branch data
        b, found, no_free = _select_slot(policy, loads, counts, alive,
                                         open_seq, access_seq, closes, size,
                                         pdeps[j], t, dmask)
        overflow_arr = overflow | (~found & no_free)
        loads_arr = loads.at[b].add(size)
        counts_arr = counts.at[b].add(1)
        alive_arr = alive.at[b].set(True)
        open_seq_arr = open_seq.at[b].set(
            jnp.where(found, open_seq[b], seq))
        open_time_arr = open_time.at[b].set(
            jnp.where(found, open_time[b], t))
        access_arr = access_seq.at[b].set(seq)
        closes_arr = closes.at[b].set(
            jnp.maximum(jnp.where(found, closes[b], NEG),
                        jnp.maximum(pdeps[j], t)))
        placements_arr = placements.at[j].set(b)
        opened_arr = opened + jnp.where(found, 0, 1)

        pick = lambda a_val, d_val: jax.tree.map(
            lambda x, y: jnp.where(is_arr, x, y), a_val, d_val)
        new = pick(
            (loads_arr, counts_arr, alive_arr, open_seq_arr, access_arr,
             closes_arr, open_time_arr, placements_arr, usage, seq + 1,
             opened_arr, overflow_arr),
            (loads_dep, counts_dep, alive_dep, open_seq, access_seq,
             closes_dep, open_time, placements, usage_dep, seq, opened,
             overflow))
        # padded events are no-ops: the carry passes through untouched
        carry = jax.tree.map(lambda new_x, old_x: jnp.where(is_pad, old_x,
                                                            new_x),
                             new, carry)
        return carry, None

    n = sizes.shape[0]
    init = (jnp.zeros((n_slots, d)), jnp.zeros(n_slots, jnp.int32),
            jnp.zeros(n_slots, bool), jnp.zeros(n_slots, jnp.int32),
            jnp.full(n_slots, -1, jnp.int32), jnp.full(n_slots, NEG),
            jnp.zeros(n_slots), jnp.full(n, -1, jnp.int32), 0.0,
            jnp.int32(0), jnp.int32(0), jnp.bool_(False))
    carry, _ = jax.lax.scan(step, init, (times, kinds, items))
    return carry[8], carry[10], carry[7], carry[11]


def _replay_batch(sizes, times, kinds, items, pdeps, dmask, *, policy: str,
                  max_bins: int, backend: str = "jnp"):
    """``L`` lanes' event replays in lockstep: one scan over the event
    *index* whose step processes every lane at once, so the arrival scoring
    is a single (L, slots, d) op - on TPU the fused
    ``kernels.fitscore.fitscore_select_batch`` Pallas kernel, with zero host
    round-trips per step.

    Same argument convention as ``_replay`` with a leading lane axis on
    every array (``dmask`` may be None); same return tuple with a leading
    lane axis.  ``backend="jnp"`` selects with the inline vmapped
    ``_select_slot`` (bit-identical to the vmapped ``_replay`` path);
    "pallas"/"pallas_interpret" run the kernel natively / in interpret mode.
    """
    L, n_max, d = sizes.shape
    n_slots = max_bins
    lanes = jnp.arange(L)
    dmask_full = jnp.ones((L, d)) if dmask is None else dmask

    def step(carry, ev):
        (loads, counts, alive, open_seq, access_seq, closes, open_time,
         placements, usage, seq, opened, overflow) = carry
        t, kind, j = ev                       # (L,) each
        j = j.astype(jnp.int32)
        size = jnp.take_along_axis(sizes, j[:, None, None], axis=1)[:, 0]
        pdep_j = jnp.take_along_axis(pdeps, j[:, None], axis=1)[:, 0]
        is_arr = kind == ARRIVAL_KIND
        is_pad = kind == PAD_KIND

        # ---- departure branch data
        b_dep = jnp.take_along_axis(placements, j[:, None], axis=1)[:, 0]
        loads_dep = loads.at[lanes, b_dep].add(-size)
        counts_dep = counts.at[lanes, b_dep].add(-1)
        closing = counts_dep[lanes, b_dep] == 0
        usage_dep = usage + jnp.where(closing, t - open_time[lanes, b_dep],
                                      0.0)
        alive_dep = alive.at[lanes, b_dep].set(
            jnp.where(closing, False, alive[lanes, b_dep]))
        loads_dep = loads_dep.at[lanes, b_dep].set(
            jnp.where(closing[:, None], jnp.zeros((L, d)),
                      loads_dep[lanes, b_dep]))
        closes_dep = closes.at[lanes, b_dep].set(
            jnp.where(closing, NEG, closes[lanes, b_dep]))

        # ---- arrival branch data
        if backend == "jnp":
            b, found, no_free = jax.vmap(partial(_select_slot, policy))(
                loads, counts, alive, open_seq, access_seq, closes, size,
                pdep_j, t, dmask_full)
        else:
            b, found, no_free = fitscore_select_batch(
                loads, counts, alive, open_seq, access_seq, closes, size,
                pdep_j, t, dmask_full, policy=policy,
                interpret=(backend == "pallas_interpret"))
        b = b.astype(jnp.int32)
        overflow_arr = overflow | (~found & no_free)
        loads_arr = loads.at[lanes, b].add(size)
        counts_arr = counts.at[lanes, b].add(1)
        alive_arr = alive.at[lanes, b].set(True)
        open_seq_arr = open_seq.at[lanes, b].set(
            jnp.where(found, open_seq[lanes, b], seq))
        open_time_arr = open_time.at[lanes, b].set(
            jnp.where(found, open_time[lanes, b], t))
        access_arr = access_seq.at[lanes, b].set(seq)
        closes_arr = closes.at[lanes, b].set(
            jnp.maximum(jnp.where(found, closes[lanes, b], NEG),
                        jnp.maximum(pdep_j, t)))
        placements_arr = placements.at[lanes, j].set(b)
        opened_arr = opened + jnp.where(found, 0, 1)

        def pick(cond, a_val, d_val):
            return jax.tree.map(
                lambda x, y: jnp.where(
                    cond.reshape(cond.shape + (1,) * (x.ndim - 1)), x, y),
                a_val, d_val)
        new = pick(
            is_arr,
            (loads_arr, counts_arr, alive_arr, open_seq_arr, access_arr,
             closes_arr, open_time_arr, placements_arr, usage, seq + 1,
             opened_arr, overflow_arr),
            (loads_dep, counts_dep, alive_dep, open_seq, access_seq,
             closes_dep, open_time, placements, usage_dep, seq, opened,
             overflow))
        # padded events are no-ops: the carry passes through untouched
        carry = pick(is_pad, carry, new)
        return carry, None

    init = (jnp.zeros((L, n_slots, d)), jnp.zeros((L, n_slots), jnp.int32),
            jnp.zeros((L, n_slots), bool),
            jnp.zeros((L, n_slots), jnp.int32),
            jnp.full((L, n_slots), -1, jnp.int32),
            jnp.full((L, n_slots), NEG), jnp.zeros((L, n_slots)),
            jnp.full((L, n_max), -1, jnp.int32), jnp.zeros(L),
            jnp.zeros(L, jnp.int32), jnp.zeros(L, jnp.int32),
            jnp.zeros(L, bool))
    xs = tuple(jnp.swapaxes(a, 0, 1) for a in (times, kinds, items))
    carry, _ = jax.lax.scan(step, init, xs)
    return carry[8], carry[10], carry[7], carry[11]


@partial(jax.jit, static_argnames=("policy", "max_bins"))
def _simulate(sizes, times, kinds, items, pdeps, *, policy: str,
              max_bins: int):
    return _replay(sizes, times, kinds, items, pdeps, None,
                   policy=policy, max_bins=max_bins)


@partial(jax.jit, static_argnames=("policy", "max_bins", "backend"))
def _simulate_kernel(sizes, times, kinds, items, pdeps, *, policy: str,
                     max_bins: int, backend: str):
    u, o, p, ov = _replay_batch(sizes[None], times[None], kinds[None],
                                items[None], pdeps[None], None,
                                policy=policy, max_bins=max_bins,
                                backend=backend)
    return u[0], o[0], p[0], ov[0]


def event_sequence(inst: Instance):
    """(times, kinds, items) int32/float arrays, departures sorted before
    arrivals at equal times (half-open [arrival, departure) intervals).
    Shared by simulate() and the repro.sweep batching layer."""
    n = inst.n_items
    times = np.concatenate([inst.arrivals, inst.departures])
    kinds = np.concatenate([np.full(n, ARRIVAL_KIND, np.int32),
                            np.full(n, DEPARTURE_KIND, np.int32)])
    items = np.concatenate([np.arange(n), np.arange(n)]).astype(np.int32)
    order = np.lexsort((np.arange(2 * n), kinds, times))
    return times[order], kinds[order], items[order]


def simulate(inst: Instance, policy: str = "first_fit",
             predicted_durations: Optional[np.ndarray] = None,
             max_bins: int = 256, auto_grow: bool = True,
             max_bins_cap: int = MAX_BINS_CAP,
             backend: Optional[str] = None) -> JaxSimResult:
    """Replay one instance.  If the slot pool overflows and ``auto_grow`` is
    set, retries with a doubled ``max_bins`` (up to ``max_bins_cap``) instead
    of returning garbage - the same escalation ladder the batched sweep
    runner applies per lane.  ``backend`` picks the scoring engine (see
    ``BACKENDS``); the default "auto" resolves to the Pallas kernel on TPU
    and the inline jnp scan step elsewhere."""
    assert policy in POLICIES, policy
    backend = resolve_backend(backend)
    pdeps = inst.departures if predicted_durations is None \
        else inst.arrivals + predicted_durations
    times, kinds, items = event_sequence(inst)
    sizes_j, times_j = jnp.asarray(inst.sizes), jnp.asarray(times)
    kinds_j, items_j = jnp.asarray(kinds), jnp.asarray(items)
    pdeps_j = jnp.asarray(pdeps)
    while True:
        if backend == "jnp":
            usage, opened, placements, overflow = _simulate(
                sizes_j, times_j, kinds_j, items_j, pdeps_j,
                policy=policy, max_bins=max_bins)
        else:
            usage, opened, placements, overflow = _simulate_kernel(
                sizes_j, times_j, kinds_j, items_j, pdeps_j,
                policy=policy, max_bins=max_bins, backend=backend)
        if not bool(overflow) or not auto_grow or max_bins >= max_bins_cap:
            break
        max_bins = grow_max_bins(max_bins, max_bins_cap)
    return JaxSimResult(float(usage), int(opened),
                        np.asarray(placements), bool(overflow), max_bins)
