"""Vectorized DVBP trace replay as a jax.lax.scan - the TPU-native engine.

The CPU oracle (core.engine) walks a heap; on an accelerator the same replay
becomes a scan over the precomputed event sequence (2n events: departures
before arrivals at equal times) with a fixed pool of bin slots.  Each step is
an O(lanes x slots x d) vector op.

``_replay_batch`` is the single replay engine for *every* policy family:

  * the score-based Any Fit family (``POLICIES``: first_fit, best_fit l1 /
    l2 / linf, mru, greedy, nrt_standard, nrt_prioritized), and
  * the category-structured families (``CATEGORY_POLICIES``): CBD / CBDT,
    Hybrid / Reduced Hybrid (+ direct-sum), RCP / PPE (+ modified),
    Lifetime Alignment (binary / geometric), and the adaptive switch.

Category policies replay in the same scan by extending the carry with
category state - a per-slot category tag (duration x arrival-window class
for the Hybrid variants, beta/rho class for CBD/CBDT, the GENERAL / BASE /
LARGE roles plus geometric prediction buckets X_i for RCP/PPE) and carried
scalars (RCP's base-bin index, PPE's guess-and-double alpha, the adaptive
switch's running departure error) - while per-item categories, thresholds
and error terms are pure functions of the (predicted) durations, computed
once before the scan from the shared categorization functions in
``core.algorithms.{duration,learned,adaptive}``.  Slot selection is then
"feasible AND category-compatible": the same fused select with an extra
category-mask input, so all families share one step body and one kernel.

Backends (``BACKENDS`` / ``resolve_backend``; "auto" = Pallas on TPU, jnp
elsewhere, override with REPRO_FITSCORE_BACKEND):

  * "jnp" - the per-step placement decision is the vmapped inline
    ``_select_slot``; the carry stays in compact (max_bins, d) layout.
  * "pallas" / "pallas_interpret" - the decision is the fused
    ``kernels.fitscore.fitscore_select_batch_padded`` kernel (feasibility +
    policy score + category mask + opening-order tie-break + free-slot
    selection in one VMEM-tiled pass, zero host round-trips per step).  The
    whole carry lives in the kernel's padded (Np, dpad) layout - padded
    once before the scan and unpadded never (outputs are per-lane scalars),
    instead of re-padding the state every step (~25x redundant data traffic
    at d=5).

    With ``block_events=T > 1`` the kernel backends go one rung further:
    the scan runs over *event blocks*, each block replayed entirely
    on-chip by ``kernels.fitscore.fitscore_replay_block`` (departure
    application, category update, masked select and commit for T events
    per invocation) with the packed carry resident in VMEM - the carry
    round-trips through HBM once per block instead of once per event.
    Execution knob only: decisions are identical
    (tests/test_replay_block.py).

Kernel and jnp paths are bit-identical on fp32-exact instances - the
scoring constants and policy list are imported from ``kernels.fitscore`` so
the paths cannot drift (tests/test_fitscore_select.py,
tests/test_sweep_categories.py).

Batch padding conventions (produced by ``repro.sweep.batching``):

  * events with ``kind == PAD_KIND`` are no-ops (the carry passes through
    unchanged), which is how shorter instances ride in a ``(B, 2 n_max)``
    event tensor;
  * an optional per-instance ``dmask`` marks which of the (padded) size
    dimensions are real, so best-fit scores ignore zero-padded dimensions
    (zero-size dims are always feasible but would otherwise poison the
    l_inf residual score).
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.fitscore import (ARRIVAL_KIND, DEPARTURE_KIND, F32_EPS, IBIG,
                                KCAT, LOC_B, LOC_C, LOC_G, LOC_L,
                                MIGRATE_KIND, PAD_KIND,
                                SCORE_BIG, SCORE_NEG, SELECT_POLICIES,
                                TAG_BASE, TAG_GENERAL, TAG_LARGE, TAG_NONE,
                                TAG_VIRGIN, fitscore_replay_block,
                                fitscore_select_batch_padded,
                                replay_carry_names, select_pad_geometry)
from ..kernels import fitscore as _fk
from .. import obs
from .algorithms.adaptive import pow2_ceiling_jnp, prediction_error_jnp
from .algorithms.departure import departure_window_jnp
from .algorithms.duration import (dur_exponent_jnp, duration_class_jnp,
                                  hybrid_threshold_jnp)
from .algorithms.learned import geo_class_jnp, la_class_jnp
from .types import Instance

# Scoring semantics are shared with the Pallas kernel (kernels/fitscore.py
# is the single definition site so the two paths cannot drift).
POLICIES = SELECT_POLICIES
NEG = SCORE_NEG
BIG = SCORE_BIG

# Category-structured policies replayed by the same scan (tentpole of the
# paper's headline comparisons).  Parametric variants parse too:
# "cbd_beta4", "cbdt_rho3600", "adaptive_2_16".
CATEGORY_POLICIES = ("cbd", "cbdt", "hybrid", "reduced_hybrid",
                     "hybrid_direct_sum", "reduced_hybrid_direct_sum",
                     "rcp", "ppe", "rcp_modified", "ppe_modified",
                     "la_binary", "la_geometric", "adaptive")
SCAN_POLICIES = POLICIES + CATEGORY_POLICIES

# Default CBDT window: 0.25 days, the paper's best fixed rho (Fig. 4/8).
CBDT_DEFAULT_RHO = 0.25 * 86400.0

# KCAT, the TAG_* / LOC_* carry encodings and the ARRIVAL/DEPARTURE/PAD
# event kinds are imported from kernels.fitscore (the shared definition
# site with the event-blocked replay megakernel) and re-exported here.

# Slot-pool escalation schedule shared by simulate() and repro.sweep.runner.
# The ceiling is env-overridable so capacity-constrained deployments can pin
# it below (or above) the default without code changes.
MAX_BINS_CAP = int(os.environ.get("REPRO_MAX_BINS_CAP", "65536"))


class CapacityError(RuntimeError):
    """The overflow-escalation ladder hit its ceiling and the replay still
    overflows: the instance genuinely needs more than ``max_bins_cap``
    concurrently open bins (or the cap is misconfigured).  Carries the
    offending policy / instance / final pool size so sweep drivers can
    report *which* lane blew up instead of a bare flag."""

    def __init__(self, message: str, *, policy: str = "", max_bins: int = 0,
                 instance: str = ""):
        super().__init__(message)
        self.policy = policy
        self.max_bins = max_bins
        self.instance = instance

# Scoring/selection backends.  "auto" resolves to the Pallas kernel on TPU
# and the inline jnp path elsewhere; "pallas_interpret" runs the kernel body
# in interpret mode (the CPU correctness harness).
BACKENDS = ("auto", "jnp", "pallas", "pallas_interpret")


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve a backend name (or REPRO_FITSCORE_BACKEND / "auto")."""
    backend = backend or os.environ.get("REPRO_FITSCORE_BACKEND", "auto")
    assert backend in BACKENDS, backend
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


def grow_max_bins(max_bins: int, cap: int = MAX_BINS_CAP) -> int:
    """Next rung of the overflow-escalation ladder (doubling, capped)."""
    return min(max(2 * max_bins, 1), cap)


# ======================================================================
# Policy specs: one name space over both families
# ======================================================================

@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Static description of how a policy replays in the scan."""

    family: str                 # score | cbd | cbdt | hybrid | rcp | la |
    #                             adaptive
    beta: float = 2.0           # cbd duration base
    rho: float = CBDT_DEFAULT_RHO   # cbdt departure-window width (seconds)
    reduced: bool = False       # hybrid: duration-only categories
    direct_sum: bool = False    # hybrid: per-max-dimension sub-instances
    large_bins: bool = True     # rcp/ppe: dedicated bins for items > 1/2
    adaptive_alpha: bool = False    # ppe: guess-and-double threshold
    la_mode: str = "binary"     # lifetime alignment class structure
    low: float = 2.0            # adaptive regime thresholds
    high: float = 16.0


def _policy_param(policy: str, text: str, what: str) -> float:
    """Parse one numeric parameter of a parametric policy name; unknown /
    non-numeric text is a KeyError (the "not a policy" signal)."""
    try:
        return float(text)
    except ValueError as e:   # malformed parameter, e.g. "cbd_betax"
        raise KeyError(
            f"malformed scan policy {policy!r} ({what}): {e}") from e


def policy_spec(policy: str) -> PolicySpec:
    """Parse a scan policy name (including parametric variants).

    Raises KeyError for unknown or malformed names and ValueError - at
    parse time, naming the valid range - for recognized parametric names
    whose parameter is out of range ("cbd_beta-1", "cbdt_rho0",
    "adaptive_8_2"): those values would otherwise fail deep inside the
    scan (log of a negative base, division by zero) or silently misbehave
    (an adaptive switch whose regimes never trigger)."""
    if policy in SELECT_POLICIES:
        return PolicySpec("score")
    if policy == "cbd" or policy.startswith("cbd_beta"):
        beta = 2.0 if policy == "cbd" else \
            _policy_param(policy, policy[len("cbd_beta"):], "beta")
        if not beta > 1.0:
            raise ValueError(
                f"{policy!r}: cbd beta must be > 1 (duration classes are "
                f"[beta^(i-1), beta^i)); got {beta:g}")
        return PolicySpec("cbd", beta=beta)
    if policy == "cbdt" or policy.startswith("cbdt_rho"):
        rho = CBDT_DEFAULT_RHO if policy == "cbdt" else \
            _policy_param(policy, policy[len("cbdt_rho"):], "rho")
        if not rho > 0.0:
            raise ValueError(
                f"{policy!r}: cbdt rho must be > 0 seconds (the departure-"
                f"window width); got {rho:g}")
        return PolicySpec("cbdt", rho=rho)
    if policy in ("hybrid", "reduced_hybrid", "hybrid_direct_sum",
                  "reduced_hybrid_direct_sum"):
        return PolicySpec("hybrid", reduced="reduced" in policy,
                          direct_sum="direct_sum" in policy)
    if policy in ("rcp", "ppe", "rcp_modified", "ppe_modified"):
        return PolicySpec("rcp", large_bins="modified" not in policy,
                          adaptive_alpha=policy.startswith("ppe"))
    if policy in ("la_binary", "la_geometric"):
        return PolicySpec("la", la_mode=policy[3:])
    if policy == "adaptive" or policy.startswith("adaptive_"):
        if policy == "adaptive":
            return PolicySpec("adaptive")
        parts = policy[len("adaptive_"):].split("_")
        if len(parts) != 2:
            raise KeyError(f"malformed scan policy {policy!r}: expected "
                           "adaptive_LOW_HIGH")
        low = _policy_param(policy, parts[0], "low")
        high = _policy_param(policy, parts[1], "high")
        if not 1.0 <= low <= high:
            raise ValueError(
                f"{policy!r}: adaptive thresholds need 1 <= low <= high "
                f"(departure error is >= 1 by construction); got "
                f"low={low:g} high={high:g}")
        return PolicySpec("adaptive", low=low, high=high)
    raise KeyError(f"unknown scan policy {policy!r}; known: {SCAN_POLICIES}")


def known_policy(policy: str) -> bool:
    """True when ``policy`` replays through ``_replay_batch``.  A
    recognized parametric name with an out-of-range parameter raises the
    parse-time ValueError instead of answering False - callers should see
    "cbd_beta-1" fail loudly, not fall back to a host path."""
    try:
        policy_spec(policy)
        return True
    except KeyError:
        return False


def host_algorithm(policy: str):
    """The oracle-engine algorithm instance equivalent to a scan policy
    (the parity reference used by tests and benchmarks)."""
    from .algorithms import get_algorithm
    spec = policy_spec(policy)
    if spec.family == "score":
        if policy.startswith("best_fit_"):
            return get_algorithm("best_fit", norm=policy.split("_")[-1])
        return get_algorithm(policy)
    if spec.family == "cbd":
        return get_algorithm("cbd", beta=spec.beta)
    if spec.family == "cbdt":
        return get_algorithm("cbdt", rho=spec.rho)
    if spec.family == "la":
        return get_algorithm("lifetime_alignment", mode=spec.la_mode)
    if spec.family == "adaptive":
        return get_algorithm("adaptive", low=spec.low, high=spec.high)
    return get_algorithm(policy)


@dataclasses.dataclass
class JaxSimResult:
    usage_time: float
    n_bins_opened: int
    placements: np.ndarray
    overflowed: bool
    max_bins: int = 0   # slot-pool size that produced this result


# ======================================================================
# The inline jnp placement decision (the kernel's reference twin)
# ======================================================================

def _score(policy, loads, alive, open_seq, access_seq, closes, size,
           pdep, now, dmask=None, cmask=None):
    """Lower is better; +BIG means infeasible.

    ``dmask`` (d,) marks real dimensions when sizes are zero-padded to a
    common d; zero-size padded dims never affect feasibility but must be
    excluded from the best-fit residual norms.  ``cmask`` (n_slots,)
    restricts feasibility to category-compatible slots (None = all)."""
    feasible = jnp.all(size[None, :] <= 1.0 - loads + F32_EPS, axis=1) & alive
    if cmask is not None:
        feasible = feasible & cmask
    if policy == "first_fit":
        s = open_seq.astype(jnp.float32)
    elif policy == "mru":
        s = -access_seq.astype(jnp.float32)
    elif policy.startswith("best_fit"):
        after = 1.0 - loads - size[None, :]
        if policy.endswith("l1"):
            after = after if dmask is None else after * dmask
            s = after.sum(1)
        elif policy.endswith("l2"):
            after = after if dmask is None else after * dmask
            s = jnp.sqrt(jnp.sum(after * after, 1))
        else:
            if dmask is not None:
                after = jnp.where(dmask > 0, after, NEG)
            s = after.max(1)
    elif policy == "greedy":
        s = -jnp.maximum(closes, now)
    elif policy == "nrt_standard":
        s = jnp.abs(jnp.maximum(closes, now) - pdep)
    else:   # nrt_prioritized: case (a) bins strictly before case (b);
        # explicit two-stage select (a fp32 additive offset would absorb
        # the case-b ordering)
        eff = jnp.maximum(closes, now)
        gap = eff - pdep
        sa = jnp.where(feasible & (gap >= 0), gap, BIG)
        sb = jnp.where(feasible & (gap < 0), -gap, BIG)
        return jnp.where(jnp.any(sa < BIG), sa, sb)
    return jnp.where(feasible, s, BIG)


def _select_slot(policy, loads, counts, alive, open_seq, access_seq, closes,
                 size, pdep, now, dmask, cmask=None):
    """The fused placement decision, inline-jnp flavor: min score with ties
    broken by opening order (the oracle iterates open bins in opening order
    and takes the first), falling back to the smallest closed/virgin slot.
    Returns (slot, found, no_free) - the contract the Pallas kernel
    (``kernels.fitscore.fitscore_select_batch``) reproduces bit-for-bit."""
    n_slots = loads.shape[0]
    s = _score(policy, loads, alive, open_seq, access_seq, closes, size,
               pdep, now, dmask, cmask)
    smin = jnp.min(s)
    tie = s <= smin
    best = jnp.argmin(jnp.where(tie, open_seq, jnp.int32(IBIG)))
    found = smin < BIG
    free = jnp.argmin(jnp.where(counts == 0, jnp.arange(n_slots),
                                n_slots + 1))
    no_free = counts[free] != 0
    b = jnp.where(found, best, free).astype(jnp.int32)
    return b, found, no_free


# ======================================================================
# Category machinery: per-item constants + carried state per family
# ======================================================================

def _dense_key_ids(i, cls, win):
    """One lane's dense hybrid key ids: key_id[j] = first item index whose
    (i, cls, win) triple equals item j's - a valid index into an
    (n_max,)-sized aggregate table.  O(n log n) sort + segment-min (the
    pairwise-equality broadcast would be O(n^2) memory, which OOMs on
    real-trace lane sizes)."""
    n = i.shape[0]
    order = jnp.lexsort((win, cls, i))
    si, sc, sw = i[order], cls[order], win[order]
    new = jnp.concatenate([jnp.ones(1, bool), (si[1:] != si[:-1]) |
                           (sc[1:] != sc[:-1]) | (sw[1:] != sw[:-1])])
    grp = jnp.cumsum(new) - 1                   # contiguous group per key
    first = jax.ops.segment_min(order, grp, num_segments=n)
    return jnp.zeros(n, jnp.int32).at[order].set(
        first[grp].astype(jnp.int32))


def _category_state0(spec, L, item_rows, d, Np):
    """Initial carried category state for one policy family - shape-only
    (the placement-dependent state starts empty), so a streamed replay can
    build the same carry without the instance arrays.  ``item_rows`` is the
    item-table length: ``n_max`` for in-memory replays, the recycled pool
    size for streamed ones (``repro.stream``)."""
    f32, i32 = jnp.float32, jnp.int32
    tag0 = jnp.full((L, Np), TAG_VIRGIN, i32)
    if spec.family in ("score", "la"):
        return {}
    if spec.family in ("cbd", "cbdt"):
        return {"tag": tag0}
    if spec.family == "hybrid":
        return {"tag": tag0, "agg": jnp.zeros((L, item_rows, d), f32),
                "ingen": jnp.zeros((L, item_rows), bool)}
    if spec.family == "rcp":
        return {"tag": tag0,
                "agg_gen": jnp.zeros((L, KCAT, d), f32),
                "agg_cat": jnp.zeros((L, KCAT, d), f32),
                "agg_bcat": jnp.zeros((L, KCAT, d), f32),
                "agg_base": jnp.zeros((L, d), f32),
                "on": jnp.zeros((L, KCAT), bool),
                "base": jnp.full((L,), -1, i32),
                "alpha": jnp.ones((L,), f32),
                "loc": jnp.zeros((L, item_rows), i32)}
    assert spec.family == "adaptive", spec.family
    return {"err": jnp.ones((L,), f32)}


def _core_state0(L, Np, dpad, item_rows):
    """The fresh core scan carry (loads, counts, alive, open/access seq,
    closes, open_time, placements, usage, seq, opened, overflow) - exactly
    what ``_replay_batch`` starts from when ``carry0`` is None."""
    i32 = jnp.int32
    return (jnp.zeros((L, Np, dpad)), jnp.zeros((L, Np), i32),
            jnp.zeros((L, Np), bool),
            jnp.zeros((L, Np), i32),
            jnp.full((L, Np), -1, i32),
            jnp.full((L, Np), NEG), jnp.zeros((L, Np)),
            jnp.full((L, item_rows), -1, i32), jnp.zeros(L),
            jnp.zeros(L, i32), jnp.zeros(L, i32),
            jnp.zeros(L, bool))


def _category_setup(spec, sizes, pdeps, dmask, arrivals, rdeps, n_items,
                    times, kinds, items, Np):
    """Per-item category constants, initial carried category state, and
    extra per-event scan inputs for one policy family.

    All pure jnp on the lane-batched arrays: categories, thresholds and
    error terms are functions of the (predicted) durations only, so they
    are computed once here and the scan carries just the placement-dependent
    state (slot tags, aggregates, ON flags, alpha / err scalars)."""
    L, n_max, d = sizes.shape
    f32, i32 = jnp.float32, jnp.int32
    cat0 = _category_state0(spec, L, n_max, d, Np)
    if spec.family == "score":
        return {}, cat0, ()
    assert arrivals is not None and rdeps is not None and n_items is not None, \
        f"{spec.family} lanes need arrivals/rdeps/n_items"
    pdur = pdeps - arrivals

    if spec.family == "cbd":
        return ({"cat": duration_class_jnp(pdur, spec.beta)}, cat0, ())
    if spec.family == "cbdt":
        return ({"cat": departure_window_jnp(pdeps, spec.rho)}, cat0, ())

    if spec.family == "hybrid":
        rdur = rdeps - arrivals
        real = jnp.arange(n_max)[None, :] < n_items[:, None]
        min_dur = jnp.min(jnp.where(real, rdur, jnp.inf), axis=1)
        z = dur_exponent_jnp(min_dur)                    # (L,)
        jexp = dur_exponent_jnp(pdur)                    # (L, n_max)
        i = jnp.maximum(jexp - z[:, None] + 1, 1)        # scaled index >= 1
        thr = hybrid_threshold_jnp(i).astype(f32)
        cls = jnp.argmax(sizes, axis=2).astype(i32) if spec.direct_sum \
            else jnp.zeros((L, n_max), i32)
        win = jnp.zeros((L, n_max), i32) if spec.reduced else \
            jnp.floor(arrivals / jnp.ldexp(jnp.float32(1.0),
                                           jexp)).astype(i32)
        # dense per-lane key ids: a key (cls, i, window) is identified by
        # the first item index carrying it, so aggregates index a fixed
        # (n_max,)-sized table without host round-trips
        key = jax.vmap(_dense_key_ids)(i, cls, win)
        return {"key": key, "thr": thr, "cls": cls}, cat0, ()

    if spec.family == "rcp":
        rdur = rdeps - arrivals
        cat = jnp.clip(geo_class_jnp(jnp.maximum(pdur, 0.0)), 0, KCAT - 1)
        large = jnp.max(sizes, axis=2) > 0.5
        p2err = pow2_ceiling_jnp(
            prediction_error_jnp(rdur, pdur)).astype(f32)
        # x in the 1/sqrt(x) threshold: running count of distinct categories
        # over the arrival events - precomputable because categories are
        # pure functions of the predicted durations
        E = times.shape[1]
        is_arr = kinds == ARRIVAL_KIND
        ev_cat = jnp.take_along_axis(cat, items.astype(i32), axis=1)
        eidx = jnp.arange(E, dtype=i32)
        hot = (ev_cat[:, :, None] == jnp.arange(KCAT, dtype=i32)) & \
            is_arr[:, :, None]
        first = jnp.min(jnp.where(hot, eidx[None, :, None], E), axis=1)
        newflag = is_arr & (eidx[None, :] ==
                            jnp.take_along_axis(first, ev_cat, axis=1))
        xcount = jnp.cumsum(newflag.astype(i32), axis=1)
        return ({"cat": cat, "large": large, "p2err": p2err}, cat0,
                (xcount,))

    if spec.family == "la":
        return ({"cat": la_class_jnp(jnp.maximum(pdur, 0.0), spec.la_mode)},
                cat0, ())

    assert spec.family == "adaptive", spec.family
    rdur = rdeps - arrivals
    return ({"errmax": prediction_error_jnp(rdur, pdur).astype(f32)}, cat0,
            ())


def replay_event_extras(policy, sizes, pdeps, dmask, arrivals, rdeps,
                        n_items, times, kinds, items):
    """The per-event extra scan inputs for one policy, computed on the
    *full* event axis - what a segmented (checkpointed) replay must
    precompute once and slice per segment via ``_replay_batch``'s
    ``ev_extra``.  RCP's running distinct-category count is a cumsum over
    the whole event stream; recomputing it inside a segment would restart
    the count and change decisions.  PAD events are never arrivals, so
    tail padding leaves the cumsum undisturbed.  Returns a (possibly
    empty) tuple of (L, E) arrays."""
    spec = policy_spec(policy)
    if spec.family == "score":
        return ()
    _, _, xs_extra = _category_setup(
        spec, jnp.asarray(sizes), jnp.asarray(pdeps), dmask,
        jnp.asarray(arrivals), jnp.asarray(rdeps), jnp.asarray(n_items),
        jnp.asarray(times), jnp.asarray(kinds), jnp.asarray(items), 1)
    return xs_extra


# ======================================================================
# The event-blocked replay path (kernel backends, block_events > 1)
# ======================================================================

# policy_spec family -> megakernel family (cbd and cbdt share the
# class-restricted First Fit body; only the per-item class constant differs)
_KERNEL_FAMILY = {"score": "score", "cbd": "cbd", "cbdt": "cbd",
                  "hybrid": "hybrid", "rcp": "rcp", "la": "la",
                  "adaptive": "adaptive"}


def _replay_batch_blocked(sizes, times, kinds, items, pdeps, dmask,
                          arrivals, rdeps, n_items, *, policy: str,
                          max_bins: int, backend: str, block_events: int,
                          carry0=None, return_carry: bool = False,
                          ev_extra=None, migrate: bool = False):
    """Event-blocked replay: a short ``lax.scan`` over blocks of ``T``
    events, each block processed entirely on-chip by
    ``kernels.fitscore.fitscore_replay_block`` with the packed carry
    resident in VMEM - the carry round-trips through HBM once per block
    instead of once per event.  Decision-for-decision identical to the
    per-event paths (tests/test_replay_block.py)."""
    from .algorithms.learned import LA_BINARY_SPLIT
    spec = policy_spec(policy)
    fam = _KERNEL_FAMILY[spec.family]
    L, n_max, d = sizes.shape
    f32, i32 = jnp.float32, jnp.int32
    T = int(block_events)
    Np, dpad, _, _ = select_pad_geometry(max_bins, d)

    # pad once, exactly as the per-event kernel path does
    sizes_p = jnp.asarray(sizes, f32) if dpad == d else \
        jnp.zeros((L, n_max, dpad), f32).at[:, :, :d].set(sizes)
    dm = jnp.ones((L, d), f32) if dmask is None else jnp.asarray(dmask, f32)
    dmask_p = dm if dpad == d else \
        jnp.zeros((L, dpad), f32).at[:, :d].set(dm)

    consts, _cat0, xs_extra = _category_setup(
        spec, sizes, pdeps, dmask, arrivals, rdeps, n_items, times, kinds,
        items, Np)
    if ev_extra is not None:
        # precomputed full-event-axis extras (segmented replay: RCP's
        # running distinct-category cumsum must span segments)
        xs_extra = tuple(jnp.asarray(x) for x in ev_extra)

    # per-event operand streams: pure functions of the (predicted)
    # durations, gathered by event item index and padded to a T multiple
    # with PAD_KIND no-ops (the tail block)
    items_i = jnp.asarray(items, i32)
    E = times.shape[1]
    NB = -(-E // T)
    pad = NB * T - E

    def padded(a, fill):
        if pad == 0:
            return a
        return jnp.concatenate(
            [a, jnp.full((L, pad) + a.shape[2:], fill, a.dtype)], axis=1)

    def g_ev(a):
        return jnp.take_along_axis(jnp.asarray(a), items_i, axis=1)

    ev_i = {"kind": padded(jnp.asarray(kinds, i32), PAD_KIND),
            "item": padded(items_i, 0)}
    ev_f = {"t": padded(jnp.asarray(times, f32), 0.0),
            "pdep": padded(g_ev(pdeps).astype(f32), 0.0)}
    ev_size = padded(jnp.take_along_axis(sizes_p, items_i[:, :, None],
                                         axis=1), 0.0)
    if fam == "cbd":
        ev_i["cat"] = padded(g_ev(consts["cat"]).astype(i32), 0)
    elif fam == "hybrid":
        ev_i["key"] = padded(g_ev(consts["key"]).astype(i32), 0)
        ev_i["cls"] = padded(g_ev(consts["cls"]).astype(i32), 0)
        ev_f["thr"] = padded(g_ev(consts["thr"]).astype(f32), 0.0)
    elif fam == "rcp":
        ev_i["cat"] = padded(g_ev(consts["cat"]).astype(i32), 0)
        ev_i["large"] = padded(g_ev(consts["large"]).astype(i32), 0)
        ev_i["x"] = padded(xs_extra[0].astype(i32), 0)
        ev_f["p2err"] = padded(g_ev(consts["p2err"]).astype(f32), 0.0)
    elif fam == "la":
        ev_i["cat"] = padded(g_ev(consts["cat"]).astype(i32), 0)
    elif fam == "adaptive":
        ev_f["errmax"] = padded(g_ev(consts["errmax"]).astype(f32), 0.0)

    xs_streams = (ev_i, ev_f, ev_size)

    if carry0 is not None:
        # resume a segmented replay: the packed carry IS the replay state
        carry = jax.tree.map(jnp.asarray, carry0)
    else:
        carry = packed_init_carry(fam, L, n_max, max_bins, d)

    carry = _fk.fitscore_replay_chunk(
        carry, *xs_streams, dmask_p, block_events=T, family=fam,
        policy=policy if fam == "score" else "first_fit",
        n=max_bins, d=d, large_bins=spec.large_bins,
        adaptive_alpha=spec.adaptive_alpha,
        direct_sum=spec.direct_sum, la_mode=spec.la_mode,
        la_split=LA_BINARY_SPLIT, low=spec.low, high=spec.high,
        migrate=migrate, interpret=(backend == "pallas_interpret"))
    out = (carry["sf"][:, _fk.SF_USAGE],
           carry["si"][:, _fk.SI_OPENED],
           carry["itemi"][:, :, _fk.ITEMI_PLACE],
           carry["si"][:, _fk.SI_OVERFLOW] > 0)
    # usage/opened/placements live in carry columns (cumulative), so the
    # final segment of a checkpointed replay returns full-run totals
    return out + (carry,) if return_carry else out


def packed_init_carry(fam: str, L: int, item_rows: int, max_bins: int,
                      d: int):
    """A fresh packed (VMEM-layout) replay carry for the event-blocked
    megakernel path: slot closes at ``SCORE_NEG`` (virgin), tags
    ``TAG_VIRGIN``, placements -1, PPE alpha / adaptive err at 1.0, RCP
    base slot -1.  ``item_rows`` is the ``itemi`` (and hybrid ``hagg``)
    row count - ``n_max`` in-memory, the recycled pool size when streamed."""
    f32, i32 = jnp.float32, jnp.int32
    Np, dpad, _, _ = select_pad_geometry(max_bins, d)
    carry = {
        "loads": jnp.zeros((L, Np, dpad), f32),
        "slotf": jnp.zeros((L, Np, _fk.SLOTF_COLS), f32)
        .at[:, :, _fk.SLOTF_CLOSES].set(NEG),
        "sloti": jnp.zeros((L, Np, _fk.SLOTI_COLS), i32)
        .at[:, :, _fk.SLOTI_TAG].set(TAG_VIRGIN),
        "itemi": jnp.zeros((L, item_rows, _fk.ITEMI_COLS), i32)
        .at[:, :, _fk.ITEMI_PLACE].set(-1),
        "sf": jnp.zeros((L, _fk.SF_COLS), f32)
        .at[:, _fk.SF_ALPHA].set(1.0).at[:, _fk.SF_ERR].set(1.0),
        "si": jnp.zeros((L, _fk.SI_COLS), i32)
        .at[:, _fk.SI_BASE].set(-1),
    }
    if fam == "hybrid":
        carry["hagg"] = jnp.zeros((L, item_rows, dpad), f32)
    elif fam == "rcp":
        carry["ragg"] = jnp.zeros((L, _fk.RAGG_ROWS, dpad), f32)
        carry["ron"] = jnp.zeros((L, KCAT, _fk.RON_COLS), i32)
    return carry


def replay_init_carry(policy: str, max_bins: int, d: int, item_rows: int,
                      *, L: int = 1, backend: str = "jnp",
                      block_events: int = 0):
    """The fresh carry ``_replay_batch`` starts from, in the layout the
    (backend, block_events) config threads across chunk boundaries - what
    a streamed replay (``repro.stream``) initializes once and then passes
    back in as ``carry0`` chunk after chunk."""
    spec = policy_spec(policy)
    if backend != "jnp" and block_events and block_events > 1:
        return packed_init_carry(_KERNEL_FAMILY[spec.family], L, item_rows,
                                 max_bins, d)
    if backend != "jnp":
        Np, dpad, _, _ = select_pad_geometry(max_bins, d)
    else:
        Np, dpad = max_bins, d
    return (_core_state0(L, Np, dpad, item_rows),
            _category_state0(spec, L, item_rows, d, Np))


def make_live_carry(policy: str, max_bins: int, d: int,
                    max_items: int = 256):
    """A fresh single-lane packed replay carry for an *open-ended* event
    stream - the serving front end's live fleet state.

    Same layout and init as ``_replay_batch_blocked``'s carry (L=1,
    ``select_pad_geometry(max_bins, d)`` slot padding, ``max_items`` item
    rows): slot closes at ``SCORE_NEG`` (virgin), tags ``TAG_VIRGIN``,
    placements -1, PPE alpha / adaptive err at 1.0, RCP base slot -1 -
    so ``kernels.ops.fitscore_replay_dispatch`` can replay event blocks
    against it exactly as the sweep scan does, carry aliased in -> out.
    The hybrid family is clairvoyant-only (its key table is built from the
    whole instance up front) and has no live-carry form."""
    spec = policy_spec(policy)
    fam = _KERNEL_FAMILY[spec.family]
    assert fam != "hybrid", \
        f"{policy!r} is clairvoyant-only (whole-instance key table); " \
        "no live serving carry"
    return packed_init_carry(fam, 1, max_items, max_bins, d)


def grow_live_carry(carry, max_bins: int, d: int):
    """Pad a live carry's slot axis to the geometry of a larger pool (the
    serving overflow-regrow rung).  New rows are virgin - zero loads,
    ``SCORE_NEG`` closes, ``TAG_VIRGIN`` tags, zero counts - so replaying
    any overflow-free event stream on the grown carry makes the same
    decisions (extra free rows are only reached when the old pool would
    have overflowed)."""
    Np2, _, _, _ = select_pad_geometry(max_bins, d)
    Np = carry["loads"].shape[1]
    if Np2 <= Np:
        return carry
    pad = Np2 - Np

    def wide(a, fill, col=None):
        tail = jnp.zeros((1, pad) + a.shape[2:], a.dtype)
        if col is not None:
            tail = tail.at[:, :, col].set(fill)
        return jnp.concatenate([a, tail], axis=1)

    out = dict(carry)
    out["loads"] = wide(carry["loads"], 0.0)
    out["slotf"] = wide(carry["slotf"], NEG, _fk.SLOTF_CLOSES)
    out["sloti"] = wide(carry["sloti"], TAG_VIRGIN, _fk.SLOTI_TAG)
    return out


def grow_live_items(carry, max_items: int):
    """Pad a live carry's item axis (placements -1); the serving item-row
    free list doubles through this when the fleet's in-flight population
    outgrows the initial allocation."""
    n = carry["itemi"].shape[1]
    if max_items <= n:
        return carry
    out = dict(carry)
    out["itemi"] = jnp.concatenate(
        [carry["itemi"],
         jnp.zeros((1, max_items - n, _fk.ITEMI_COLS), jnp.int32)
         .at[:, :, _fk.ITEMI_PLACE].set(-1)], axis=1)
    return out


def _replay_batch(sizes, times, kinds, items, pdeps, dmask, arrivals=None,
                  rdeps=None, n_items=None, *, policy: str, max_bins: int,
                  backend: str = "jnp", block_events: int = 0,
                  trace_level: int = 0, carry0=None,
                  return_carry: bool = False, ev_extra=None,
                  migrate: bool = False):
    """``L`` lanes' event replays in lockstep: one scan over the event
    *index* whose step processes every lane at once, so the arrival scoring
    is a single (L, slots, d) op - on TPU the fused
    ``kernels.fitscore.fitscore_select_batch_padded`` Pallas kernel, with
    zero host round-trips per step.

    Every array carries a leading lane axis: sizes (L, n_max, d); times /
    kinds / items (L, 2 n_max); pdeps (L, n_max) *predicted* departures;
    ``dmask`` (L, d) real-dimension mask or None.  Category policies
    additionally need ``arrivals`` / ``rdeps`` (real departures) (L, n_max)
    and ``n_items`` (L,) to derive per-item categories, thresholds and
    departure errors (see ``_category_setup``).

    Returns (usage (L,), opened (L,), placements (L, n_max), overflow (L,)).
    With ``trace_level >= 1`` a fifth element is appended: a dict of
    stacked per-event series (each ``(L, 2 n_max, ...)``) - the chosen /
    freed slot, post-event open-bin count, per-dim aggregate load,
    category tag of the touched slot and running usage (``trace_level >= 2``
    adds the full per-slot alive mask).  ``trace_level=0`` is literally
    the pre-trace code path (``ys=None``): bit-identical outputs.

    ``backend="jnp"`` selects with the inline vmapped ``_select_slot`` on a
    compact (max_bins, d) carry; "pallas"/"pallas_interpret" run the kernel
    natively / in interpret mode with the carry held permanently in the
    padded (Np, dpad) kernel layout (padded once here, not per step).

    Segmented (checkpointed) replay threads the scan carry through:
    ``carry0`` resumes from a prior segment's carry, ``return_carry``
    appends the final carry to the outputs, and ``ev_extra`` overrides the
    per-event extra streams (which must be precomputed on the *full* event
    axis - RCP's distinct-category cumsum cannot restart per segment).
    See ``resilience.checkpoint.checkpointed_replay``.

    ``migrate=True`` additionally compiles the MIGRATE event branch
    (consolidation: a full departure application with the learning updates
    skipped, then the arrival machinery on the post-departure state with
    the item's source slot excluded from the select).  ``migrate=False``
    builds the exact pre-MIGRATE graph, so non-consolidating replays pay
    nothing.  See ``repro.consolidate``.
    """
    assert not (return_carry and trace_level), \
        "checkpointed replay does not stack decision traces"
    kernel_layout = backend != "jnp"
    if kernel_layout and block_events and block_events > 1 and \
            not trace_level:
        # event-blocked megakernel: whole T-event blocks on-chip, carry
        # written back to HBM once per block (kernel backends only; the
        # per-event jnp scan below stays the bit-exact reference)
        return _replay_batch_blocked(
            sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps,
            n_items, policy=policy, max_bins=max_bins, backend=backend,
            block_events=block_events, carry0=carry0,
            return_carry=return_carry, ev_extra=ev_extra, migrate=migrate)
    spec = policy_spec(policy)
    L, n_max, d = sizes.shape
    f32, i32 = jnp.float32, jnp.int32
    if kernel_layout:
        Np, dpad, _, _ = select_pad_geometry(max_bins, d)
    else:
        Np, dpad = max_bins, d
    lanes = jnp.arange(L)

    # pad once: item sizes and the dim mask live in the select's dpad
    # layout for the whole scan
    sizes_p = jnp.asarray(sizes, f32) if dpad == d else \
        jnp.zeros((L, n_max, dpad), f32).at[:, :, :d].set(sizes)
    dm = jnp.ones((L, d), f32) if dmask is None else jnp.asarray(dmask, f32)
    dmask_p = dm if dpad == d else \
        jnp.zeros((L, dpad), f32).at[:, :d].set(dm)

    consts, cat0, xs_extra = _category_setup(
        spec, sizes, pdeps, dmask, arrivals, rdeps, n_items, times, kinds,
        items, Np)
    if ev_extra is not None:
        # precomputed full-event-axis extras (segmented replay)
        xs_extra = tuple(jnp.asarray(x) for x in ev_extra)

    def do_select(base, loads, counts, alive, open_seq, access_seq, closes,
                  size, pdep_j, t, cmask=None):
        if not kernel_layout:
            return jax.vmap(partial(_select_slot, base))(
                loads, counts, alive, open_seq, access_seq, closes, size,
                pdep_j, t, dmask_p, cmask)
        return fitscore_select_batch_padded(
            loads, counts, alive, open_seq, access_seq, closes, size,
            pdep_j, t, dmask_p, cmask, policy=base, n=max_bins,
            interpret=(backend == "pallas_interpret"))

    def pick(cond, a_val, d_val):
        return jax.tree.map(
            lambda x, y: jnp.where(
                cond.reshape(cond.shape + (1,) * (x.ndim - 1)), x, y),
            a_val, d_val)

    def step(carry, ev):
        core, cat = carry
        (loads, counts, alive, open_seq, access_seq, closes, open_time,
         placements, usage, seq, opened, overflow) = core
        t, kind = ev[0], ev[1]
        j = ev[2].astype(i32)
        g = lambda a: jnp.take_along_axis(a, j[:, None], axis=1)[:, 0]
        size = jnp.take_along_axis(sizes_p, j[:, None, None], axis=1)[:, 0]
        size_d = size[:, :d]
        pdep_j = g(pdeps)
        is_arr = kind == ARRIVAL_KIND
        is_pad = kind == PAD_KIND

        # ---- departure branch: shared bin bookkeeping
        b_dep = g(placements)
        loads_dep = loads.at[lanes, b_dep].add(-size)
        counts_dep = counts.at[lanes, b_dep].add(-1)
        closing = counts_dep[lanes, b_dep] == 0
        usage_dep = usage + jnp.where(closing, t - open_time[lanes, b_dep],
                                      0.0)
        alive_dep = alive.at[lanes, b_dep].set(
            jnp.where(closing, False, alive[lanes, b_dep]))
        loads_dep = loads_dep.at[lanes, b_dep].set(
            jnp.where(closing[:, None], jnp.zeros((L, dpad)),
                      loads_dep[lanes, b_dep]))
        closes_dep = closes.at[lanes, b_dep].set(
            jnp.where(closing, NEG, closes[lanes, b_dep]))

        # ---- departure-side category deltas
        cat_dep = dict(cat)   # category state if this event is a departure

        if spec.family == "hybrid":
            keyj = g(consts["key"])
            wasg = g(cat["ingen"])
            cat_dep["agg"] = cat["agg"].at[lanes, keyj].set(
                jnp.maximum(cat["agg"][lanes, keyj] -
                            jnp.where(wasg[:, None], size_d, 0.0), 0.0))

        elif spec.family == "rcp":
            # per-location aggregate decrements, category turn-OFF below
            # 1/2, alpha guess-and-double, base-close reset
            catj = g(consts["cat"])
            locd = g(cat["loc"])
            sz_g = jnp.where((locd == LOC_G)[:, None], size_d, 0.0)
            sz_b = jnp.where((locd == LOC_B)[:, None], size_d, 0.0)
            sz_c = jnp.where((locd == LOC_C)[:, None], size_d, 0.0)
            agg_gen_d = cat["agg_gen"].at[lanes, catj].set(
                jnp.maximum(cat["agg_gen"][lanes, catj] - sz_g, 0.0))
            new_cat = jnp.maximum(cat["agg_cat"][lanes, catj] - sz_c, 0.0)
            agg_cat_d = cat["agg_cat"].at[lanes, catj].set(new_cat)
            turn_off = (locd == LOC_C) & cat["on"][lanes, catj] & \
                (jnp.max(new_cat, axis=1) < 0.5)
            base_closed = closing & (cat["base"] >= 0) & \
                (b_dep == cat["base"])
            cat_dep.update(
                agg_gen=agg_gen_d, agg_cat=agg_cat_d,
                on=cat["on"].at[lanes, catj].set(
                    cat["on"][lanes, catj] & ~turn_off),
                agg_base=jnp.where(
                    base_closed[:, None], 0.0,
                    jnp.maximum(cat["agg_base"] - sz_b, 0.0)),
                agg_bcat=jnp.where(
                    base_closed[:, None, None], 0.0,
                    cat["agg_bcat"].at[lanes, catj].set(
                        jnp.maximum(cat["agg_bcat"][lanes, catj] - sz_b,
                                    0.0))),
                base=jnp.where(base_closed, -1, cat["base"]),
                alpha=jnp.maximum(cat["alpha"], g(consts["p2err"]))
                if spec.adaptive_alpha else cat["alpha"])

        elif spec.family == "adaptive":
            cat_dep["err"] = jnp.maximum(cat["err"], g(consts["errmax"]))

        # ---- the placement decision + arrival-side category deltas.
        # ``arrive`` reads only its state arguments, so the same machinery
        # serves plain arrivals (pre-event state) and - under
        # ``migrate=True`` - MIGRATE re-places (post-departure state with
        # the source slot excluded from the select).
        def arrive(core_s, cat_s, excl=None):
            (loads_s, counts_s, alive_s, open_seq_s, access_seq_s,
             closes_s, open_time_s, placements_s, usage_s, seq_s,
             opened_s, overflow_s) = core_s
            if excl is None:
                fold = lambda cm: cm
            else:
                em = jnp.arange(Np)[None, :] != excl[:, None]
                fold = lambda cm: em if cm is None else cm & em
            sel = lambda base, cmask=None: do_select(
                base, loads_s, counts_s, alive_s, open_seq_s, access_seq_s,
                closes_s, size, pdep_j, t, fold(cmask))
            cat_a = dict(cat_s)   # category state after this placement

            if spec.family == "score":
                b, found, no_free = sel(policy)

            elif spec.family in ("cbd", "cbdt"):
                # First Fit within the item's duration/departure class
                catj = g(consts["cat"])
                b, found, no_free = sel("first_fit",
                                        cat_s["tag"] == catj[:, None])
                cat_a["tag"] = cat_s["tag"].at[lanes, b].set(
                    jnp.where(found, cat_s["tag"][lanes, b], catj))

            elif spec.family == "hybrid":
                keyj, thrj, clsj = g(consts["key"]), g(consts["thr"]), \
                    g(consts["cls"])
                after = cat_s["agg"][lanes, keyj] + size_d
                norm = jnp.take_along_axis(
                    after, clsj[:, None], axis=1)[:, 0] \
                    if spec.direct_sum else jnp.max(after, axis=1)
                is_gen = norm <= thrj + F32_EPS
                wanted = jnp.where(is_gen, clsj, d + keyj)
                b, found, no_free = sel("first_fit",
                                        cat_s["tag"] == wanted[:, None])
                cat_a["tag"] = cat_s["tag"].at[lanes, b].set(
                    jnp.where(found, cat_s["tag"][lanes, b], wanted))
                cat_a["agg"] = cat_s["agg"].at[lanes, keyj].add(
                    jnp.where(is_gen[:, None], size_d, 0.0))
                cat_a["ingen"] = cat_s["ingen"].at[lanes, j].set(is_gen)

            elif spec.family == "rcp":
                catj, largej = g(consts["cat"]), g(consts["large"])
                x = jnp.maximum(ev[3], 1).astype(f32)  # distinct cats so far
                coef = cat_s["alpha"] if spec.adaptive_alpha else 1.0
                thr = coef / jnp.sqrt(x)
                fits_gen = jnp.max(cat_s["agg_gen"][lanes, catj] + size_d,
                                   axis=1) <= thr + F32_EPS
                has_base = cat_s["base"] >= 0
                base_loads = loads_s[lanes, jnp.maximum(cat_s["base"], 0)]
                base_fits = jnp.where(
                    has_base,
                    jnp.all(size <= 1.0 - base_loads + F32_EPS, axis=1),
                    True)
                if excl is not None:
                    # migrate off the base bin itself: the re-place must
                    # not target its own source (the oracle's source bin is
                    # infeasible during the select)
                    base_fits = base_fits & (cat_s["base"] != excl)
                is_on = cat_s["on"][lanes, catj]
                d_large = largej if spec.large_bins else jnp.zeros(L, bool)
                d_gen = ~d_large & fits_gen
                d_cat = ~d_large & ~fits_gen & is_on
                d_base = ~d_large & ~fits_gen & ~is_on & base_fits
                d_catf = ~d_large & ~fits_gen & ~is_on & ~base_fits  # "C!"
                wanted = jnp.where(
                    d_gen, TAG_GENERAL,
                    jnp.where(d_cat, catj,
                              jnp.where(d_base & has_base, TAG_BASE,
                                        TAG_NONE)))
                b, found, no_free = sel("first_fit",
                                        cat_s["tag"] == wanted[:, None])
                open_tag = jnp.where(
                    d_large, TAG_LARGE,
                    jnp.where(d_gen, TAG_GENERAL,
                              jnp.where(d_base, TAG_BASE, catj)))
                tag_a = cat_s["tag"].at[lanes, b].set(
                    jnp.where(found, cat_s["tag"][lanes, b], open_tag))
                new_base = d_base & ~has_base
                base_a = jnp.where(new_base, b, cat_s["base"])
                agg_base_a = jnp.where(new_base[:, None], 0.0,
                                       cat_s["agg_base"]) + \
                    jnp.where(d_base[:, None], size_d, 0.0)
                agg_bcat_a = jnp.where(new_base[:, None, None], 0.0,
                                       cat_s["agg_bcat"]) \
                    .at[lanes, catj].add(
                        jnp.where(d_base[:, None], size_d, 0.0))
                agg_gen_a = cat_s["agg_gen"].at[lanes, catj].add(
                    jnp.where(d_gen[:, None], size_d, 0.0))
                agg_cat_a = cat_s["agg_cat"].at[lanes, catj].add(
                    jnp.where((d_cat | d_catf)[:, None], size_d, 0.0))
                on_a = cat_s["on"].at[lanes, catj].set(
                    cat_s["on"][lanes, catj] | d_catf)
                loc_a = cat_s["loc"].at[lanes, j].set(
                    jnp.where(d_gen, LOC_G,
                              jnp.where(d_base, LOC_B,
                                        jnp.where(d_large, LOC_L, LOC_C))))
                # base conversion (paper §VI-A): base exceeded 1/2 ->
                # becomes a category bin of its dominant member category,
                # which turns ON
                conv = d_base & (jnp.max(agg_base_a, axis=1) > 0.5)
                dom = jnp.argmax(jnp.max(agg_bcat_a, axis=2), axis=1) \
                    .astype(i32)
                tag_a = tag_a.at[lanes, b].set(
                    jnp.where(conv, dom, tag_a[lanes, b]))
                on_a = on_a.at[lanes, dom].set(on_a[lanes, dom] | conv)
                agg_cat_a = jnp.where(conv[:, None, None],
                                      agg_cat_a + agg_bcat_a, agg_cat_a)
                loc_a = jnp.where(conv[:, None] & (loc_a == LOC_B), LOC_C,
                                  loc_a)
                cat_a.update(
                    tag=tag_a, on=on_a, loc=loc_a, agg_gen=agg_gen_a,
                    agg_cat=agg_cat_a,
                    agg_base=jnp.where(conv[:, None], 0.0, agg_base_a),
                    agg_bcat=jnp.where(conv[:, None, None], 0.0,
                                       agg_bcat_a),
                    base=jnp.where(conv, -1, base_a))

            elif spec.family == "la":
                # Best Fit (l_inf) within the item's lifetime class; bins
                # are classed by predicted remaining usage (carried
                # ``closes`` clamped to now); class-0 items fill leftover
                # capacity anywhere, others fall back to foreign-class bins
                icat = g(consts["cat"])
                remt = jnp.maximum(closes_s, t[:, None]) - t[:, None]
                bincat = la_class_jnp(remt, spec.la_mode)
                same = bincat == icat[:, None]
                short = (icat == 0)[:, None]
                ra = sel("best_fit_linf", jnp.where(short, True, same))
                rb = sel("best_fit_linf", jnp.where(short, False, ~same))
                found = ra[1] | rb[1]
                b = jnp.where(ra[1], ra[0], rb[0]).astype(i32)
                no_free = ra[2]

            else:   # adaptive: regime-switch between three Any Fit
                # policies on the carried running departure error
                err = cat_s["err"]
                k = jnp.where(err < spec.low, 0,
                              jnp.where(err < spec.high, 1, 2))
                r0, r1, r2 = sel("nrt_prioritized"), sel("greedy"), \
                    sel("first_fit")
                b = jnp.where(k == 0, r0[0],
                              jnp.where(k == 1, r1[0], r2[0])).astype(i32)
                found = jnp.where(k == 0, r0[1],
                                  jnp.where(k == 1, r1[1], r2[1]))
                no_free = r0[2]

            # ---- arrival branch: shared bin bookkeeping
            b = b.astype(i32)
            overflow_arr = overflow_s | (~found & no_free)
            loads_arr = loads_s.at[lanes, b].add(size)
            counts_arr = counts_s.at[lanes, b].add(1)
            alive_arr = alive_s.at[lanes, b].set(True)
            open_seq_arr = open_seq_s.at[lanes, b].set(
                jnp.where(found, open_seq_s[lanes, b], seq_s))
            open_time_arr = open_time_s.at[lanes, b].set(
                jnp.where(found, open_time_s[lanes, b], t))
            access_arr = access_seq_s.at[lanes, b].set(seq_s)
            closes_arr = closes_s.at[lanes, b].set(
                jnp.maximum(jnp.where(found, closes_s[lanes, b], NEG),
                            jnp.maximum(pdep_j, t)))
            placements_arr = placements_s.at[lanes, j].set(b)
            opened_arr = opened_s + jnp.where(found, 0, 1)
            return ((loads_arr, counts_arr, alive_arr, open_seq_arr,
                     access_arr, closes_arr, open_time_arr, placements_arr,
                     usage_s, seq_s + 1, opened_arr, overflow_arr),
                    cat_a, b)

        core_dep = (loads_dep, counts_dep, alive_dep, open_seq, access_seq,
                    closes_dep, open_time, placements, usage_dep, seq,
                    opened, overflow)
        core_arr, cat_arr, b_sel = arrive(core, cat)

        new = pick(is_arr, (core_arr, cat_arr), (core_dep, cat_dep))
        if migrate:
            # MIGRATE = full departure application (learning updates
            # restored: a migration is not a departure observation) then
            # the arrival machinery on the post-departure state, source
            # slot excluded from the select
            is_mig = kind == MIGRATE_KIND
            cat_migdep = dict(cat_dep)
            if spec.family == "rcp" and spec.adaptive_alpha:
                cat_migdep["alpha"] = cat["alpha"]
            elif spec.family == "adaptive":
                cat_migdep["err"] = cat["err"]
            core_mig, cat_mig, _ = arrive(core_dep, cat_migdep, b_dep)
            new = pick(is_mig, (core_mig, cat_mig), new)
        # padded events are no-ops: the carry passes through untouched
        carry = pick(is_pad, carry, new)
        if not trace_level:
            return carry, None
        # trace emission: the post-event state, as stacked scan outputs
        # (device-side tensors - the host collector never runs in here)
        core_n, cat_n = carry
        ev_slot = jnp.where(is_pad, -1,
                            jnp.where(is_arr, b_sel, b_dep)).astype(i32)
        tag_n = cat_n["tag"][lanes, jnp.maximum(ev_slot, 0)] \
            if "tag" in cat_n else jnp.full((L,), -1, i32)
        ys = {"slot": ev_slot,
              "open_bins": core_n[2].sum(axis=1).astype(i32),
              "load": core_n[0].sum(axis=1)[:, :d].astype(jnp.float32),
              "tag": jnp.where(ev_slot >= 0, tag_n, -1).astype(i32),
              "usage": core_n[8].astype(jnp.float32)}
        if trace_level >= 2:
            ys["alive"] = core_n[2]
        return carry, ys

    core0 = _core_state0(L, Np, dpad, n_max)
    xs = tuple(jnp.swapaxes(a, 0, 1)
               for a in (times, kinds, items) + xs_extra)
    init = (core0, cat0) if carry0 is None else \
        jax.tree.map(jnp.asarray, carry0)
    (core, _cat), ys = jax.lax.scan(step, init, xs)
    out = (core[8], core[10], core[7], core[11])
    if return_carry:
        # usage/opened/placements are cumulative carry columns, so the
        # final segment of a checkpointed replay returns full-run totals
        return out + ((core, _cat),)
    if trace_level:
        # scan stacks along the leading (event) axis; traces are (L, E, .)
        return out + ({k: jnp.swapaxes(v, 0, 1) for k, v in ys.items()},)
    return out


@partial(jax.jit, static_argnames=("policy", "max_bins", "backend",
                                   "block_events"))
def _simulate_one(sizes, times, kinds, items, pdeps, arrivals, rdeps, *,
                  policy: str, max_bins: int, backend: str,
                  block_events: int = 0):
    n1 = jnp.full((1,), sizes.shape[0], jnp.int32)
    u, o, p, ov = _replay_batch(sizes[None], times[None], kinds[None],
                                items[None], pdeps[None], None,
                                arrivals[None], rdeps[None], n1,
                                policy=policy, max_bins=max_bins,
                                backend=backend, block_events=block_events)
    return u[0], o[0], p[0], ov[0]


def event_sequence(inst: Instance):
    """(times, kinds, items) int32/float arrays, departures sorted before
    arrivals at equal times (half-open [arrival, departure) intervals).
    Shared by simulate() and the repro.sweep batching layer."""
    n = inst.n_items
    times = np.concatenate([inst.arrivals, inst.departures])
    kinds = np.concatenate([np.full(n, ARRIVAL_KIND, np.int32),
                            np.full(n, DEPARTURE_KIND, np.int32)])
    items = np.concatenate([np.arange(n), np.arange(n)]).astype(np.int32)
    order = np.lexsort((np.arange(2 * n), kinds, times))
    return times[order], kinds[order], items[order]


def simulate(inst: Instance, policy: str = "first_fit",
             predicted_durations: Optional[np.ndarray] = None,
             max_bins: int = 256, auto_grow: bool = True,
             max_bins_cap: int = MAX_BINS_CAP,
             backend: Optional[str] = None,
             block_events: int = 0) -> JaxSimResult:
    """Replay one instance (any ``SCAN_POLICIES`` policy).  If the slot pool
    overflows and ``auto_grow`` is set, retries with a doubled ``max_bins``
    (up to ``max_bins_cap``) instead of returning garbage - the same
    escalation ladder the batched sweep runner applies per lane.
    ``backend`` picks the scoring engine (see ``BACKENDS``); the default
    "auto" resolves to the Pallas kernel on TPU and the inline jnp scan step
    elsewhere.  ``block_events`` > 1 (kernel backends only) replays whole
    blocks of that many events per megakernel invocation - execution
    detail, never affects results."""
    assert known_policy(policy), \
        f"{policy!r} is not a scan policy; known: {SCAN_POLICIES}"
    backend = resolve_backend(backend)
    pdeps = inst.departures if predicted_durations is None \
        else inst.arrivals + predicted_durations
    times, kinds, items = event_sequence(inst)
    args = tuple(jnp.asarray(a) for a in
                 (inst.sizes, times, kinds, items, pdeps, inst.arrivals,
                  inst.departures))
    while True:
        usage, opened, placements, overflow = _simulate_one(
            *args, policy=policy, max_bins=max_bins, backend=backend,
            block_events=block_events)
        if not bool(overflow) or not auto_grow:
            break
        if max_bins >= max_bins_cap:
            # escalation exhausted: fail structured, not with a silently
            # garbage result (auto_grow=False keeps the flag contract)
            raise CapacityError(
                f"slot pool exhausted replaying {inst.name!r} with "
                f"{policy!r}: still overflowing at max_bins={max_bins} "
                f"(cap {max_bins_cap}; raise REPRO_MAX_BINS_CAP or pass "
                f"a larger max_bins_cap)",
                policy=policy, max_bins=max_bins, instance=inst.name)
        obs.counter_add("sweep.overflow_rungs")
        max_bins = grow_max_bins(max_bins, max_bins_cap)
    return JaxSimResult(float(usage), int(opened),
                        np.asarray(placements), bool(overflow), max_bins)
