"""MinUsageTime Dynamic Vector Bin Packing - the paper's core contribution.

Public API:
    Instance, Arrival, PackingResult       (types)
    run(instance, algorithm, ...)          (exact event-driven engine)
    lower_bound(instance), span(instance)  (Eq. 1 optimum lower bound)
    get_algorithm(name, **params)          (algorithm zoo registry)
    lognormal_predictions / uniform_predictions (error models, §VI)
"""
from .types import EPS, Arrival, Instance, PackingResult  # noqa: F401
from .engine import run  # noqa: F401
from .lower_bound import lower_bound, span  # noqa: F401
from .metrics import BoxStats, summarize  # noqa: F401
from .predictions import (lognormal_predictions,  # noqa: F401
                          lognormal_predictions_batch, uniform_predictions,
                          uniform_predictions_batch)
from .algorithms import (ALL_ALGORITHMS, ANY_FIT, CLAIRVOYANT,  # noqa: F401
                         LEARNING_AUGMENTED, NON_CLAIRVOYANT, REGISTRY,
                         Algorithm, get_algorithm)
