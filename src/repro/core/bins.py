"""Open-bin bookkeeping shared by the engine and all packing algorithms.

The pool is a struct-of-arrays over *absolute* bin indices (monotonically
assigned; a closed bin index is never reused, matching the paper's semantics
where the usage time of a bin is one contiguous episode).  Algorithms operate
on the set of currently-open bins through vectorized views.

All capacity checks use ``types.EPS`` so exact fits are accepted.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .types import EPS


class BinPool:
    """Vectorized state for every bin ever opened during one engine run."""

    def __init__(self, d: int, init_cap: int = 64):
        self.d = d
        self._cap = init_cap
        self.used = np.zeros((init_cap, d))          # current load per dim
        self.n_active = np.zeros(init_cap, np.int64)  # active items in bin
        self.open_time = np.full(init_cap, np.nan)
        self.open_seq = np.full(init_cap, -1, np.int64)   # FF ordering key
        self.access_seq = np.full(init_cap, -1, np.int64)  # MRU ordering key
        self.indicated_close = np.full(init_cap, -np.inf)  # max predicted dep
        self.alive = np.zeros(init_cap, bool)
        self.tag = np.full(init_cap, -1, np.int64)   # algorithm-owned label
        self.n_bins = 0          # total ever opened
        self._seq = 0            # placement sequence counter
        self._open_list: List[int] = []   # open bins in opening order

    # ------------------------------------------------------------------ admin
    def _grow(self):
        new_cap = self._cap * 2
        for name in ("used", "n_active", "open_time", "open_seq", "access_seq",
                     "indicated_close", "alive", "tag"):
            arr = getattr(self, name)
            new = np.zeros((new_cap,) + arr.shape[1:], arr.dtype)
            if name == "open_time":
                new[:] = np.nan
            elif name == "indicated_close":
                new[:] = -np.inf
            elif name in ("open_seq", "access_seq", "tag"):
                new[:] = -1
            new[: self._cap] = arr
            setattr(self, name, new)
        self._cap = new_cap

    def open_bin(self, now: float, tag: int = -1) -> int:
        if self.n_bins == self._cap:
            self._grow()
        idx = self.n_bins
        self.n_bins += 1
        self.used[idx] = 0.0
        self.n_active[idx] = 0
        self.open_time[idx] = now
        self.open_seq[idx] = self._seq
        self.alive[idx] = True
        self.tag[idx] = tag
        self._open_list.append(idx)
        return idx

    def close_bin(self, idx: int):
        assert self.alive[idx] and self.n_active[idx] == 0
        self.alive[idx] = False
        self._open_list.remove(idx)

    # ------------------------------------------------------------ item events
    def place(self, idx: int, size: np.ndarray, pdep: float, now: float):
        self.used[idx] += size
        assert np.all(self.used[idx] <= 1 + EPS), (
            f"capacity violated in bin {idx}: {self.used[idx]}")
        self.n_active[idx] += 1
        self.access_seq[idx] = self._seq
        self._seq += 1
        if pdep is not None:
            # Paper §VI adaptation: a bin's indicated closing time is never in
            # the past; underestimated items are predicted to depart "now".
            self.indicated_close[idx] = max(self.indicated_close[idx], pdep, now)

    def remove(self, idx: int, size: np.ndarray):
        self.used[idx] -= size
        self.n_active[idx] -= 1
        assert self.n_active[idx] >= 0
        if self.n_active[idx] == 0:
            self.used[idx] = 0.0   # kill float residue for exact reuse checks

    # ------------------------------------------------------------------ views
    def open_indices(self) -> np.ndarray:
        """Open bins in opening order (stable; the First Fit order)."""
        return np.asarray(self._open_list, np.int64)

    def fits_mask(self, open_idx: np.ndarray, size: np.ndarray) -> np.ndarray:
        """Feasibility of ``size`` in each of ``open_idx`` (all dims)."""
        if len(open_idx) == 0:
            return np.zeros(0, bool)
        rem = 1.0 - self.used[open_idx]
        return np.all(size <= rem + EPS, axis=1)

    def remaining(self, open_idx: np.ndarray) -> np.ndarray:
        return 1.0 - self.used[open_idx]

    def effective_close(self, open_idx: np.ndarray, now: float) -> np.ndarray:
        """Indicated closing times clamped to >= now (paper §VI adaptation)."""
        return np.maximum(self.indicated_close[open_idx], now)
