"""repro.resilience - fault injection, guarded dispatch, checkpoint/resume
and input quarantine: the layer that keeps long replays and the serving
scheduler alive when parts of the stack fail.

  * ``faults``     - deterministic scripted failures at the dispatch seams
                     (env ``REPRO_FAULTS``; every recovery path below is
                     CI-testable because the failures replay identically),
  * ``guard``      - retry-with-backoff for transient device errors, then
                     graceful degradation down an explicit ladder (blocked
                     megakernel -> per-event kernel -> jnp reference,
                     sharded -> single device) with bit-identical results,
  * ``checkpoint`` - atomic scan-carry snapshots at block boundaries so a
                     killed ``run_sweep --resume`` continues bit-identically,
  * ``validate``   - malformed workload rows quarantined (counted), never
                     crashing a run; ``python -m repro validate``.

Counter glossary additions live in ``sweep/README.md`` ("Resilience").
"""
from ..core.jaxsim import CapacityError
from . import checkpoint, faults, guard, validate
from .checkpoint import (ReplayCheckpointer, checkpointed_replay,
                         load_checkpoint, save_checkpoint)
from .faults import FAULT_KINDS, FaultPlan, FaultSpec, InjectedFault, fire, \
    parse_plan
from .guard import (Rung, backoff_delay, guarded_call, is_degradable,
                    is_transient, replay_rungs, run_ladder, rung_label,
                    transition_name)
from .validate import (ValidationReport, sanitize_rows, validate_instance,
                       validate_rows)

__all__ = [
    "CapacityError",
    "checkpoint", "faults", "guard", "validate",
    "ReplayCheckpointer", "checkpointed_replay", "load_checkpoint",
    "save_checkpoint",
    "FAULT_KINDS", "FaultPlan", "FaultSpec", "InjectedFault", "fire",
    "parse_plan",
    "Rung", "backoff_delay", "guarded_call", "is_degradable",
    "is_transient", "replay_rungs", "run_ladder", "rung_label",
    "transition_name",
    "ValidationReport", "sanitize_rows", "validate_instance",
    "validate_rows",
]
