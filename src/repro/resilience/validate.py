"""Input validation + quarantine: malformed workload rows never crash a run.

Real traces carry garbage - NaN durations, departures before arrivals,
demands above machine capacity, duplicated request ids.  ``Instance``
*asserts* these invariants, so one bad row aborts a whole sweep at
construction time.  This module checks the raw row arrays *before*
construction (``validate_rows``), and ``sanitize_rows`` drops the bad rows
into a quarantine report - counted per reason as
``resilience.quarantine_<reason>`` plus the total
``resilience.quarantine_rows`` - and builds the ``Instance`` from the
surviving rows, sorted by arrival.

``python -m repro validate`` runs the same checks over a suite spec (the
generators and the real-trace loader both funnel through ``Instance``, so
a clean pass proves the whole pipeline yields well-formed workloads);
exit status 1 means quarantined rows or an unbuildable suite.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from .. import obs
from ..core.types import EPS, Instance

# reason -> human description, in report order
REASONS = (
    ("nan", "non-finite size / arrival / departure"),
    ("nonpos_size", "size component <= 0"),
    ("oversize", "size component > capacity"),
    ("nonpos_duration", "departure <= arrival (empty interval)"),
    ("dup_id", "duplicate item id (first occurrence kept)"),
)


@dataclasses.dataclass
class ValidationReport:
    """Outcome of one ``validate_rows`` pass."""

    n_rows: int
    keep: np.ndarray                      # (n,) bool - rows that survive
    reasons: Dict[str, np.ndarray]        # reason -> (n,) bool

    @property
    def n_bad(self) -> int:
        return int(self.n_rows - self.keep.sum())

    @property
    def ok(self) -> bool:
        return self.n_bad == 0

    def counts(self) -> Dict[str, int]:
        return {r: int(m.sum()) for r, m in self.reasons.items()
                if m.any()}

    def summary(self) -> str:
        if self.ok:
            return f"{self.n_rows} rows ok"
        parts = ", ".join(f"{r}={c}" for r, c in self.counts().items())
        return (f"{self.n_rows} rows, {self.n_bad} quarantined "
                f"({parts})")


def validate_rows(sizes, arrivals, departures, ids=None,
                  capacity: float = 1.0) -> ValidationReport:
    """Check raw workload rows against the ``Instance`` invariants.

    ``sizes`` (n, d), ``arrivals`` / ``departures`` (n,); ``ids`` (n,)
    optional item identifiers (duplicates past the first occurrence are
    flagged).  A row failing several checks counts once per reason but is
    quarantined once."""
    sizes = np.asarray(sizes, np.float64)
    if sizes.ndim == 1:
        sizes = sizes[:, None]
    arrivals = np.asarray(arrivals, np.float64)
    departures = np.asarray(departures, np.float64)
    n = sizes.shape[0]
    nan = ~(np.isfinite(sizes).all(axis=1) & np.isfinite(arrivals) &
            np.isfinite(departures))
    # comparisons involving NaN are False, so gate the value checks on the
    # finite rows - a NaN row is "nan", not also "nonpos_size"
    fin = ~nan
    nonpos_size = fin & (np.where(fin[:, None], sizes, 1.0) <= 0).any(axis=1)
    oversize = fin & (np.where(fin[:, None], sizes, 0.0) >
                      capacity + EPS).any(axis=1)
    nonpos_duration = fin & (departures <= arrivals)
    if ids is not None:
        ids = np.asarray(ids)
        _, first = np.unique(ids, return_index=True)
        dup = np.ones(n, bool)
        dup[first] = False
    else:
        dup = np.zeros(n, bool)
    reasons = {"nan": nan, "nonpos_size": nonpos_size,
               "oversize": oversize, "nonpos_duration": nonpos_duration,
               "dup_id": dup}
    keep = ~(nan | nonpos_size | oversize | nonpos_duration | dup)
    return ValidationReport(n, keep, reasons)


def sanitize_rows(sizes, arrivals, departures, ids=None,
                  capacity: float = 1.0, name: str = "instance",
                  ) -> Tuple[Instance, ValidationReport]:
    """Quarantine bad rows (counted) and build an ``Instance`` from the
    survivors, sorted by arrival.  The counters are the always-on record;
    callers decide whether a non-empty quarantine is fatal."""
    rep = validate_rows(sizes, arrivals, departures, ids, capacity)
    if not rep.ok:
        obs.counter_add("resilience.quarantine_rows", rep.n_bad)
        for reason, count in rep.counts().items():
            obs.counter_add(f"resilience.quarantine_{reason}", count)
        obs.instant("resilience.quarantine", instance=name,
                    **rep.counts())
    sizes = np.asarray(sizes, np.float64)
    if sizes.ndim == 1:
        sizes = sizes[:, None]
    arrivals = np.asarray(arrivals, np.float64)[rep.keep]
    departures = np.asarray(departures, np.float64)[rep.keep]
    sizes = sizes[rep.keep]
    order = np.argsort(arrivals, kind="stable")
    inst = Instance(sizes[order], arrivals[order], departures[order], name)
    return inst, rep


def validate_instance(inst: Instance) -> ValidationReport:
    """Re-check a built ``Instance`` (defense in depth - the constructor
    asserts the same invariants)."""
    return validate_rows(inst.sizes, inst.arrivals, inst.departures)


def main(argv=None, prog: str = "python -m repro validate") -> None:
    """Validate every instance a suite spec builds; exit 1 on bad rows."""
    import argparse
    from ..sweep.grid import SuiteSpec
    from ..sweep.__main__ import SUITE_DEFAULT_SEED

    ap = argparse.ArgumentParser(
        prog=prog,
        description="Check workload suites for malformed rows (NaN or "
                    "negative durations, departure < arrival, oversize "
                    "demands, duplicate ids).")
    ap.add_argument("--suites", nargs="+", default=["azure"],
                    choices=["azure", "huawei", "azure_trace"])
    ap.add_argument("--n-instances", type=int, default=6)
    ap.add_argument("--n-items", type=int, default=500)
    ap.add_argument("--suite-seed", type=int, default=None)
    ap.add_argument("--trace-root", default="data/azure")
    args = ap.parse_args(argv)

    bad = 0
    for fam in args.suites:
        suite = SuiteSpec(fam, args.n_instances, args.n_items,
                          args.suite_seed if args.suite_seed is not None
                          else SUITE_DEFAULT_SEED[fam],
                          trace_root=args.trace_root)
        try:
            insts = suite.build()
        except (FileNotFoundError, AssertionError, ValueError) as e:
            print(f"{suite.label()}: BUILD FAILED: {e}")
            bad += 1
            continue
        for inst in insts:
            rep = validate_instance(inst)
            status = "ok" if rep.ok else "BAD"
            print(f"{suite.label()}/{inst.name}: {rep.summary()} [{status}]")
            bad += rep.n_bad
    if bad:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
