"""Deterministic fault injection: scripted failures at the dispatch seams.

Every failure-prone boundary in the execution stack crosses a named host
-side *seam* - ``faults.fire(site)`` - before dispatching real work:

  * ``kernel.select`` / ``kernel.select_block`` - the jitted select
    wrappers in ``kernels.ops`` (the seam wraps the jit; a seam *inside* a
    jitted body would fire once at trace time and never again),
  * ``sweep.scan``   - one batched replay dispatch in ``sweep.runner``,
  * ``sweep.group``  - one (suite, policy, pred) group in ``sweep.grid``,
  * ``ckpt.segment`` / ``ckpt.save`` - the segmented checkpointed replay,
  * ``store.load`` / ``store.save`` - sweep-store I/O (``path=`` context),
  * ``serving.select`` - one on-device placement decision.

A ``FaultPlan`` scripts which calls fail and how: each spec matches sites
by glob, arms at the ``at``-th crossing of a matching site and fires for
``count`` consecutive crossings.  Plans are deterministic - faults are a
pure function of the call sequence (plus an explicit ``seed`` that only
jitters the ``slow`` delay), so a chaos test replays identically.

Fault kinds (mirroring what real runs die of):

  * ``xla``      - raises ``InjectedFault`` with an ``INTERNAL:`` message
                   (an XlaRuntimeError-shaped device failure; degradable),
  * ``oom``      - raises with ``RESOURCE_EXHAUSTED:`` (transient: the
                   guard retries it before degrading),
  * ``error``    - a plain injected crash (degradable, not transient),
  * ``slow``     - sleeps ``delay`` seconds (deadline / shedding tests),
  * ``truncate`` - truncates the file passed as ``fire(..., path=)`` to
                   half its size (torn-write corruption),
  * ``kill``     - ``os._exit(137)``: the process dies as if SIGKILLed
                   (checkpoint/resume chaos tests run this in a
                   subprocess).

Activation: ``install(plan)`` / ``clear()`` in-process, the ``injected``
context manager for tests, or env ``REPRO_FAULTS`` for subprocesses -
a comma list of ``site:kind[:at[:count[:delay]]]``, e.g.
``REPRO_FAULTS="sweep.group:kill:3"``.  With no plan installed ``fire``
is two global reads - cheap enough to sit on every hot path
(benchmarks/perf.py::resilience_overhead asserts the budget).
"""
from __future__ import annotations

import dataclasses
import fnmatch
import hashlib
import os
import time
from typing import Dict, List, Optional

from .. import obs

FAULT_KINDS = ("xla", "oom", "error", "slow", "truncate", "kill")

_MESSAGES = {
    "xla": "INTERNAL: injected XlaRuntimeError at seam {site!r}",
    "oom": "RESOURCE_EXHAUSTED: injected OOM at seam {site!r}",
    "error": "injected fault at seam {site!r}",
}


class InjectedFault(RuntimeError):
    """A scripted failure raised by the harness (stands in for
    XlaRuntimeError and friends; ``guard.is_degradable`` treats it as a
    device failure, and ``guard.is_transient`` classifies by the same
    status markers real jax errors carry)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault: glob over seam names, kind, and when."""

    site: str            # fnmatch glob over seam names ("sweep.*")
    kind: str            # one of FAULT_KINDS
    at: int = 1          # 1-based crossing index at which it arms
    count: int = 1       # consecutive crossings that fire (0 = forever)
    delay: float = 0.05  # "slow" sleep seconds (jittered by the plan seed)

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, \
            f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}"
        assert self.at >= 1 and self.count >= 0


class FaultPlan:
    """Deterministic per-site call counting over a list of FaultSpecs."""

    def __init__(self, specs: List[FaultSpec], seed: int = 0):
        self.specs = list(specs)
        self.seed = seed
        self.calls: Dict[str, int] = {}     # site -> crossings so far
        self.fired: Dict[str, int] = {}     # "site:kind" -> times fired

    def on_call(self, site: str) -> Optional[FaultSpec]:
        n = self.calls.get(site, 0) + 1
        self.calls[site] = n
        for sp in self.specs:
            if fnmatch.fnmatchcase(site, sp.site) and n >= sp.at and \
                    (sp.count == 0 or n < sp.at + sp.count):
                self.fired[f"{site}:{sp.kind}"] = \
                    self.fired.get(f"{site}:{sp.kind}", 0) + 1
                return sp
        return None

    def jitter(self, site: str, delay: float) -> float:
        """Deterministic [0.5, 1.5) delay jitter from (seed, site, call)."""
        h = hashlib.blake2b(
            f"{self.seed}:{site}:{self.calls.get(site, 0)}".encode(),
            digest_size=4).digest()
        return delay * (0.5 + int.from_bytes(h, "big") / 0x100000000)


def parse_plan(text: str, seed: int = 0) -> FaultPlan:
    """Parse the ``REPRO_FAULTS`` format:
    ``site:kind[:at[:count[:delay]]]`` comma-separated."""
    specs = []
    for tok in text.split(","):
        tok = tok.strip()
        if not tok:
            continue
        parts = tok.split(":")
        assert len(parts) >= 2, \
            f"fault spec {tok!r} needs at least site:kind"
        site, kind = parts[0], parts[1]
        at = int(parts[2]) if len(parts) > 2 else 1
        count = int(parts[3]) if len(parts) > 3 else 1
        delay = float(parts[4]) if len(parts) > 4 else 0.05
        specs.append(FaultSpec(site, kind, at, count, delay))
    return FaultPlan(specs, seed=seed)


# ------------------------------------------------------- active plan state

_PLAN: Optional[FaultPlan] = None
_ENV_CHECKED = False


def install(plan) -> FaultPlan:
    """Activate a FaultPlan (or a ``REPRO_FAULTS``-format string)."""
    global _PLAN
    if isinstance(plan, str):
        plan = parse_plan(plan)
    _PLAN = plan
    return plan


def clear() -> None:
    """Deactivate fault injection (the env plan is not re-read)."""
    global _PLAN, _ENV_CHECKED
    _PLAN = None
    _ENV_CHECKED = True


def active() -> Optional[FaultPlan]:
    return _PLAN


class injected:
    """``with faults.injected("sweep.scan:xla"): ...`` - scoped plan."""

    def __init__(self, plan):
        self.plan = parse_plan(plan) if isinstance(plan, str) else plan

    def __enter__(self) -> FaultPlan:
        self._prev = _PLAN
        install(self.plan)
        return self.plan

    def __exit__(self, *exc):
        global _PLAN
        _PLAN = self._prev
        return False


def fire(site: str, path: Optional[str] = None) -> None:
    """The seam: a no-op (two global reads) unless an armed spec matches.

    ``path`` is the file the seam is about to touch (store / checkpoint
    I/O) - the ``truncate`` kind corrupts it in place."""
    global _PLAN, _ENV_CHECKED
    if _PLAN is None:
        if _ENV_CHECKED:
            return
        _ENV_CHECKED = True
        text = os.environ.get("REPRO_FAULTS", "")
        if not text:
            return
        _PLAN = parse_plan(text)
    sp = _PLAN.on_call(site)
    if sp is None:
        return
    obs.counter_add(f"resilience.fault_{sp.kind}")
    obs.instant(f"fault.{site}", kind=sp.kind)
    if sp.kind == "slow":
        time.sleep(_PLAN.jitter(site, sp.delay))
        return
    if sp.kind == "truncate":
        if path and os.path.exists(path):
            size = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.truncate(max(size // 2, 1))
        return
    if sp.kind == "kill":
        os._exit(137)   # die like SIGKILL: no atexit, no cleanup
    raise InjectedFault(_MESSAGES[sp.kind].format(site=site))
