"""Checkpoint/resume for long replays: atomic snapshots of the scan carry.

The batched replay is a ``lax.scan`` over the event axis; its carry at any
event boundary is the complete replay state (slot loads, category state,
running usage - see ``core.jaxsim._replay_batch``).  ``checkpointed_replay``
drives the same scan in fixed-shape *segments* of ``every_events`` events
(padding the tail with PAD no-op events, rounded to a ``block_events``
multiple so the megakernel path segments identically), snapshotting the
carry between segments.  A killed run resumes from the last snapshot and
produces bit-identical usage/bins - the segments replay the identical
event stream with the identical carry.

Two correctness subtleties the segmentation must respect:

  * RCP's running distinct-category count is a cumsum over the *whole*
    event axis (``jaxsim._category_setup``); it is computed once here on
    the full padded stream (``jaxsim.replay_event_extras``) and sliced per
    segment - recomputing it inside a segment would restart the count and
    change decisions.
  * Segments share one jit trace (fixed event shape, carry passed in as a
    traced pytree); only the first segment (no carry yet) traces
    separately.

Snapshot format: one ``.npz`` written to a temp file, fsynced, then
atomically renamed; holds the carry leaves, a JSON header (pytree
structure + run metadata) and a content checksum.  Loading verifies the
checksum and that the metadata matches the *current* run (policy, padded
geometry, backend, a digest of the input arrays) - a stale or torn
snapshot is quarantined to a ``.corrupt`` sidecar and ignored, never
trusted.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from functools import partial
from typing import Optional, Tuple

import jax
import numpy as np

from .. import obs
from . import faults

# ------------------------------------------------- pytree (de)serialization
# Scan carries are nests of dict/tuple over arrays; encode the structure as
# JSON instead of pickling treedefs, so snapshots stay inspectable and
# loadable across jax versions.


def _pack(obj, leaves):
    if obj is None:
        return {"t": "none"}
    if isinstance(obj, dict):
        keys = sorted(obj)
        return {"t": "dict", "k": keys,
                "v": [_pack(obj[k], leaves) for k in keys]}
    if isinstance(obj, (tuple, list)):
        return {"t": "tuple" if isinstance(obj, tuple) else "list",
                "v": [_pack(x, leaves) for x in obj]}
    leaves.append(np.asarray(obj))
    return {"t": "leaf", "i": len(leaves) - 1}


def _unpack(node, leaves):
    t = node["t"]
    if t == "none":
        return None
    if t == "dict":
        return {k: _unpack(v, leaves)
                for k, v in zip(node["k"], node["v"])}
    if t in ("tuple", "list"):
        seq = [_unpack(v, leaves) for v in node["v"]]
        return tuple(seq) if t == "tuple" else seq
    return leaves[node["i"]]


def _checksum(structure: dict, leaves) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(json.dumps(structure, sort_keys=True).encode())
    for a in leaves:
        h.update(str((a.shape, str(a.dtype))).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def save_checkpoint(path: str, carry, meta: dict) -> str:
    """Atomically snapshot a carry pytree: tmp + fsync + rename, with a
    content checksum in the header."""
    leaves = []
    structure = _pack(carry, leaves)
    header = {"meta": meta, "structure": structure,
              "checksum": _checksum(structure, leaves)}
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __header__=np.array(json.dumps(header)),
                     **{f"leaf_{i}": a for i, a in enumerate(leaves)})
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    faults.fire("ckpt.save", path=path)
    return path


def load_checkpoint(path: str, expect_meta: Optional[dict] = None):
    """Load a snapshot; returns ``(carry, meta)`` or None.

    None means "start from scratch": missing file, torn/corrupt file
    (checksum or parse failure - quarantined to ``path.corrupt``), or
    metadata not matching ``expect_meta`` (a snapshot from a different
    run/geometry; left in place, counted as stale)."""
    if not os.path.exists(path):
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            header = json.loads(str(z["__header__"].item()))
            leaves = [z[f"leaf_{i}"] for i in
                      range(len(z.files) - 1)]
        if header["checksum"] != _checksum(header["structure"], leaves):
            raise ValueError("checkpoint checksum mismatch")
    except Exception as e:   # torn write, bad zip, bad json: quarantine
        side = path + ".corrupt"
        os.replace(path, side)
        obs.counter_add("resilience.ckpt_corrupt")
        obs.instant("resilience.ckpt_corrupt", path=path,
                    error=str(e)[:200])
        return None
    meta = header["meta"]
    if expect_meta is not None and \
            any(meta.get(k) != v for k, v in expect_meta.items()):
        obs.counter_add("resilience.ckpt_stale")
        return None
    return _unpack(header["structure"], leaves), meta


# --------------------------------------------------------- segmented replay

@dataclasses.dataclass
class ReplayCheckpointer:
    """Where/how often to snapshot a segmented replay.

    ``every_events`` is the segment length (rounded up to a
    ``block_events`` multiple); ``resume=False`` ignores existing
    snapshots (they are overwritten); ``keep=True`` leaves the final
    snapshot on disk after a completed run (default: deleted - a finished
    replay needs no resume point)."""

    root: str
    every_events: int = 2048
    resume: bool = True
    keep: bool = False

    def path_for(self, key: str) -> str:
        safe = "".join(c if c.isalnum() or c in "._-" else "-"
                       for c in key)
        return os.path.join(self.root, f"ckpt_{safe}.npz")


@partial(jax.jit, static_argnames=("policy", "max_bins", "backend",
                                   "block_events", "migrate"))
def _segment(sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps,
             n_items, ev_extra, carry0, *, policy: str, max_bins: int,
             backend: str, block_events: int, migrate: bool = False):
    from ..core.jaxsim import _replay_batch
    return _replay_batch(
        sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps, n_items,
        policy=policy, max_bins=max_bins, backend=backend,
        block_events=block_events, carry0=carry0, return_carry=True,
        ev_extra=ev_extra, migrate=migrate)


def _input_digest(arrays, policy, max_bins, backend, block_events,
                  seg: int, migrate: bool = False) -> str:
    h = hashlib.blake2b(digest_size=8)
    h.update(f"{policy}|{max_bins}|{backend}|{block_events}|{seg}"
             f"|mig{int(migrate)}".encode())
    for a in arrays:
        if a is None:
            h.update(b"|none")
            continue
        a = np.asarray(a)
        h.update(str((a.shape, str(a.dtype))).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def checkpointed_replay(arrays, *, policy: str, max_bins: int,
                        backend: str, block_events: int,
                        ckpt: ReplayCheckpointer, key: str,
                        migrate: bool = False):
    """Replay flattened lanes in checkpointed segments.

    ``arrays`` is the runner's flattened-lane tuple (sizes, times, kinds,
    items, pdeps (L, n_max), dmask, arrivals, rdeps, n_items).  Returns
    (usage (L,), opened (L,), placements (L, n_max), overflow (L,)) -
    bit-identical to the unsegmented replay (tests/test_resilience.py
    asserts it per policy family).  Single-device by construction; the
    runner's ladder handles sharding.  ``migrate=True`` compiles the
    MIGRATE event branch in (streams carrying consolidation events);
    the flag is part of the snapshot digest so a resume never mixes
    graphs."""
    from ..core.jaxsim import PAD_KIND, replay_event_extras
    sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps, n_items = \
        arrays
    times = np.asarray(times)
    kinds = np.asarray(kinds)
    items = np.asarray(items)
    L, E = times.shape
    T = max(int(block_events), 1)
    seg = max(int(ckpt.every_events), T)
    seg = -(-seg // T) * T                 # block-multiple segments
    nseg = max(-(-E // seg), 1)
    pad = nseg * seg - E
    if pad:
        # PAD events are no-ops (the carry passes through), so padding the
        # tail up to a segment multiple never changes decisions
        times = np.concatenate(
            [times, np.zeros((L, pad), times.dtype)], axis=1)
        kinds = np.concatenate(
            [kinds, np.full((L, pad), PAD_KIND, kinds.dtype)], axis=1)
        items = np.concatenate(
            [items, np.zeros((L, pad), items.dtype)], axis=1)
    extras = replay_event_extras(policy, sizes, pdeps, dmask, arrivals,
                                 rdeps, n_items, times, kinds, items)
    digest = _input_digest(arrays, policy, max_bins, backend, block_events,
                           seg, migrate)
    path = ckpt.path_for(key)
    start, carry = 0, None
    if ckpt.resume:
        loaded = load_checkpoint(path, {"digest": digest})
        if loaded is not None:
            carry, meta = loaded
            carry = jax.tree.map(lambda a: a, carry)   # plain np leaves
            start = int(meta["next_seg"])
            obs.counter_add("resilience.ckpt_resume")
            obs.instant("resilience.ckpt_resume", key=key, seg=start)
    out = None
    for s in range(start, nseg):
        faults.fire("ckpt.segment")
        lo, hi = s * seg, (s + 1) * seg
        usage, opened, placements, overflow, carry = _segment(
            sizes, times[:, lo:hi], kinds[:, lo:hi], items[:, lo:hi],
            pdeps, dmask, arrivals, rdeps, n_items,
            tuple(np.asarray(x)[:, lo:hi] for x in extras), carry,
            policy=policy, max_bins=max_bins, backend=backend,
            block_events=block_events, migrate=migrate)
        out = (usage, opened, placements, overflow)
        if s + 1 < nseg:
            # snapshot BETWEEN segments: the carry is the full replay
            # state, so resume needs nothing else
            save_checkpoint(
                path, jax.tree.map(np.asarray, carry),
                {"digest": digest, "next_seg": s + 1, "policy": policy,
                 "max_bins": int(max_bins), "backend": backend,
                 "block_events": int(block_events)})
            obs.counter_add("resilience.ckpt_save")
    if not ckpt.keep and os.path.exists(path):
        os.unlink(path)
    return out


# --------------------------------------------------------- streamed replay

@dataclasses.dataclass
class StreamCheckpointer:
    """Chunk-boundary snapshots for ``repro.stream.replay_stream``.

    The streamed replay's complete state at a chunk boundary is (carry,
    row pool, chunk index): the host-side chunk builder is deterministic,
    so a resumed run rebuilds it by fast-forwarding the request stream to
    the snapshot's chunk - no event arrays are ever persisted.  Snapshots
    reuse the atomic/checksummed ``save_checkpoint`` format; the digest
    key covers the source fingerprint and the full replay config (policy,
    pool size, backend, block/chunk geometry), so a snapshot from a
    different stream or geometry is stale, never trusted.

    ``every_chunks`` is the snapshot cadence (each save fences the device
    pipeline - the double-buffered overlap resumes on the next chunk);
    ``keep=True`` leaves the last snapshot after a completed run."""

    root: str
    every_chunks: int = 8
    resume: bool = True
    keep: bool = False

    def key(self, fingerprint: str, *, policy: str, max_bins: int,
            backend: str, block_events: int, chunk_events: int) -> str:
        h = hashlib.blake2b(digest_size=8)
        h.update(f"{fingerprint}|{policy}|{max_bins}|{backend}"
                 f"|{block_events}|{chunk_events}".encode())
        return f"{policy}-{h.hexdigest()}"

    def path_for(self, key: str) -> str:
        safe = "".join(c if c.isalnum() or c in "._-" else "-"
                       for c in key)
        return os.path.join(self.root, f"stream_{safe}.npz")

    def load(self, key: str):
        """(carry, pool, chunks_done) from a matching snapshot, or None."""
        if not self.resume:
            return None
        loaded = load_checkpoint(self.path_for(key), {"digest": key})
        if loaded is None:
            return None
        state, meta = loaded
        import jax.numpy as jnp
        state = jax.tree.map(jnp.asarray, state)
        obs.counter_add("resilience.stream_ckpt_resume")
        obs.instant("resilience.stream_ckpt_resume", key=key,
                    chunks=int(meta["chunks"]))
        return state["carry"], state["pool"], int(meta["chunks"])

    def maybe_save(self, key: str, carry, pool, chunks: int, *,
                   final: bool) -> None:
        path = self.path_for(key)
        if final:
            if not self.keep and os.path.exists(path):
                os.unlink(path)
            return
        if chunks % max(int(self.every_chunks), 1):
            return
        state = jax.tree.map(np.asarray, {"carry": carry, "pool": pool})
        save_checkpoint(path, state, {"digest": key, "chunks": int(chunks)})
        obs.counter_add("resilience.stream_ckpt_save")
