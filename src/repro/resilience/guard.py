"""Guarded dispatch: retry-with-backoff plus the degradation ladder.

Two layers, composed by ``run_ladder``:

  * ``guarded_call`` retries *transient* device failures (RESOURCE_
    EXHAUSTED / UNAVAILABLE / DEADLINE_EXCEEDED / ABORTED - the status
    markers real XlaRuntimeErrors carry) on the same execution plan, with
    deterministic jittered exponential backoff.  Counted as
    ``resilience.retry``.
  * When retries exhaust (or the failure is non-transient but still a
    *device* failure - ``is_degradable``), execution moves DOWN an
    explicit ladder of equivalent plans: blocked megakernel -> per-event
    kernel, sharded -> single-device, kernel backend -> jnp reference.
    Every rung replays the identical decision sequence (the backends are
    bit-identical on fp32-exact instances; tests/test_resilience.py
    asserts usage equality under injected faults), so degrading trades
    throughput, never results.  Each step is counted as
    ``resilience.degrade_<from>_<to>``.

Failures that are neither transient nor degradable (assertion errors,
shape errors, KeyboardInterrupt) propagate immediately - the ladder
exists for *device* trouble, not for bugs.

Backoff sleeps scale with env ``REPRO_RESILIENCE_BACKOFF_SCALE`` (tests
set 0 to run the retry logic without the waiting).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from typing import Callable, List, Tuple

from .. import obs
from .faults import InjectedFault

# status markers of failures worth retrying on the same plan
TRANSIENT_MARKERS = ("RESOURCE_EXHAUSTED", "DEADLINE_EXCEEDED",
                     "UNAVAILABLE", "ABORTED")
# ... plus markers that say "the device/runtime broke" (degradable but
# not worth retrying on the same plan)
_DEVICE_MARKERS = TRANSIENT_MARKERS + ("INTERNAL", "XLA", "pallas")


def is_transient(exc: BaseException) -> bool:
    """Worth retrying on the same execution plan."""
    return isinstance(exc, Exception) and \
        any(m in str(exc) for m in TRANSIENT_MARKERS)


def is_degradable(exc: BaseException) -> bool:
    """A device/runtime failure a lower ladder rung can route around."""
    if isinstance(exc, InjectedFault):
        return True
    if type(exc).__name__ == "XlaRuntimeError":
        return True
    return isinstance(exc, RuntimeError) and \
        any(m in str(exc) for m in _DEVICE_MARKERS)


def backoff_delay(site: str, attempt: int, base: float = 0.05,
                  factor: float = 2.0, seed: int = 0) -> float:
    """Exponential backoff with deterministic jitter in [0.5, 1.5) -
    reproducible chaos runs, no synchronized retry herds."""
    h = hashlib.blake2b(f"{seed}:{site}:{attempt}".encode(),
                       digest_size=4).digest()
    jitter = 0.5 + int.from_bytes(h, "big") / 0x100000000
    scale = float(os.environ.get("REPRO_RESILIENCE_BACKOFF_SCALE", "1"))
    return base * (factor ** (attempt - 1)) * jitter * scale


def guarded_call(fn: Callable, *, site: str, retries: int = 2,
                 base_delay: float = 0.05, seed: int = 0):
    """Call ``fn()``; retry transient failures up to ``retries`` times
    with jittered exponential backoff.  Non-transient failures (and the
    last transient one) propagate to the caller - typically a ladder."""
    attempt = 0
    while True:
        try:
            return fn()
        except Exception as e:
            if attempt >= retries or not is_transient(e):
                raise
            attempt += 1
            obs.counter_add("resilience.retry")
            obs.instant("resilience.retry", site=site, attempt=attempt,
                        error=str(e)[:200])
            time.sleep(backoff_delay(site, attempt, base_delay, seed=seed))


# ------------------------------------------------------------- the ladder

@dataclasses.dataclass(frozen=True)
class Rung:
    """One execution plan on the replay degradation ladder."""

    label: str
    backend: str
    block_events: int
    ndev: int


def rung_label(backend: str, block_events: int, ndev: int) -> str:
    if block_events and block_events > 1:
        lab = "blocked"
    elif backend != "jnp":
        lab = "perevent"
    else:
        lab = "jnp"
    return lab + ("_sharded" if ndev > 1 else "")


def replay_rungs(backend: str, block_events: int, ndev: int) -> List[Rung]:
    """The ladder for one replay dispatch, degrading one axis per rung:
    drop the event-blocked megakernel first (keep the kernel), then lane
    sharding, then the kernel backend itself (jnp is the reference twin -
    the floor, never degraded past)."""
    cfgs = [(backend, block_events, ndev)]
    be, T, nd = backend, block_events, ndev
    if T and T > 1:
        T = 0
        cfgs.append((be, T, nd))
    if nd > 1:
        nd = 1
        cfgs.append((be, T, nd))
    if be != "jnp":
        be = "jnp"
        cfgs.append((be, T, nd))
    return [Rung(rung_label(*c), *c) for c in cfgs]


def transition_name(a: Rung, b: Rung) -> Tuple[str, str]:
    """(from, to) labels for the one axis a ladder step degrades."""
    if (a.block_events or 0) != (b.block_events or 0):
        return ("blocked", "perevent")
    if a.ndev != b.ndev:
        return ("sharded", "single")
    return (a.backend, b.backend)


def run_ladder(attempt: Callable[[Rung], object], rungs: List[Rung], *,
               site: str, retries: int = 2, base_delay: float = 0.05):
    """Run ``attempt(rung)`` down the ladder: each rung is retried for
    transient failures (``guarded_call``); a degradable failure moves to
    the next rung with a ``resilience.degrade_<from>_<to>`` counter.
    Returns ``(rung, result)`` for the rung that served.  The last rung's
    failure - or any non-degradable one - propagates."""
    for i, rung in enumerate(rungs):
        try:
            return rung, guarded_call(lambda: attempt(rung), site=site,
                                      retries=retries,
                                      base_delay=base_delay)
        except Exception as e:
            if i + 1 >= len(rungs) or not is_degradable(e):
                raise
            frm, to = transition_name(rung, rungs[i + 1])
            obs.counter_add(f"resilience.degrade_{frm}_{to}")
            obs.instant("resilience.degrade", site=site, frm=frm, to=to,
                        error=str(e)[:200])
    raise AssertionError("unreachable: empty ladder")
