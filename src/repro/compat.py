"""Version shims for the pinned jax.

``shard_map``: jax >= 0.6 exposes it at the top level and renames the
replication-check kwarg to ``check_vma``; jax 0.4.x has it under
``jax.experimental.shard_map`` with ``check_rep``.  Callers use the new
spelling and this wrapper translates.
"""
from __future__ import annotations

try:
    from jax import shard_map as _shard_map
    _SHARD_CHECK_KW = "check_vma"
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_SHARD_CHECK_KW: check_vma})
