"""Version shims for the pinned jax.

``shard_map``: jax >= 0.6 exposes it at the top level and renames the
replication-check kwarg to ``check_vma``; jax 0.4.x has it under
``jax.experimental.shard_map`` with ``check_rep``.  Callers use the new
spelling and this wrapper translates.

``optimization_barrier`` autodiff: jax 0.4.37 has no differentiation rule
for ``optimization_barrier_p`` (added upstream in 0.4.38), so every
remat/microbatch model that wraps layer params in a barrier fails under
``jax.grad``.  The barrier is the identity for autodiff, so
``install_optimization_barrier_grad`` registers the upstream JVP/transpose
rules when they are missing; it runs on import (same pattern as the
shard_map shim: callers just ``import repro.compat``).
"""
from __future__ import annotations

try:
    from jax import shard_map as _shard_map
    _SHARD_CHECK_KW = "check_vma"
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_SHARD_CHECK_KW: check_vma})


def install_optimization_barrier_grad() -> bool:
    """Make ``jax.lax.optimization_barrier`` differentiable (identity rules).

    Returns True when the shim (or an upstream rule) is in place.  No-op on
    jax versions that already ship the rules.
    """
    try:
        from jax.interpreters import ad
        from jax._src.lax import lax as _lax_internal
        prim = _lax_internal.optimization_barrier_p
    except (ImportError, AttributeError):   # pragma: no cover - future jax
        return False
    if prim not in ad.primitive_jvps:
        def _jvp(primals, tangents):
            tangents = [ad.instantiate_zeros(t) for t in tangents]
            return prim.bind(*primals), prim.bind(*tangents)
        ad.primitive_jvps[prim] = _jvp
    if prim not in ad.primitive_transposes:
        def _transpose(cts, *primals):
            return [ad.instantiate_zeros(ct) for ct in cts]
        ad.primitive_transposes[prim] = _transpose
    return True


install_optimization_barrier_grad()
