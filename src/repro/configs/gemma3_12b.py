"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8, head_dim 256) d_ff=15360
vocab=262144; 5:1 local(window 1024):global attention, 128k-class context.
[hf:google/gemma-3 family; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense", n_layers=48, d_model=3840,
    n_heads=16, n_kv_heads=8, head_dim=256, d_ff=15360, vocab=262144,
    attn_kind="mixed", window=1024, global_every=6, mlp_act="gelu_glu",
    rope_theta=1_000_000.0, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-reduced", family="dense", n_layers=6, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        attn_kind="mixed", window=8, global_every=6, mlp_act="gelu_glu",
        tie_embeddings=True, scan_chunk=8, attn_q_chunk=32)
