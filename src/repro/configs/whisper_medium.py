"""whisper-medium [audio]: enc-dec, 24+24L d=1024 16H (MHA kv=16) d_ff=4096
vocab=51865; conv frontend is a STUB (input_specs provides precomputed frame
embeddings). [arXiv:2212.04356; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab=51865,
    mlp_act="gelu", arch_kind="encdec", n_enc_layers=24,
    frontend="audio_stub",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-reduced", family="audio", n_layers=3,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab=512, mlp_act="gelu", arch_kind="encdec", n_enc_layers=3,
        frontend="audio_stub", scan_chunk=8, attn_q_chunk=32)
