"""minitron-8b [dense]: 32L d=4096 32H (GQA kv=8) d_ff=16384 vocab=256000;
pruned nemotron lineage (squared-ReLU MLP). [arXiv:2407.14679; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=16384, vocab=256000,
    mlp_act="relu2",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b-reduced", family="dense", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256, vocab=512,
        mlp_act="relu2", scan_chunk=8, attn_q_chunk=32)
