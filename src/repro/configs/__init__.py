"""Assigned architecture configs (--arch <id>) + the paper's own config.

Each module defines CONFIG (the exact assigned full-scale config) and
``reduced()`` (a tiny same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig

ARCHS = [
    "gemma3-12b", "qwen2.5-14b", "minitron-8b", "nemotron-4-340b",
    "granite-moe-3b-a800m", "deepseek-v2-lite-16b", "whisper-medium",
    "pixtral-12b", "rwkv6-1.6b", "hymba-1.5b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def get_reduced_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
