"""rwkv6-1.6b (Finch) [ssm]: 24L d=2048 attention-free (32 heads of 64),
d_ff=7168 vocab=65536; data-dependent per-channel decay.
[arXiv:2404.05892; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=7168, vocab=65536,
    rwkv=True, mlp_act="relu2", scan_chunk=16,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-reduced", family="ssm", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
        rwkv=True, mlp_act="relu2", scan_chunk=8, attn_q_chunk=32)
