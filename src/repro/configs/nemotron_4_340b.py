"""nemotron-4-340b [dense]: 96L d=18432 96H (GQA kv=8, head_dim 192)
d_ff=73728 vocab=256000; squared-ReLU MLP. [arXiv:2402.16819; unverified]

Fits 256 x 16GB only with FSDP + 8-bit optimizer states + grad-accum + remat
(see EXPERIMENTS.md §Dry-run).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense", n_layers=96, d_model=18432,
    n_heads=96, n_kv_heads=8, head_dim=192, d_ff=73728, vocab=256000,
    mlp_act="relu2",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b-reduced", family="dense", n_layers=6,
        d_model=96, n_heads=6, n_kv_heads=2, head_dim=16, d_ff=384,
        vocab=512, mlp_act="relu2", scan_chunk=8, attn_q_chunk=32)
