"""qwen2.5-14b [dense]: 48L d=5120 40H (GQA kv=8) d_ff=13824 vocab=152064;
QKV bias. [hf:Qwen/Qwen2.5 family; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=13824, vocab=152064,
    qkv_bias=True, mlp_act="silu_glu", rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-reduced", family="dense", n_layers=4, d_model=64,
        n_heads=8, n_kv_heads=2, head_dim=8, d_ff=160, vocab=512,
        qkv_bias=True, mlp_act="silu_glu", scan_chunk=8, attn_q_chunk=32)
