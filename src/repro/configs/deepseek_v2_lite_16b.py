"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H, MLA kv_lora=512 (+64 RoPE),
expert d_ff=1408, 64 routed experts top-6 + 2 shared, first layer dense
(d_ff 10944), vocab=102400. [arXiv:2405.04434; hf]

The assignment aside mentions "160 routed" which describes DeepSeek-V2-full;
the lite config (HF) has 64 routed experts - see DESIGN.md §5.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab=102400,
    n_experts=64, top_k=6, d_expert=1408, n_shared_experts=2,
    first_k_dense=1, dense_d_ff=10944, mla=True, kv_lora_rank=512,
    rope_head_dim=64, mlp_act="silu_glu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-reduced", family="moe", n_layers=4,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=96,
        vocab=512, n_experts=8, top_k=2, d_expert=48, n_shared_experts=1,
        first_k_dense=1, dense_d_ff=96, mla=True, kv_lora_rank=32,
        rope_head_dim=8, mlp_act="silu_glu", scan_chunk=8, attn_q_chunk=32)
