"""pixtral-12b [vlm]: 40L d=5120 32H (GQA kv=8, head_dim 128) d_ff=14336
vocab=131072; pixtral-ViT frontend is a STUB delivering patch embeddings
prepended to the text sequence. [hf:mistralai/Pixtral-12B-2409; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=131072,
    mlp_act="silu_glu", rope_theta=1_000_000.0, frontend="vision_stub",
    n_frontend_tokens=256,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b-reduced", family="vlm", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        mlp_act="silu_glu", frontend="vision_stub", n_frontend_tokens=8,
        scan_chunk=8, attn_q_chunk=32)
