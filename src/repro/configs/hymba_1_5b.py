"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5, head_dim 64) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba(SSD) heads per layer,
sliding-window attention with periodic global layers.
[arXiv:2411.13676; hf]  Meta-tokens omitted (DESIGN.md §5)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab=32001,
    attn_kind="mixed", window=1024, global_every=8, ssm=True, ssm_state=16,
    mlp_act="silu_glu", scan_chunk=16, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-reduced", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        attn_kind="mixed", window=8, global_every=4, ssm=True, ssm_state=4,
        mlp_act="silu_glu", scan_chunk=8, attn_q_chunk=32,
        tie_embeddings=True)
