"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8, head_dim 64)
expert d_ff=512 vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0 family; hf]

The assignment line lists both "MoE 40e top-8" and "32 experts top-8"; we
follow the explicit config field (40 experts) - see DESIGN.md §5.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, head_dim=64, d_ff=512, vocab=49155,
    n_experts=40, top_k=8, d_expert=512, mlp_act="silu_glu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-reduced", family="moe", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64, vocab=512,
        n_experts=8, top_k=2, d_expert=64, mlp_act="silu_glu",
        tie_embeddings=True, scan_chunk=8, attn_q_chunk=32)
