"""``python -m repro`` - the one CLI over the one experiment API.

Subcommands:
  * ``sweep`` - batched experiment grids (the former
    ``python -m repro.sweep``, flags unchanged; results in the sweep
    store).
  * ``serve`` - fleet capacity planning: replay a synthetic serving
    request stream through the batched DVBP engine (``repro.api``
    serving_requests workload) and compare policies against the host
    fleet baselines.  With ``--traffic {poisson,diurnal}`` it instead
    drives the live batched front end (admission queue -> double-buffered
    block dispatch) and reports throughput + placement latency.
  * ``bench`` - the benchmark harness (``benchmarks.run``; requires the
    repo root on sys.path, i.e. run from a checkout).
  * ``obs`` - summarize a JSONL observability run log (spans + counters),
    optionally converting it to Chrome/Perfetto trace_event JSON.
  * ``validate`` - input validation / quarantine dry run: build the suite
    instances and report rows the sanitizer would quarantine (NaN sizes,
    non-positive durations, departure < arrival, oversize, duplicate
    ids); exits non-zero when anything is bad.

    PYTHONPATH=src python -m repro sweep --suites azure --n-instances 12
    PYTHONPATH=src python -m repro serve --requests 2000 --sigma 0.5
    PYTHONPATH=src python -m repro serve --traffic poisson --rate 5e4 \
        --tps 1.2e5 --requests 2000
    PYTHONPATH=src python -m repro bench --fast
    PYTHONPATH=src python -m repro obs run.obs.jsonl --perfetto trace.json
    PYTHONPATH=src python -m repro validate --suites azure huawei
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional


def _serve(argv: Optional[List[str]]) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro serve",
        description="Fleet capacity planning: DVBP policies over a "
                    "request stream via the batched replay engine.")
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--tps", type=float, default=50.0,
                    help="decode tokens per second per slot")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sigma", type=float, default=0.0,
                    help="log-normal decode-length prediction error "
                         "(0 = clairvoyant predictions)")
    ap.add_argument("--policies",
                    default="first_fit,best_fit_linf,greedy,"
                            "nrt_prioritized",
                    help="comma list of scan policies to plan with")
    ap.add_argument("--setting", default="predicted",
                    choices=["nonclairvoyant", "clairvoyant", "predicted"],
                    help="information regime (predicted replays the "
                         "attached request predictions)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--kv-tokens", type=int, default=65536)
    ap.add_argument("--prefill-budget", type=float, default=262144)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "jnp", "pallas", "pallas_interpret"])
    ap.add_argument("--store", default="",
                    help="persist records to this sweep-store directory")
    ap.add_argument("--baselines", action="store_true",
                    help="also run the host round_robin / pack_all fleet "
                         "baselines for reference")
    ap.add_argument("--traffic", default=None,
                    choices=["poisson", "diurnal"],
                    help="run the live batched front end (admission queue "
                         "-> double-buffered block dispatch) under this "
                         "synthetic traffic instead of capacity planning")
    ap.add_argument("--batch-max", type=int, default=256,
                    help="admission batch size for --traffic mode")
    args = ap.parse_args(argv)

    from . import api
    from .serving.fleet import attach_predictions, synth_requests
    from .serving.scheduler import ReplicaCapacity

    if args.traffic:
        from .serving.dispatch import serve_traffic
        from .serving.traffic import make_traffic
        caps = ReplicaCapacity(args.slots, args.kv_tokens,
                               args.prefill_budget)
        reqs = make_traffic(args.traffic, args.requests, rate=args.rate,
                            seed=args.seed, sigma_pred=args.sigma)
        print(f"{'policy':<18} {'req/s':>10} {'p50_ms':>8} {'p99_ms':>8} "
              f"{'replica_s':>12} {'opened':>7} {'shed':>6}")
        for pol in args.policies.split(","):
            rep = serve_traffic(reqs, pol, caps, tps=args.tps,
                                batch_max=args.batch_max,
                                impl=args.backend or "auto")
            p50, p99 = rep.latency_quantiles()
            print(f"{pol:<18} {rep.throughput:>10.0f} {p50 * 1e3:>8.2f} "
                  f"{p99 * 1e3:>8.2f} {rep.replica_seconds:>12.1f} "
                  f"{rep.replicas_opened:>7d} {rep.shed:>6d}")
        return

    reqs = synth_requests(args.requests, seed=args.seed, rate=args.rate,
                          tps=args.tps)
    setting = args.setting
    if setting == "predicted":
        reqs = attach_predictions(reqs, args.sigma, seed=args.seed)
        setting = api.Setting.predicted()
    caps = ReplicaCapacity(args.slots, args.kv_tokens, args.prefill_budget)
    wl = api.serving_requests(reqs, caps=caps, tps=args.tps,
                              name=f"synth{args.requests}r{args.seed}")
    exp = api.Experiment(wl, policies=tuple(args.policies.split(",")),
                         settings=(setting,))
    res = exp.run(store=args.store or None, backend=args.backend,
                  progress=lambda m: print(f"# {m}", flush=True))
    print(f"{'policy':<18} {'setting':<22} {'replica_s':>12} "
          f"{'opened':>7} {'ratio':>8}")
    for r in res.rows():
        print(f"{r['policy']:<18} {r['setting']:<22} "
              f"{r['usage_time']:>12.1f} {r['n_bins_opened']:>7d} "
              f"{r['ratio']:>8.4f}")
    if args.baselines:
        from .serving.fleet import simulate_fleet
        for pol in ("round_robin", "pack_all"):
            b = simulate_fleet(reqs, pol, caps, args.tps)
            print(f"{pol:<18} {'(host baseline)':<22} "
                  f"{b['replica_seconds']:>12.1f} "
                  f"{b['replicas_opened']:>7d} {'-':>8}")


def _bench(argv: Optional[List[str]]) -> None:
    try:
        from benchmarks.run import main as bench_main
    except ImportError as e:
        raise SystemExit(
            "python -m repro bench needs the repo checkout on sys.path "
            f"(run from the repo root): {e}")
    bench_main(argv)


def main(argv: Optional[List[str]] = None) -> None:
    argv = sys.argv[1:] if argv is None else list(argv)
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description=__doc__.splitlines()[0],
        usage="python -m repro {sweep,serve,bench,obs,validate} ...")
    ap.add_argument("command",
                    choices=["sweep", "serve", "bench", "obs", "validate"])
    args, rest = ap.parse_known_args(argv)
    if args.command == "sweep":
        from .sweep.__main__ import main as sweep_main
        sweep_main(rest)
    elif args.command == "serve":
        _serve(rest)
    elif args.command == "obs":
        from .obs.cli import main as obs_main
        obs_main(rest)
    elif args.command == "validate":
        from .resilience.validate import main as validate_main
        validate_main(rest)
    else:
        _bench(rest)


if __name__ == "__main__":
    main()
