"""Replica inference engine: continuous batching over the model stack.

One ``ReplicaEngine`` = one model replica (a mesh slice in production; the
host devices in tests).  Fixed slot layout: the KV cache is (L, slots, Smax,
...); a request occupies one slot from admission to completion, prefill
writes its slot, and every engine tick decodes one token for all live slots
(idle slots run masked - the standard continuous-batching schedule).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.transformer import Runtime, forward, init_cache


@dataclasses.dataclass
class Sequence:
    rid: int
    tokens: List[int]
    prompt_len: int
    max_new: int
    done: bool = False


class ReplicaEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 max_len: int = 512, rt: Optional[Runtime] = None,
                 eos_id: int = 1):
        self.cfg = cfg
        self.params = params
        self.rt = rt or Runtime(mesh=None)
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = init_cache(cfg, slots, max_len)
        self.seqs: Dict[int, Sequence] = {}
        self.slot_of: Dict[int, int] = {}
        self.free = list(range(slots))
        self.pos = np.zeros(slots, np.int32)

        rtc = self.rt

        @jax.jit
        def _prefill(params, cache, tokens, slot, pos0):
            # single-sequence prefill written into one slot of the cache
            sub = jax.tree.map(
                lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                cache)
            logits, sub, _ = forward(params, cfg, rtc, tokens,
                                     mode="prefill", cache=sub, cache_pos=0)
            cache = jax.tree.map(
                lambda c, s: jax.lax.dynamic_update_slice_in_dim(
                    c, s.astype(c.dtype), slot, axis=1), cache, sub)
            return logits[:, -1], cache

        @jax.jit
        def _decode(params, cache, tokens, lens):
            # one token for every slot; per-slot positions via cache_pos=0
            # trick is not enough -> run with per-slot position vector
            logits, cache, _ = forward(params, cfg, rtc, tokens,
                                       mode="decode", cache=cache,
                                       cache_pos=lens)
            return logits[:, 0], cache

        self._prefill = _prefill
        self._decode = _decode

    # ------------------------------------------------------------------- api
    @property
    def n_active(self) -> int:
        return len(self.seqs)

    def can_admit(self) -> bool:
        return bool(self.free)

    def admit(self, rid: int, prompt: List[int], max_new: int) -> None:
        slot = self.free.pop(0)
        self.slot_of[rid] = slot
        self.seqs[rid] = Sequence(rid, list(prompt), len(prompt), max_new)
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        logits, self.cache = self._prefill(self.params, self.cache, toks,
                                           slot, 0)
        self.pos[slot] = len(prompt)
        nxt = int(jnp.argmax(logits[0]))
        self.seqs[rid].tokens.append(nxt)
        self.pos[slot] += 0   # next token written at decode step

    def step(self) -> List[int]:
        """Decode one token for every active sequence; returns finished rids."""
        if not self.seqs:
            return []
        tokens = np.zeros((self.slots, 1), np.int32)
        for rid, seq in self.seqs.items():
            tokens[self.slot_of[rid], 0] = seq.tokens[-1]
        lens = jnp.asarray(self.pos)   # per-slot depths
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens), lens)
        finished = []
        out = np.asarray(jnp.argmax(logits, -1))
        for rid, seq in list(self.seqs.items()):
            s = self.slot_of[rid]
            seq.tokens.append(int(out[s]))
            self.pos[s] += 1
            new = len(seq.tokens) - seq.prompt_len
            if new >= seq.max_new or int(out[s]) == self.eos_id or \
                    self.pos[s] >= self.max_len - 1:
                seq.done = True
                finished.append(rid)
                self.free.append(s)
                del self.seqs[rid]
                del self.slot_of[rid]
        return finished
