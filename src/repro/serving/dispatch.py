"""Batched admission: double-buffered block placement for the serving
front end.

``DVBPScheduler.place`` routes ONE request per kernel dispatch (the
event-blocked megakernel at T=1).  This module is the throughput path:
the ``AdmissionQueue`` accumulates pending requests and ``BlockDispatcher``
drains them as ONE ``fitscore_replay_block`` call - a block of T pending
arrivals (plus any departures that fired since the last dispatch) placed
in a single on-chip pass, with the fleet carry VMEM-resident and aliased
in -> out exactly as in the sweep scan.

**Live carry.**  The dispatcher owns a persistent single-lane packed
replay carry (``core.jaxsim.make_live_carry``).  Unlike the scheduler's
T=1 snapshot select (which disables the kernel's free-slot stage and lets
the host ``BinPool`` open bins), the live carry keeps real slot counts:
the kernel opens and closes slots itself, and the host keeps only a tiny
mirror mapping kernel slots to absolute replica ids for the fleet
accounting (replica-seconds, opened, peak).  Item rows come from a host
free list and are recycled only when the departure's block *resolves*:
the host reads the arrival's placement out of ``itemi[row]`` after the
fact, so the row must stay untouched while any block that references it
is still in flight.

**Double buffering.**  ``flush()`` enqueues the jitted block dispatch and
returns immediately (jax async dispatch): placement of batch k runs on
device while the host assembles batch k+1.  Up to ``depth`` blocks stay
in flight; ``_resolve()`` fences (``np.asarray`` readback ==
``block_until_ready``) only when the pipeline is full or results are
demanded.

**Fixed T geometries.**  Batches pad with ``PAD_KIND`` no-op events to a
small fixed set of block sizes (default 1/8/32/256), so the jit trace
count stays bounded; ``serving.jit_trace`` / ``serving.jit_cache_hit``
counters (off ``kernels.ops.dispatch_trace_count``) are the monitored
invariant, gated in CI like ``perf/sweep_retrace_6x2v12x1``.

**Degradation ladder.**  Every dispatch crosses the ``serving.select``
fault seam per rung: the configured block engine, then the kernel in
interpret mode (when the configured engine was the native kernel), then a
per-event T=1 loop - each step ticking a
``resilience.degrade_dispatch_<from>_<to>`` counter.  Overflow (the pool
ran out of slots) regrows the carry (``grow_live_carry``, doubling
``max_bins``) and replays the failed block plus everything newer from the
saved pre-block carries - the streams are kept host-side until their
block resolves.

**Equivalence.**  Batched decisions are provably equal to the sequential
oracle: events enter the stream in global time order (the front end
force-drains the admission queue before enqueuing a departure), the
blocked kernel replays them one at a time on-chip
(tests/test_replay_block.py: blocked == per-event), and the per-event
kernel decisions match the host algorithm zoo (tests/test_serving.py,
tests/test_dispatch.py) - so a T=256 batch lands every request exactly
where one-at-a-time placement would have.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from ..resilience import faults, guard
from .admission import AdmissionQueue
from .scheduler import ReplicaCapacity, Request

DEFAULT_GEOMETRIES = (1, 8, 32, 256)


def _constants():
    """Kernel-layout constants, imported lazily so ``repro.serving`` stays
    importable without jax initialized."""
    from ..kernels import fitscore as fk
    return fk


@dataclasses.dataclass
class _Event:
    kind: int            # ARRIVAL_KIND / DEPARTURE_KIND
    rid: int
    row: int             # item row in the carry
    t: float
    pdep: float          # absolute (predicted) departure time
    size: np.ndarray
    cat: int = 0         # family category (cbd/cbdt/rcp/la)
    large: int = 0       # rcp: size exceeds 1/2 in some dimension
    x: int = 0           # rcp: running distinct-category count


@dataclasses.dataclass
class _Inflight:
    """One dispatched, unresolved block: everything needed to read back
    placements - or to replay the block after overflow / a device fault."""
    carry_in: dict
    carry_out: dict
    events: List[_Event]
    streams: tuple       # (ev_i, ev_f, ev_size) numpy, padded to T
    T: int
    rung: int            # ladder rung that dispatched it
    migrate: bool = False  # consolidation drain block (MIGRATE events)


class BlockDispatcher:
    """Blocks of pending events -> one megakernel call on a live carry."""

    def __init__(self, policy: str, caps: ReplicaCapacity = ReplicaCapacity(),
                 tps: float = 50.0, d: int = 3, max_bins: int = 64,
                 max_items: int = 1024,
                 geometries: Sequence[int] = DEFAULT_GEOMETRIES,
                 impl: str = "auto", depth: int = 2):
        from ..core.jaxsim import (_KERNEL_FAMILY, make_live_carry,
                                   policy_spec)
        self.policy = policy
        self.caps = caps
        self.tps = tps
        self.d = d
        self.max_bins = max_bins
        self.impl = impl
        self.depth = depth
        self.geometries = tuple(sorted(set(int(g) for g in geometries)))
        assert self.geometries and self.geometries[0] >= 1
        spec = policy_spec(policy)
        self._spec = spec
        self.family = _KERNEL_FAMILY[spec.family]
        assert not spec.adaptive_alpha, \
            f"{policy!r} (PPE guess-and-double) scores real durations at " \
            "departure - not observable on a live stream; use rcp/" \
            "rcp_modified"
        self._needs_pred = self.family in ("cbd", "rcp", "la")
        self._carry = make_live_carry(policy, max_bins, d, max_items)
        self._n_items = max_items
        self._free = list(range(max_items - 1, -1, -1))   # pop() -> row 0..
        self._pending: List[_Event] = []
        self._inflight: List[_Inflight] = []
        self._rid_arrival: Dict[int, _Event] = {}
        self._rcp_seen: set = set()
        # host mirror: kernel slot -> fleet accounting
        self._slot_count = np.zeros(self._carry["loads"].shape[1], np.int64)
        self._slot_replica = np.full(self._slot_count.shape, -1, np.int64)
        self._slot_opened_at = np.zeros(self._slot_count.shape)
        self._next_replica = 0
        self._rid_slot: Dict[int, int] = {}
        self._rid_wall: Dict[int, float] = {}
        self.placements: Dict[int, int] = {}
        self.latencies: List[float] = []
        self.replica_seconds = 0.0
        self.replicas_opened = 0
        self.peak_replicas = 0
        self._open_now = 0

    # --------------------------------------------------------- event intake
    def _categorize(self, pdur: Optional[float], t: float,
                    size: np.ndarray) -> Tuple[int, int, int]:
        """Per-arrival family constants from the shared host categorization
        functions - the same ones ``DVBPScheduler`` and the batched scan's
        ``_category_setup`` use, so all paths agree on class boundaries."""
        from ..core.algorithms.departure import departure_window
        from ..core.algorithms.duration import duration_class
        from ..core.algorithms.learned import geo_class, la_class
        fk = _constants()
        if self._needs_pred:
            assert pdur is not None, \
                f"{self.policy!r} needs predicted decode lengths"
        if self._spec.family == "cbd":
            return int(duration_class(pdur, self._spec.beta)), 0, 0
        if self._spec.family == "cbdt":
            return int(departure_window(t + pdur, self._spec.rho)), 0, 0
        if self.family == "rcp":
            cat = int(np.clip(geo_class(max(pdur, 0.0)), 0, fk.KCAT - 1))
            if cat not in self._rcp_seen:
                self._rcp_seen.add(cat)
            return cat, int(float(size.max()) > 0.5), len(self._rcp_seen)
        if self.family == "la":
            return int(la_class(pdur, self._spec.la_mode)), 0, 0
        return 0, 0, 0

    def enqueue_arrival(self, req: Request, now: float,
                        wall_t: Optional[float] = None) -> None:
        fk = _constants()
        size = req.size(self.caps)
        pdur = None if req.predicted_decode_len is None else \
            req.predicted_decode_len / self.tps
        pdep = now if pdur is None else now + pdur
        cat, large, x = self._categorize(pdur, now, size)
        if not self._free:
            self._grow_items()
        row = self._free.pop()
        self._rid_wall[req.rid] = time.perf_counter() if wall_t is None \
            else wall_t
        ev = _Event(fk.ARRIVAL_KIND, req.rid, row, now, pdep, size, cat,
                    large, x)
        self._rid_arrival[req.rid] = ev
        self._pending.append(ev)
        if len(self._pending) >= self.geometries[-1]:
            self.flush()

    def enqueue_departure(self, rid: int, now: float) -> None:
        """The request finished: append its departure event.  The arrival
        must already be enqueued (the front end force-drains admission
        before finishing, keeping the event stream in global time order).
        """
        fk = _constants()
        arr = self._rid_arrival.pop(rid, None)
        if arr is None:
            raise KeyError(
                f"finish({rid}) before its arrival was dispatched; the "
                "front end must drain admission first")
        self._pending.append(dataclasses.replace(
            arr, kind=fk.DEPARTURE_KIND, t=now,
            x=len(self._rcp_seen) if self.family == "rcp" else 0))
        # the row is NOT freed here: ``_resolve`` reads the arrival's
        # placement out of ``itemi[row]`` after the block retires, so the
        # row must stay untouched until the departure's block resolves -
        # freeing it now would let a newer arrival overwrite the cell
        # while the older block is still in flight
        if len(self._pending) >= self.geometries[-1]:
            self.flush()

    def _grow_items(self) -> None:
        from ..core.jaxsim import grow_live_items
        self.sync()   # simplest safe point: no in-flight carries to patch
        new = 2 * self._n_items
        self._carry = grow_live_items(self._carry, new)
        self._free = list(range(new - 1, self._n_items - 1, -1)) + self._free
        self._n_items = new

    # ------------------------------------------------------------- dispatch
    def _geometry(self, m: int) -> int:
        for g in self.geometries:
            if m <= g:
                return g
        return self.geometries[-1]

    def _streams(self, events: List[_Event], T: int) -> tuple:
        """Pack a block of events into the kernel's padded numpy streams
        (``PAD_KIND`` filler to the fixed geometry)."""
        fk = _constants()
        Np_d = self._carry["loads"].shape[2]
        kind = np.full((1, T), fk.PAD_KIND, np.int32)
        item = np.zeros((1, T), np.int32)
        t = np.zeros((1, T), np.float32)
        pdep = np.zeros((1, T), np.float32)
        size = np.zeros((1, T, Np_d), np.float32)
        cat = np.zeros((1, T), np.int32)
        large = np.zeros((1, T), np.int32)
        x = np.zeros((1, T), np.int32)
        for j, ev in enumerate(events):
            kind[0, j] = ev.kind
            item[0, j] = ev.row
            t[0, j] = ev.t
            pdep[0, j] = ev.pdep
            size[0, j, :self.d] = ev.size
            cat[0, j] = ev.cat
            large[0, j] = ev.large
            x[0, j] = ev.x
        ev_i = {"kind": kind, "item": item}
        ev_f = {"t": t, "pdep": pdep}
        if self.family in ("cbd", "la"):
            ev_i["cat"] = cat
        elif self.family == "rcp":
            ev_i["cat"] = cat
            ev_i["large"] = large
            ev_i["x"] = x
            ev_f["p2err"] = np.ones((1, T), np.float32)
        elif self.family == "adaptive":
            # open-ended streams never observe real durations, so the
            # departure error stays at 1.0 - exactly the host
            # AdaptiveSwitch's behavior on serving request ids
            ev_f["errmax"] = np.ones((1, T), np.float32)
        return ev_i, ev_f, size

    def _rungs(self) -> List[Tuple[str, str]]:
        from ..kernels.ops import resolved_select_impl
        resolved = resolved_select_impl(self.impl, block=True)
        rungs = [("block", self.impl)]
        if resolved == "pallas":
            rungs.append(("block_interpret", "pallas_interpret"))
        rungs.append(("events", "pallas_interpret"))
        return rungs

    def _dispatch(self, carry, streams, T: int, start_rung: int = 0,
                  migrate: bool = False) -> Tuple[dict, int]:
        """Run the degradation ladder from ``start_rung``: each rung
        crosses the ``serving.select`` fault seam once, degradable errors
        step down with a ``resilience.degrade_dispatch_*`` counter.
        ``migrate=True`` compiles the MIGRATE branch in (consolidation
        drain blocks only, so plain traffic keeps its exact graph)."""
        import jax.numpy as jnp

        from ..kernels import ops
        ev_i, ev_f, ev_size = streams
        dmask = np.zeros((1, self._carry["loads"].shape[2]), np.float32)
        dmask[0, :self.d] = 1.0
        rungs = self._rungs()
        for i in range(start_rung, len(rungs)):
            label, impl = rungs[i]
            try:
                faults.fire("serving.select")
                before = ops.dispatch_trace_count()
                if label == "events":
                    # per-event fallback: the same kernel, one event per
                    # call - slower, simpler, synchronous in spirit
                    out = carry
                    for j in range(T):
                        evi1 = {k: v[:, j:j + 1] for k, v in ev_i.items()}
                        evf1 = {k: v[:, j:j + 1] for k, v in ev_f.items()}
                        out = ops.fitscore_replay_dispatch(
                            out, evi1, evf1, ev_size[:, j:j + 1],
                            jnp.asarray(dmask), policy=self.policy,
                            n=self.max_bins, d=self.d, impl=impl,
                            migrate=migrate)
                else:
                    out = ops.fitscore_replay_dispatch(
                        carry, ev_i, ev_f, ev_size, jnp.asarray(dmask),
                        policy=self.policy, n=self.max_bins, d=self.d,
                        impl=impl, migrate=migrate)
                retraced = ops.dispatch_trace_count() - before
                if retraced:
                    obs.counter_add("serving.jit_trace", retraced)
                else:
                    obs.counter_add("serving.jit_cache_hit")
                return out, i
            except Exception as e:
                if not guard.is_degradable(e) or i + 1 >= len(rungs):
                    raise
                nxt = rungs[i + 1][0]
                obs.counter_add(
                    f"resilience.degrade_dispatch_{label}_{nxt}")
                obs.instant("resilience.degrade_dispatch", frm=label,
                            to=nxt, error=str(e)[:200])
        raise AssertionError("unreachable: last rung re-raises")

    def flush(self) -> None:
        """Dispatch the pending events as one (or, past the largest
        geometry, several) padded block(s); returns without fencing -
        the block executes while the host assembles the next batch."""
        while self._pending:
            chunk = self._pending[:self.geometries[-1]]
            del self._pending[:len(chunk)]
            T = self._geometry(len(chunk))
            obs.counter_hist("serving.dispatch_batch_size", len(chunk))
            streams = self._streams(chunk, T)
            with obs.span("serving.dispatch", T=T, events=len(chunk),
                          policy=self.policy):
                out, rung = self._dispatch(self._carry, streams, T)
            self._inflight.append(_Inflight(self._carry, out, chunk,
                                            streams, T, rung))
            self._carry = out
            while len(self._inflight) > self.depth:
                self._resolve()

    # -------------------------------------------------------------- resolve
    def _readback(self, rec: _Inflight) -> np.ndarray:
        """Fence on the block's carry; returns per-item placements.
        Raises on device failure (caught by ``_resolve`` for replay)."""
        fk = _constants()
        itemi = np.asarray(rec.carry_out["itemi"][0, :, fk.ITEMI_PLACE])
        si = np.asarray(rec.carry_out["si"][0])
        if si[fk.SI_OVERFLOW] > 0:
            from ..core.jaxsim import CapacityError
            raise CapacityError(
                f"live carry overflowed {self.max_bins} slots",
                policy=self.policy, max_bins=self.max_bins)
        return itemi

    def _resolve(self) -> None:
        """Retire the oldest in-flight block: read back its placements and
        update the host replica mirror.  Overflow and degradable device
        errors replay the block (grown carry for overflow) plus every
        newer in-flight block from the saved pre-block carries."""
        from ..core.jaxsim import (CapacityError, MAX_BINS_CAP,
                                   grow_live_carry, grow_max_bins)
        fk = _constants()
        rec = self._inflight[0]
        while True:
            try:
                with obs.span("serving.resolve", T=rec.T,
                              events=len(rec.events)):
                    itemi = self._readback(rec)
                break
            except CapacityError:
                if self.max_bins >= MAX_BINS_CAP:
                    raise
                self.max_bins = grow_max_bins(self.max_bins)
                obs.counter_add("serving.carry_regrow")
                self._replay_from(0, grow=True)
                rec = self._inflight[0]
            except Exception as e:
                if not guard.is_degradable(e) or \
                        rec.rung + 1 >= len(self._rungs()):
                    raise
                obs.counter_add("resilience.degrade_dispatch_resolve")
                self._replay_from(0, grow=False,
                                  start_rung=rec.rung + 1)
                rec = self._inflight[0]
        self._inflight.pop(0)
        now_wall = time.perf_counter()
        for ev in rec.events:
            if ev.kind == fk.ARRIVAL_KIND:
                slot = int(itemi[ev.row])
                assert slot >= 0, "arrival unplaced without overflow"
                self._mirror_place(slot, ev)
                t0 = self._rid_wall.pop(ev.rid, None)
                if t0 is not None:
                    self.latencies.append(now_wall - t0)
            elif ev.kind == fk.MIGRATE_KIND:
                # departure half: leave the source slot (closing it if
                # the migrant was the last occupant) ...
                self._mirror_depart(self._rid_slot.pop(ev.rid), ev.t)
                # ... arrival half: land on the kernel's re-place (the
                # source slot was excluded from its select)
                slot = int(itemi[ev.row])
                assert slot >= 0, "migrant unplaced without overflow"
                self._mirror_place(slot, ev)
            else:
                self._mirror_depart(self._rid_slot.pop(ev.rid), ev.t)
                self._free.append(ev.row)

    def _mirror_place(self, slot: int, ev: _Event) -> None:
        if self._slot_count[slot] == 0:
            self._slot_replica[slot] = self._next_replica
            self._next_replica += 1
            self._slot_opened_at[slot] = ev.t
            self.replicas_opened += 1
            self._open_now += 1
            self.peak_replicas = max(self.peak_replicas, self._open_now)
        self._slot_count[slot] += 1
        self._rid_slot[ev.rid] = slot
        self.placements[ev.rid] = int(self._slot_replica[slot])

    def _mirror_depart(self, slot: int, t: float) -> None:
        self._slot_count[slot] -= 1
        if self._slot_count[slot] == 0:
            self.replica_seconds += t - self._slot_opened_at[slot]
            self._open_now -= 1

    def _replay_from(self, i: int, grow: bool, start_rung: int = 0) -> None:
        """Re-dispatch in-flight blocks ``i..`` from block ``i``'s saved
        pre-block carry - after growing the pool (overflow) or stepping
        down the ladder (device fault)."""
        from ..core.jaxsim import grow_live_carry
        carry = self._inflight[i].carry_in
        if grow:
            carry = grow_live_carry(carry, self.max_bins, self.d)
            # the mirror arrays track slots; grow them alongside
            Np = carry["loads"].shape[1]
            if Np > self._slot_count.shape[0]:
                pad = Np - self._slot_count.shape[0]
                self._slot_count = np.concatenate(
                    [self._slot_count, np.zeros(pad, np.int64)])
                self._slot_replica = np.concatenate(
                    [self._slot_replica, np.full(pad, -1, np.int64)])
                self._slot_opened_at = np.concatenate(
                    [self._slot_opened_at, np.zeros(pad)])
        # the event streams are geometry-stable under slot growth (dpad
        # depends only on d), so saved streams re-dispatch as-is
        for k in range(i, len(self._inflight)):
            rec = self._inflight[k]
            out, rung = self._dispatch(carry, rec.streams, rec.T,
                                       start_rung if k == i else 0,
                                       migrate=rec.migrate)
            rec.carry_in, rec.carry_out, rec.rung = carry, out, rung
            carry = out
        self._carry = carry

    def sync(self) -> None:
        """Flush pending events and fence every in-flight block."""
        self.flush()
        while self._inflight:
            self._resolve()

    # -------------------------------------------------------- consolidation
    def consolidate(self, now: float, spec) -> Dict[str, int]:
        """Opt-in consolidation drain pass over the live fleet.

        Quiesces the pipeline (``sync``), runs the SAME planner as the
        batched driver and the host oracle (``consolidate.plan_migrations``)
        on the live carry's pool snapshot, and dispatches the plan as
        MIGRATE blocks (``migrate=True`` compiles the branch in only
        here - plain traffic keeps its exact graph).  Each migrant leaves
        its source replica (closing it when it was the last occupant) and
        is re-placed by the policy's own select with the source slot
        excluded.  Resolves before returning, so ``placements`` /
        ``replica_seconds`` reflect the drain; returns the churn stats
        (``migrations`` / ``bins_closed`` / ``budget_exhausted``)."""
        from ..consolidate import ConsolidationSpec, plan_migrations
        fk = _constants()
        if isinstance(spec, str):
            spec = ConsolidationSpec.parse(spec)
        assert spec.enabled, "consolidate() needs an active spec"
        self.sync()   # plan on a quiesced carry: nothing in flight
        sloti = np.asarray(self._carry["sloti"][0])
        loads = np.asarray(
            self._carry["loads"][0, :, :self.d]).astype(np.float64)
        bin_items: Dict[int, List[int]] = {}
        row_ev: Dict[int, _Event] = {}
        sizes = np.zeros((self._n_items, self.d))
        for rid in sorted(self._rid_slot):
            arr = self._rid_arrival.get(rid)
            assert arr is not None, \
                "live rid without a stored arrival after sync()"
            bin_items.setdefault(
                int(self._rid_slot[rid]), []).append(arr.row)
            row_ev[arr.row] = arr
            sizes[arr.row] = arr.size
        plan = plan_migrations(
            loads, sloti[:, fk.SLOTI_COUNTS],
            sloti[:, fk.SLOTI_ALIVE] > 0, sloti[:, fk.SLOTI_OSEQ],
            bin_items, sizes, threshold=spec.threshold,
            budget=spec.budget)
        stats = {"migrations": len(plan.items),
                 "bins_closed": plan.bins_closed,
                 "budget_exhausted": plan.budget_exhausted}
        obs.counter_add("consolidate.migrations", len(plan.items))
        obs.counter_add("consolidate.bins_closed", plan.bins_closed)
        obs.counter_add("consolidate.budget_exhausted",
                        plan.budget_exhausted)
        if not plan.items:
            return stats
        events = [dataclasses.replace(
            row_ev[row], kind=fk.MIGRATE_KIND, t=now,
            x=len(self._rcp_seen) if self.family == "rcp" else 0)
            for row in plan.items]
        with obs.span("serving.consolidate", policy=self.policy,
                      migrations=len(events),
                      bins_closed=plan.bins_closed):
            while events:
                chunk = events[:self.geometries[-1]]
                del events[:len(chunk)]
                T = self._geometry(len(chunk))
                streams = self._streams(chunk, T)
                out, rung = self._dispatch(self._carry, streams, T,
                                           migrate=True)
                self._inflight.append(_Inflight(
                    self._carry, out, chunk, streams, T, rung,
                    migrate=True))
                self._carry = out
            while self._inflight:
                self._resolve()
        return stats


class BatchedFrontEnd:
    """Admission -> batched dispatch: the online serving pipeline.

    ``submit`` feeds the bounded ``AdmissionQueue``; ``tick`` drains up to
    ``batch_max`` survivors into the dispatcher as one block; ``finish``
    force-drains admission first (keeping the event stream in global time
    order - the equivalence precondition) and then enqueues the departure.
    ``sync`` fences the pipeline; decisions land in ``placements`` (rid ->
    replica id in opening order, directly comparable to
    ``DVBPScheduler.place``'s absolute bin indices)."""

    def __init__(self, policy: str,
                 caps: ReplicaCapacity = ReplicaCapacity(),
                 tps: float = 50.0, max_pending: int = 4096,
                 deadline: float = 1e9, batch_max: int = 256,
                 geometries: Sequence[int] = DEFAULT_GEOMETRIES,
                 impl: str = "auto", max_bins: int = 64,
                 max_items: int = 1024, depth: int = 2):
        self.dispatcher = BlockDispatcher(
            policy, caps, tps, max_bins=max_bins, max_items=max_items,
            geometries=geometries, impl=impl, depth=depth)
        self.queue = AdmissionQueue(None, max_pending=max_pending,
                                    deadline=deadline, batch_max=batch_max)
        self.batch_max = batch_max
        # admission wall clock per rid: the p50/p99 admission-to-placement
        # latency starts here, not at dispatcher enqueue
        self._wall: Dict[int, float] = {}
        # arrivals handed to the dispatcher since its last flush: the
        # ``finish`` path drains admission continuously (keeping the queue
        # short), so the batch trigger counts hand-overs, not queue depth
        self._since_flush = 0

    def submit(self, req: Request, now: float) -> bool:
        wall = time.perf_counter()
        ok = self.queue.submit(req, now)
        if ok:
            self._wall[req.rid] = wall
            if len(self.queue) >= self.batch_max:
                self.tick(now)
        return ok

    def _hand_over(self, req: Request, t_in: float) -> None:
        # the arrival event carries the request's own (submit) time, not
        # the drain time - exactly what the sequential oracle sees when it
        # places each request at its arrival, so batched decisions stay
        # comparable decision-for-decision
        self.dispatcher.enqueue_arrival(req, t_in,
                                        wall_t=self._wall.pop(req.rid, None))
        self.queue.stats.placed += 1
        self._since_flush += 1

    def tick(self, now: float) -> int:
        """Drain one admission batch into the dispatcher; returns how many
        requests were dispatched."""
        obs.counter_hist("serving.queue_depth", len(self.queue))
        batch = self.queue.take(now)
        for req, t_in in batch:
            self._hand_over(req, t_in)
        if batch:
            self.dispatcher.flush()
            self._since_flush = 0
        return len(batch)

    def finish(self, rid: int, now: float) -> None:
        """The request's decode completed.  Every queued arrival precedes
        this departure in sim time, so drain them all first."""
        while len(self.queue):
            for req, t_in in self.queue.take(now, limit=len(self.queue)):
                self._hand_over(req, t_in)
        self.dispatcher.enqueue_departure(rid, now)
        if self._since_flush >= self.batch_max:
            self.dispatcher.flush()
            self._since_flush = 0

    def sync(self) -> None:
        self.dispatcher.sync()

    def consolidate(self, now: float, spec) -> Dict[str, int]:
        """Run one consolidation drain pass on the dispatcher (see
        ``BlockDispatcher.consolidate``)."""
        return self.dispatcher.consolidate(now, spec)

    @property
    def placements(self) -> Dict[int, int]:
        return self.dispatcher.placements

    @property
    def latencies(self) -> List[float]:
        return self.dispatcher.latencies


@dataclasses.dataclass
class ServeReport:
    policy: str
    n_requests: int
    placed: int
    shed: int
    replica_seconds: float
    replicas_opened: int
    peak_replicas: int
    wall_seconds: float
    latencies: List[float]
    placements: Dict[int, int]
    metrics: Dict[str, float]

    @property
    def throughput(self) -> float:
        return self.placed / self.wall_seconds if self.wall_seconds else 0.0

    def latency_quantiles(self, qs=(0.5, 0.99)) -> List[float]:
        lat = np.sort(np.asarray(self.latencies))
        if lat.size == 0:
            return [0.0 for _ in qs]
        return [float(np.quantile(lat, q)) for q in qs]


def serve_traffic(reqs: List[Request], policy: str,
                  caps: ReplicaCapacity = ReplicaCapacity(),
                  tps: float = 50.0, batch_max: int = 256,
                  geometries: Sequence[int] = DEFAULT_GEOMETRIES,
                  impl: str = "auto", max_bins: int = 64,
                  max_items: int = 1024, deadline: float = 1e9,
                  depth: int = 2) -> ServeReport:
    """Drive the batched front end through a request trace, event-driven
    exactly like the sequential oracle (``fleet.simulate_fleet``):
    departures with earlier sim time fire before the next arrival, so the
    dispatcher's event stream - and therefore every placement - matches
    one-at-a-time replay decision-for-decision."""
    counters0 = obs.counters()
    fe = BatchedFrontEnd(policy, caps, tps, batch_max=batch_max,
                         geometries=geometries, impl=impl,
                         max_bins=max_bins, max_items=max_items,
                         deadline=deadline, depth=depth)
    t0 = time.perf_counter()
    heap: List[Tuple[float, int]] = []
    for r in sorted(reqs, key=lambda x: x.arrival):
        while heap and heap[0][0] <= r.arrival:
            ft, rid = heapq.heappop(heap)
            fe.finish(rid, ft)
        if fe.submit(r, r.arrival):
            heapq.heappush(heap, (r.arrival + r.decode_len / tps, r.rid))
    while heap:
        ft, rid = heapq.heappop(heap)
        fe.finish(rid, ft)
    fe.sync()
    wall = time.perf_counter() - t0
    dp = fe.dispatcher
    return ServeReport(
        policy=policy, n_requests=len(reqs),
        placed=len(dp.placements), shed=fe.queue.stats.shed,
        replica_seconds=dp.replica_seconds,
        replicas_opened=dp.replicas_opened,
        peak_replicas=dp.peak_replicas, wall_seconds=wall,
        latencies=list(dp.latencies), placements=dict(dp.placements),
        metrics=obs.counter_deltas(counters0))
