"""DVBP request->replica placement: the paper's technique as the serving
control plane.

Replicas (mesh slices running a model) are *bins* with capacity vector
<batch slots, KV pages, prefill-FLOP budget>; requests are *items* whose
duration is their decode length - unknown (non-clairvoyant), known
(clairvoyant replay) or predicted (learning-augmented).  The autoscaler
objective is replica-occupancy seconds == the paper's accumulated bin usage
time; a replica with no active requests is released ("bin closed").

The scheduler drives the same BinPool + algorithm zoo as the offline engine,
so every policy (First Fit ... Prioritized NRT ... modified PPE) is available
verbatim.  For the score-based 8-policy family (``core.jaxsim.POLICIES``)
the placement decision can also run on-device via the fused
``kernels.ops.fitscore_select`` kernel (``select_backend="auto"`` uses the
Pallas kernel on TPU and its jnp twin elsewhere; "host" keeps the numpy
algorithm zoo).  The category-structured CBD/CBDT policies run on-device
too: the request's duration/departure class is computed host-side with the
shared categorization functions and handed to the kernel as a *category
mask* over the replica pool (tag == class), so their class-restricted First
Fit is the same fused select.  Both paths implement the same
(score, opening-order) selection rule, so they agree decision-for-decision
on fp32-exact sizes (tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .. import obs
from ..core.bins import BinPool
from ..core.types import Arrival
from ..core.algorithms import get_algorithm
from ..core.algorithms.departure import departure_window
from ..core.algorithms.duration import duration_class
from ..resilience import faults, guard

# scheduler policy (+ kwargs) -> jaxsim/kernel policy name
_DEVICE_POLICIES = ("first_fit", "best_fit", "mru", "greedy",
                    "nrt_standard", "nrt_prioritized")
# category-structured policies with an on-device masked select
_DEVICE_CATEGORY_POLICIES = ("cbd", "cbdt")

# Demand-vector memo: requests quantize to a small set of (prompt, decode,
# caps) keys (prompt/decode lengths are integers, capacities are fixed per
# fleet), so the hot admission path - every submit/place and the
# serving_requests workload adapter - mostly re-derives vectors it already
# built.  Same shape as the sweep's content-digest event-sequence LRU
# (``sweep.batching._EVSEQ_CACHE``): bounded OrderedDict with hit/miss
# counters (``serving.size_memo_hit`` / ``serving.size_memo_miss``) as the
# single stats site.  Entries are read-only so a cached vector can be
# handed out by reference.
_SIZE_CACHE: "OrderedDict[Tuple, np.ndarray]" = OrderedDict()
_SIZE_CACHE_MAX = 65536


def _demand_vector(prompt_len: int, decode_len: int,
                   caps: "ReplicaCapacity") -> np.ndarray:
    key = (prompt_len, decode_len, caps.slots, caps.kv_tokens,
           caps.prefill_budget)
    hit = _SIZE_CACHE.get(key)
    if hit is not None:
        _SIZE_CACHE.move_to_end(key)
        obs.counter_add("serving.size_memo_hit")
        return hit
    obs.counter_add("serving.size_memo_miss")
    kv = (prompt_len + decode_len) / caps.kv_tokens
    size = np.array([1.0 / caps.slots, min(kv, 1.0),
                     prompt_len / caps.prefill_budget])
    size.flags.writeable = False
    _SIZE_CACHE[key] = size
    while len(_SIZE_CACHE) > _SIZE_CACHE_MAX:
        _SIZE_CACHE.popitem(last=False)
    return size


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    decode_len: int                    # ground truth (revealed at finish)
    predicted_decode_len: Optional[int] = None

    def size(self, caps: "ReplicaCapacity") -> np.ndarray:
        return _demand_vector(self.prompt_len, self.decode_len, caps)


@dataclasses.dataclass(frozen=True)
class ReplicaCapacity:
    slots: int = 8                 # concurrent sequences per replica
    kv_tokens: int = 65536         # KV-cache token pool
    prefill_budget: float = 262144  # prompt tokens/s headroom


@dataclasses.dataclass
class PlacementStats:
    replica_seconds: float = 0.0
    replicas_opened: int = 0
    peak_replicas: int = 0
    rejected: int = 0


class DVBPScheduler:
    """Online request placement over an elastic replica fleet."""

    def __init__(self, policy="nrt_prioritized",
                 caps: ReplicaCapacity = ReplicaCapacity(),
                 policy_kwargs: Optional[Dict] = None,
                 tokens_per_second: float = 50.0,
                 select_backend: str = "host",
                 select_block: bool = False):
        if not isinstance(policy, str):   # an api.Policy object
            name, kw = policy.registry_args()
            policy, policy_kwargs = name, {**kw, **(policy_kwargs or {})}
        self.caps = caps
        self.tps = tokens_per_second
        self.pool = BinPool(d=3)
        self.alg = get_algorithm(policy, **(policy_kwargs or {}))
        self.select_backend = select_backend
        # route the on-device select through the event-blocked replay
        # megakernel at T=1 (fitscore_replay_block) instead of the
        # per-step fused select - same decisions, one kernel for both the
        # sweep hot loop and serving
        self.select_block = select_block
        assert not (select_block and select_backend == "host"), \
            "select_block routes the ON-DEVICE select through the replay " \
            "megakernel; pick select_backend='auto'/'pallas'/" \
            "'pallas_interpret' (the host path would silently ignore it)"
        self._policy = policy
        self._category_policy = policy in _DEVICE_CATEGORY_POLICIES
        if policy == "best_fit":
            norm = (policy_kwargs or {}).get("norm", "linf")
            self._device_policy = f"best_fit_{norm}"
        elif self._category_policy:
            self._device_policy = "first_fit"   # First Fit within the class
        else:
            self._device_policy = policy
        if select_backend != "host":
            assert policy in _DEVICE_POLICIES + _DEVICE_CATEGORY_POLICIES, \
                f"{policy!r} has no on-device select (host only)"

        class _Inst:   # minimal instance facade for algorithm.bind
            durations = np.array([1.0])
            n_items = 0
            sizes = np.zeros((0, 3))
            arrivals = np.zeros(0)
            departures = np.zeros(0)
        self.alg.bind(self.pool, _Inst())
        self.stats = PlacementStats()
        self.last_select_backend: Optional[str] = None  # set by place()
        self._open_at: Dict[int, float] = {}
        self._active: Dict[int, tuple] = {}   # rid -> (bin idx, size)
        self.placements: Dict[int, int] = {}

    # ------------------------------------------------------ device fast path
    def _request_category(self, pdep: Optional[float],
                          now: float) -> Optional[int]:
        """The arriving request's CBD/CBDT class (None for score policies).
        Uses the same shared categorization functions as the host classes,
        so both paths agree on the class boundary exactly."""
        if not self._category_policy:
            return None
        assert pdep is not None, \
            f"{self.alg.name} needs predicted decode lengths"
        if self._policy == "cbd":
            return int(duration_class(pdep - now, self.alg.beta))
        return int(departure_window(pdep, self.alg.rho))

    def _select_device(self, size: np.ndarray, pdep: Optional[float],
                       now: float, cat: Optional[int],
                       impl: Optional[str] = None,
                       block: Optional[bool] = None) -> int:
        """Fused on-device placement decision over the whole pool state.

        The pool uses absolute, never-reused bin indices, so the kernel's
        free-slot stage is disabled (counts=1: ``no_free`` always) and only
        the best-feasible result is consulted; -1 means "open a new bin",
        exactly the host algorithms' contract.  ``cat`` (CBD/CBDT) turns
        into the kernel's category mask: only same-class replicas are
        eligible.  ``impl`` / ``block`` override the configured engine -
        how ``_select_guarded`` degrades a failing plan."""
        import jax.numpy as jnp

        from ..kernels import ops
        impl = self.select_backend if impl is None else impl
        block = self.select_block if block is None else block
        p = self.pool
        if block:
            # the event-blocked replay megakernel at T=1: one arrival
            # event replayed on a single-lane snapshot of the pool state
            slot, found = ops.fitscore_select_block(
                jnp.asarray(p.used, jnp.float32),
                jnp.asarray(p.alive),
                jnp.asarray(p.open_seq, jnp.int32),
                jnp.asarray(p.access_seq, jnp.int32),
                jnp.asarray(np.maximum(p.indicated_close, -1e30),
                            jnp.float32),
                jnp.asarray(size, jnp.float32),
                float(pdep) if pdep is not None else float(now), float(now),
                cat=cat, tags=None if cat is None else jnp.asarray(
                    p.tag, jnp.int32),
                policy=self._device_policy, n=p._cap, d=3,
                impl=impl)
            return int(slot) if bool(found) else -1
        cmask = None if cat is None else \
            jnp.asarray(p.tag == cat, jnp.int32)
        slot, found, _no_free = ops.fitscore_select(
            jnp.asarray(p.used, jnp.float32),
            jnp.ones(p._cap, jnp.int32),
            jnp.asarray(p.alive),
            jnp.asarray(p.open_seq, jnp.int32),
            jnp.asarray(p.access_seq, jnp.int32),
            jnp.asarray(np.maximum(p.indicated_close, -1e30), jnp.float32),
            jnp.asarray(size, jnp.float32),
            float(pdep) if pdep is not None else float(now), float(now),
            cmask=cmask, policy=self._device_policy,
            impl=impl)
        return int(slot) if bool(found) else -1

    def _select_guarded(self, size: np.ndarray, pdep: Optional[float],
                        now: float, arr: Arrival):
        """The placement decision behind the serving degradation ladder.

        Device rungs: the configured plan (megakernel at T=1 when
        ``select_block``), then the per-request kernel select, then the
        jnp reference select - and when every device rung fails, the host
        numpy algorithm zoo places the request (the scheduler NEVER stops
        placing; it just gets slower).  A rung failing with a device error
        (``guard.is_degradable``) steps down with a
        ``resilience.degrade_select_<from>_<to>`` counter; anything else
        (a bug) propagates.  Returns ``(idx, tag)`` where ``tag`` is the
        engine that actually decided."""
        from ..kernels.ops import resolved_select_impl
        if self.select_backend == "host":
            return self.alg.select_bin(arr), "host"
        cat = self._request_category(pdep, now)
        rungs = []
        if self.select_block:
            rungs.append(("block", self.select_backend, True))
        rungs.append(("kernel", self.select_backend, False))
        if resolved_select_impl(self.select_backend, block=False) != "jnp":
            rungs.append(("jnp", "jnp", False))
        for i, (label, impl, block) in enumerate(rungs):
            try:
                faults.fire("serving.select")
                idx = self._select_device(size, pdep, now, cat,
                                          impl=impl, block=block)
            except Exception as e:
                if not guard.is_degradable(e):
                    raise
                nxt = rungs[i + 1][0] if i + 1 < len(rungs) else "host"
                obs.counter_add(f"resilience.degrade_select_{label}_{nxt}")
                obs.instant("resilience.degrade_select", frm=label, to=nxt,
                            error=str(e)[:200])
                continue
            if cat is not None:
                self.alg._cat = cat   # keep the host class's tag
                #                       bookkeeping (on_placed) in sync
            return idx, resolved_select_impl(impl, block=block)
        # every device rung failed: the host algorithm zoo still places
        return self.alg.select_bin(arr), "host"

    # ------------------------------------------------------------------- api
    def place(self, req: Request, now: float) -> int:
        """Place a request; returns the replica (bin) index."""
        size = req.size(self.caps)
        pdur = None
        if req.predicted_decode_len is not None:
            pdur = req.predicted_decode_len / self.tps
        pdep = None if pdur is None else now + pdur
        arr = Arrival(req.rid, size, now, pdep)
        # span backend tag: the engine that ACTUALLY decided - "host" for
        # the numpy algorithm zoo, else the kernel impl that served the
        # select after any resilience degradation ("auto" silently falls
        # back to jnp off-TPU; the tag and the serving.select_<backend>
        # counter make both visible)
        with obs.span("serving.select", policy=self._policy,
                      rid=req.rid) as sp:
            idx, tag = self._select_guarded(size, pdep, now, arr)
            sp.set(backend=tag)
        self.last_select_backend = tag
        obs.counter_add(f"serving.select_{tag}")
        opened = idx < 0
        if opened:
            idx = self.pool.open_bin(now)
            self._open_at[idx] = now
            self.stats.replicas_opened += 1
        self.pool.place(idx, size, pdep if pdep is not None else now, now)
        self.alg.on_placed(arr, idx, opened)
        self._active[req.rid] = (idx, size)
        self.placements[req.rid] = idx
        self.stats.peak_replicas = max(self.stats.peak_replicas,
                                       len(self.pool._open_list))
        return idx

    def finish(self, rid: int, now: float) -> None:
        idx, size = self._active.pop(rid)
        self.pool.remove(idx, size)
        self.alg.on_departed(rid, idx, now, size)
        if self.pool.n_active[idx] == 0:
            self.stats.replica_seconds += now - self._open_at.pop(idx)
            self.pool.close_bin(idx)
            self.alg.on_closed(idx, now)

    def open_replicas(self) -> List[int]:
        return list(self.pool._open_list)
