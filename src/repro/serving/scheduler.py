"""DVBP request->replica placement: the paper's technique as the serving
control plane.

Replicas (mesh slices running a model) are *bins* with capacity vector
<batch slots, KV pages, prefill-FLOP budget>; requests are *items* whose
duration is their decode length - unknown (non-clairvoyant), known
(clairvoyant replay) or predicted (learning-augmented).  The autoscaler
objective is replica-occupancy seconds == the paper's accumulated bin usage
time; a replica with no active requests is released ("bin closed").

The scheduler drives the same BinPool + algorithm zoo as the offline engine,
so every policy (First Fit ... Prioritized NRT ... modified PPE) is available
verbatim.  On TPU the inner feasibility/score loop is the kernels/fitscore
Pallas kernel (the host fallback is pure numpy).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.bins import BinPool
from ..core.types import Arrival
from ..core.algorithms import get_algorithm


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    decode_len: int                    # ground truth (revealed at finish)
    predicted_decode_len: Optional[int] = None

    def size(self, caps: "ReplicaCapacity") -> np.ndarray:
        kv = (self.prompt_len + self.decode_len) / caps.kv_tokens
        return np.array([1.0 / caps.slots, min(kv, 1.0),
                         self.prompt_len / caps.prefill_budget])


@dataclasses.dataclass(frozen=True)
class ReplicaCapacity:
    slots: int = 8                 # concurrent sequences per replica
    kv_tokens: int = 65536         # KV-cache token pool
    prefill_budget: float = 262144  # prompt tokens/s headroom


@dataclasses.dataclass
class PlacementStats:
    replica_seconds: float = 0.0
    replicas_opened: int = 0
    peak_replicas: int = 0
    rejected: int = 0


class DVBPScheduler:
    """Online request placement over an elastic replica fleet."""

    def __init__(self, policy: str = "nrt_prioritized",
                 caps: ReplicaCapacity = ReplicaCapacity(),
                 policy_kwargs: Optional[Dict] = None,
                 tokens_per_second: float = 50.0):
        self.caps = caps
        self.tps = tokens_per_second
        self.pool = BinPool(d=3)
        self.alg = get_algorithm(policy, **(policy_kwargs or {}))

        class _Inst:   # minimal instance facade for algorithm.bind
            durations = np.array([1.0])
            n_items = 0
            sizes = np.zeros((0, 3))
            arrivals = np.zeros(0)
            departures = np.zeros(0)
        self.alg.bind(self.pool, _Inst())
        self.stats = PlacementStats()
        self._open_at: Dict[int, float] = {}
        self._active: Dict[int, tuple] = {}   # rid -> (bin idx, size)
        self.placements: Dict[int, int] = {}

    # ------------------------------------------------------------------- api
    def place(self, req: Request, now: float) -> int:
        """Place a request; returns the replica (bin) index."""
        size = req.size(self.caps)
        pdur = None
        if req.predicted_decode_len is not None:
            pdur = req.predicted_decode_len / self.tps
        pdep = None if pdur is None else now + pdur
        arr = Arrival(req.rid, size, now, pdep)
        idx = self.alg.select_bin(arr)
        opened = idx < 0
        if opened:
            idx = self.pool.open_bin(now)
            self._open_at[idx] = now
            self.stats.replicas_opened += 1
        self.pool.place(idx, size, pdep if pdep is not None else now, now)
        self.alg.on_placed(arr, idx, opened)
        self._active[req.rid] = (idx, size)
        self.placements[req.rid] = idx
        self.stats.peak_replicas = max(self.stats.peak_replicas,
                                       len(self.pool._open_list))
        return idx

    def finish(self, rid: int, now: float) -> None:
        idx, size = self._active.pop(rid)
        self.pool.remove(idx, size)
        self.alg.on_departed(rid, idx, now, size)
        if self.pool.n_active[idx] == 0:
            self.stats.replica_seconds += now - self._open_at.pop(idx)
            self.pool.close_bin(idx)
            self.alg.on_closed(idx, now)

    def open_replicas(self) -> List[int]:
        return list(self.pool._open_list)
