"""Bounded admission in front of ``DVBPScheduler``: deadlines + shedding.

A production placement loop cannot let a slow or failing select stall
admission unboundedly.  ``AdmissionQueue`` is the hardening layer between
raw traffic and the scheduler:

  * **bounded queue** - at most ``max_pending`` requests wait for
    placement.  When a new arrival would overflow the bound, queued
    requests whose deadline already lapsed are shed first
    (``resilience.shed_deadline``) - they could never be placed usefully
    anyway - and only if the queue is still full of *live* requests is
    the fresh arrival shed (``resilience.shed_queue_full``).  The two
    counters therefore distinguish "queue full of viable work" from
    "queue full of corpses" deterministically,
  * **per-request deadlines** - a request that waited longer than
    ``deadline`` seconds by drain time is shed
    (``resilience.shed_deadline``) rather than placed uselessly late,
  * **batched drain** - ``drain(now)`` places up to ``batch_max`` queued
    requests per call; the caller owns the cadence (every event-loop
    tick, every batch boundary).  ``take(now)`` is the batched front
    end's flavor: it pops the surviving requests without placing them,
    so ``serving.dispatch.BatchedFrontEnd`` can hand the whole batch to
    the block dispatcher as ONE kernel call.  Both drain in **deadline
    order** (earliest expiry first, submission order breaking ties): a
    request about to lapse is placed before one with slack, so mixed
    per-request deadlines (``submit(..., deadline=...)``) shed strictly
    less than insertion-order draining would.  With the uniform default
    deadline, expiry order == submission order and the drain is exactly
    the legacy FIFO.

Placement itself goes through ``DVBPScheduler.place``, which sits behind
the serving degradation ladder (``scheduler._select_guarded``) - so under
kernel failure the queue keeps draining on the jnp / host fallbacks, just
slower; the queue's job is to bound *how much* work piles up while that
happens.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Tuple

from .. import obs
from .scheduler import DVBPScheduler, Request


@dataclasses.dataclass
class AdmissionStats:
    submitted: int = 0
    placed: int = 0
    shed_queue_full: int = 0
    shed_deadline: int = 0

    @property
    def shed(self) -> int:
        return self.shed_queue_full + self.shed_deadline


class AdmissionQueue:
    """Bounded earliest-deadline-first admission in front of a placement
    engine.  The pending set is a heap keyed (expiry, submission seq), so
    drains pop the most urgent request first and uniform deadlines
    degenerate to exact FIFO.

    ``scheduler`` may be None when the queue only feeds ``take()`` (the
    batched front end owns placement); ``drain()`` then asserts."""

    def __init__(self, scheduler: Optional[DVBPScheduler],
                 max_pending: int = 1024, deadline: float = 5.0,
                 batch_max: int = 64):
        assert max_pending >= 1 and batch_max >= 1 and deadline > 0
        self.scheduler = scheduler
        self.max_pending = max_pending
        self.deadline = deadline
        self.batch_max = batch_max
        self.stats = AdmissionStats()
        # (expiry, seq, request, t_in); heap order == drain order
        self._pending: List[Tuple[float, int, Request, float]] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._pending)

    def _shed_one(self, now: float) -> None:
        _, _, req, t_in = heapq.heappop(self._pending)
        self.stats.shed_deadline += 1
        obs.counter_add("resilience.shed_deadline")
        obs.instant("resilience.shed", rid=req.rid, why="deadline",
                    waited=now - t_in)

    def _shed_expired(self, now: float) -> int:
        """Drop queued requests whose deadline lapsed (earliest expiry
        first - the heap root is always the most-expired entry).  Returns
        how many were shed."""
        n = 0
        while self._pending and now > self._pending[0][0]:
            self._shed_one(now)
            n += 1
        return n

    def submit(self, req: Request, now: float,
               deadline: Optional[float] = None) -> bool:
        """Enqueue a request; False means shed (queue saturated with
        still-viable requests).  Deadline-expired entries are evicted
        before a fresh arrival is ever rejected.  ``deadline`` overrides
        the queue-wide patience for this request (seconds from now)."""
        self.stats.submitted += 1
        if len(self._pending) >= self.max_pending:
            self._shed_expired(now)
        if len(self._pending) >= self.max_pending:
            self.stats.shed_queue_full += 1
            obs.counter_add("resilience.shed_queue_full")
            obs.instant("resilience.shed", rid=req.rid, why="queue_full")
            return False
        expiry = now + (self.deadline if deadline is None else deadline)
        heapq.heappush(self._pending, (expiry, self._seq, req, now))
        self._seq += 1
        return True

    def take(self, now: float, limit: Optional[int] = None
             ) -> List[Tuple[Request, float]]:
        """Pop up to ``limit`` (default ``batch_max``) queued requests in
        deadline order (earliest expiry first, submission order breaking
        ties), shedding expired entries along the way.  Returns the
        surviving ``(request, submit_time)`` pairs - the batched front
        end's drain primitive (placement happens in the block dispatcher,
        not here)."""
        budget = self.batch_max if limit is None else limit
        out: List[Tuple[Request, float]] = []
        while self._pending and budget:
            if now > self._pending[0][0]:
                self._shed_one(now)
                continue
            _, _, req, t_in = heapq.heappop(self._pending)
            out.append((req, t_in))
            budget -= 1
        return out

    def drain(self, now: float) -> List[Tuple[int, int]]:
        """Place up to ``batch_max`` queued requests; returns
        ``[(rid, replica), ...]`` for the requests actually placed.
        Requests whose deadline lapsed while queued are shed, not placed."""
        assert self.scheduler is not None, \
            "drain() needs a scheduler; batched front ends use take()"
        placed: List[Tuple[int, int]] = []
        for req, _ in self.take(now):
            idx = self.scheduler.place(req, now)
            placed.append((req.rid, idx))
            self.stats.placed += 1
        return placed
