"""Fleet-level serving simulation: DVBP placement vs. baselines.

Drives a replica fleet (simulated clock; optionally real ReplicaEngines for
small models) under a request trace.  The objective is replica-occupancy
seconds - the paper's accumulated bin usage time - which is what an
autoscaler pays for.  ``round_robin`` and ``pack_all`` baselines bracket the
DVBP policies.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from .scheduler import DVBPScheduler, ReplicaCapacity, Request


def synth_requests(n: int, *, seed: int = 0, rate: float = 8.0,
                   tps: float = 50.0) -> List[Request]:
    """Poisson arrivals, log-normal decode lengths (the VM-lifetime analogue
    for serving: paper Fig. 1 shows log-normal lifetimes)."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, n))
    prompts = rng.integers(32, 512, n)
    decodes = np.clip(rng.lognormal(5.0, 1.2, n), 8, 8192).astype(int)
    return [Request(i, float(t[i]), int(prompts[i]), int(decodes[i]))
            for i in range(n)]


def attach_predictions(reqs: List[Request], sigma: float, seed: int = 0
                       ) -> List[Request]:
    rng = np.random.default_rng(seed)
    out = []
    for r in reqs:
        delta = float(np.exp(rng.normal(0.0, sigma))) if sigma > 0 else 1.0
        out.append(dataclasses.replace(
            r, predicted_decode_len=max(1, int(r.decode_len * delta))))
    return out


def simulate_fleet(reqs: List[Request], policy: str = "greedy",
                   caps: ReplicaCapacity = ReplicaCapacity(),
                   tps: float = 50.0, policy_kwargs: Optional[Dict] = None
                   ) -> Dict:
    """Event-driven fleet simulation; service time = decode_len / tps.

    Legacy entry point: the host-side reference implementation.  Batched
    capacity planning (same decisions, scan lanes, sweep store) lives in
    ``repro.api``: ``Experiment(serving_requests(reqs, caps, tps),
    policies, settings).run()`` - parity is proven decision-for-decision
    in tests/test_api.py.  The host baselines (round_robin / pack_all)
    only exist here."""
    if policy in ("round_robin", "pack_all"):
        # the host baselines have no api replacement - no migration nag
        return _baseline(reqs, policy, caps, tps)
    from ..api._migration import warn_legacy
    warn_legacy("serving.fleet.simulate_fleet",
                "repro.api.Experiment(api.serving_requests(...))")
    sched = DVBPScheduler(policy, caps, policy_kwargs, tokens_per_second=tps)
    heap = []   # (finish time, rid)
    for r in sorted(reqs, key=lambda x: x.arrival):
        while heap and heap[0][0] <= r.arrival:
            ft, rid = heapq.heappop(heap)
            sched.finish(rid, ft)
        sched.place(r, r.arrival)
        heapq.heappush(heap, (r.arrival + r.decode_len / tps, r.rid))
    while heap:
        ft, rid = heapq.heappop(heap)
        sched.finish(rid, ft)
    s = sched.stats
    return {"policy": policy, "replica_seconds": s.replica_seconds,
            "replicas_opened": s.replicas_opened,
            "peak_replicas": s.peak_replicas}


def _baseline(reqs, policy: str, caps: ReplicaCapacity, tps: float) -> Dict:
    """round_robin: spray over replicas opened on demand, close when idle.
    pack_all: single unbounded replica (lower-bound-ish reference)."""
    active: Dict[int, List] = {}        # replica -> [(finish, rid, size)...]
    opened_at: Dict[int, float] = {}
    usage = 0.0
    opened = 0
    peak = 0
    rr = 0
    heap = []
    load = {}

    def fits(rep, r):
        s = r.size(caps)
        return np.all(load[rep] + s <= 1.0 + 1e-9)

    for r in sorted(reqs, key=lambda x: x.arrival):
        while heap and heap[0][0] <= r.arrival:
            ft, rid, rep, s = heapq.heappop(heap)
            load[rep] -= s
            active[rep].remove(rid)
            if not active[rep]:
                usage += ft - opened_at.pop(rep)
                del active[rep]
                del load[rep]
        reps = sorted(active)
        placed = None
        if policy == "pack_all":
            # single unbounded replica: capacity intentionally not enforced,
            # so replica-seconds degenerate to the activity span (the
            # lower-bound-ish reference the DVBP policies are judged against)
            placed = reps[0] if reps else None
        elif reps:
            for k in range(len(reps)):
                cand = reps[(rr + k) % len(reps)]
                if fits(cand, r):
                    placed = cand
                    rr = (rr + k + 1) % len(reps)
                    break
        if placed is None:
            placed = opened
            opened += 1
            active[placed] = []
            load[placed] = np.zeros(3)
            opened_at[placed] = r.arrival
        s = r.size(caps)
        load[placed] += s
        active[placed].append(r.rid)
        peak = max(peak, len(active))
        heapq.heappush(heap, (r.arrival + r.decode_len / tps, r.rid,
                              placed, s))
    while heap:
        ft, rid, rep, s = heapq.heappop(heap)
        load[rep] -= s
        active[rep].remove(rid)
        if not active[rep]:
            usage += ft - opened_at.pop(rep)
            del active[rep]
            del load[rep]
    return {"policy": policy, "replica_seconds": usage,
            "replicas_opened": opened, "peak_replicas": peak}
