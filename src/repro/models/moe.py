"""Capacity-based top-k Mixture-of-Experts with locality-preserving dispatch.

Routing/dispatch runs inside ``shard_map`` so the token sort/gather/scatter
stays *local to each data shard* (no global argsort collectives).  Expert
weights shard over the "model" axis on the expert dim when divisible (EP),
else on the hidden dim (expert-TP).  In both layouts every model shard sees
all local tokens (replicated over "model"), computes its expert slice, and a
single psum over "model" combines - no all-to-all in the baseline schedule.

A ``dense`` reference mode (all experts for all tokens, gate-weighted) backs
the unit tests: with ample capacity the dropping path must match it exactly.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from .config import ModelConfig


def _act(cfg: ModelConfig, gate, up):
    if cfg.mlp_act == "silu_glu":
        return jax.nn.silu(gate) * up
    if cfg.mlp_act == "gelu_glu":
        return jax.nn.gelu(gate) * up
    if cfg.mlp_act == "relu2":
        return jnp.square(jax.nn.relu(up))
    return jax.nn.gelu(up)


def router_probs(x, router_w, dtype=jnp.float32):
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1), logits


def _capacity(T: int, k: int, E: int, cf: float) -> int:
    c = int(T * k / E * cf) + 1
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8


def _dispatch_local(x, gates, k: int, C: int, norm_topk: bool):
    """x: (T,d); gates: (T,E) fp32.  Returns (xe (E,C,d), table (E,C) token
    ids with OOB sentinel T, wtable (E,C) combine weights)."""
    T, d = x.shape
    E = gates.shape[1]
    w, ids = jax.lax.top_k(gates, k)                      # (T,k)
    if norm_topk:
        w = w / (w.sum(-1, keepdims=True) + 1e-9)
    e_flat = ids.reshape(-1)                              # (T*k,)
    onehot = (e_flat[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot             # rank within expert
    p_flat = jnp.sum(pos * onehot, axis=1)
    t_flat = jnp.arange(T * k, dtype=jnp.int32) // k
    table = jnp.full((E, C), T, jnp.int32)
    table = table.at[e_flat, p_flat].set(t_flat, mode="drop")
    wtable = jnp.zeros((E, C), w.dtype)
    wtable = wtable.at[e_flat, p_flat].set(w.reshape(-1), mode="drop")
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[table]                                     # (E,C,d)
    return xe, table, wtable


def _expert_ffn(cfg: ModelConfig, blk, xe):
    """xe: (E_loc, C, d) -> (E_loc, C, d) through each expert's MLP slice."""
    up = jnp.einsum("ecd,edf->ecf", xe, blk["we_in"].astype(xe.dtype))
    if "we_gate" in blk:
        gate = jnp.einsum("ecd,edf->ecf", xe, blk["we_gate"].astype(xe.dtype))
    else:
        gate = None
    h = _act(cfg, gate, up)
    return jnp.einsum("ecf,efd->ecd", h, blk["we_out"].astype(xe.dtype))


def _combine_local(ye, table, wtable, T: int, d: int):
    out = jnp.zeros((T, d), ye.dtype)
    contrib = ye * wtable[..., None].astype(ye.dtype)
    return out.at[table.reshape(-1)].add(contrib.reshape(-1, d), mode="drop")


def aux_losses(gates, ids, E: int):
    """Load-balance loss (Switch) + router z-loss ingredients."""
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)    # (T,k,E)
    frac_tokens = onehot.sum((0, 1)) / (ids.shape[0] * ids.shape[1])
    frac_prob = gates.mean(0)
    return E * jnp.sum(frac_tokens * frac_prob)


def moe_block(blk, x, cfg: ModelConfig, mesh: Optional[Mesh] = None,
              data_axes: Tuple[str, ...] = ("data",), norm_topk: bool = True,
              impl: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    if impl == "dense" or mesh is None:
        gates, logits = router_probs(x.reshape(-1, d), blk["router"])
        w, ids = jax.lax.top_k(gates, k)
        if norm_topk:
            w = w / (w.sum(-1, keepdims=True) + 1e-9)
        full = jnp.zeros_like(gates).at[
            jnp.arange(gates.shape[0])[:, None], ids].set(w)
        xe = jnp.einsum("td,edf->tef", x.reshape(-1, d),
                        blk["we_in"].astype(x.dtype))
        ge = jnp.einsum("td,edf->tef", x.reshape(-1, d),
                        blk["we_gate"].astype(x.dtype)) if "we_gate" in blk else None
        h = _act(cfg, ge, xe)
        ye = jnp.einsum("tef,efd->ted", h, blk["we_out"].astype(x.dtype))
        out = jnp.einsum("ted,te->td", ye, full.astype(x.dtype))
        aux = aux_losses(gates, ids, E)
        out = out.reshape(B, S, d)
    else:
        model_n = mesh.shape["model"]
        ep = E % model_n == 0
        wspec = {n: P("model", None, None) if ep else P(None, None, "model")
                 for n in ("we_in", "we_gate", "we_out")}
        if not ep:
            wspec["we_out"] = P(None, "model", None)
        specs = {"router": P(None, None)}
        specs.update({n: wspec[n] for n in blk if n in wspec})
        xspec = P(data_axes, None, None)

        def local(x_l, *ws):
            wb = dict(zip(sorted(specs), ws))
            T = x_l.shape[0] * x_l.shape[1]
            xf = x_l.reshape(T, d)
            gates, logits = router_probs(xf, wb["router"])
            C = _capacity(T, k, E, cfg.capacity_factor)
            if ep:
                # each model shard owns E/model experts: slice dispatch tables
                E_loc = E // model_n
                xe, table, wtable = _dispatch_local(xf, gates, k, C, norm_topk)
                mi = jax.lax.axis_index("model")
                sl = lambda a: jax.lax.dynamic_slice_in_dim(a, mi * E_loc, E_loc, 0)
                ye = _expert_ffn(cfg, wb, sl(xe))
                part = _combine_local(ye, sl(table), sl(wtable), T, d)
            else:
                xe, table, wtable = _dispatch_local(xf, gates, k, C, norm_topk)
                ye = _expert_ffn(cfg, wb, xe)   # hidden dim is model-sharded
                part = _combine_local(ye, table, wtable, T, d)
            out = jax.lax.psum(part, "model")
            w_top, ids = jax.lax.top_k(gates, k)
            aux = aux_losses(gates, ids, E)
            return out.reshape(x_l.shape), aux

        names = sorted(specs)
        out, aux = shard_map(
            local, mesh=mesh,
            in_specs=(xspec,) + tuple(specs[n] for n in names),
            out_specs=(xspec, P()),
            check_vma=False)(x, *[blk[n] for n in names])
        aux = aux / 1.0   # already averaged per shard; identical across shards

    if cfg.n_shared_experts:
        up = x @ blk["shared_w_in"].astype(x.dtype)
        gate = x @ blk["shared_w_gate"].astype(x.dtype) \
            if "shared_w_gate" in blk else None
        out = out + _act(cfg, gate, up) @ blk["shared_w_out"].astype(x.dtype)
    return out, aux
