"""Logical-axis -> mesh-axis rules and PartitionSpec trees.

Mesh axes: ("data", "model") single pod, ("pod", "data", "model") multi-pod.
Batch always shards over (pod, data).  Tensor parallelism maps the logical
axes heads/mlp/vocab/expert onto "model".  FSDP additionally shards the
"embed" axis of weight matrices over "data" (ZeRO-3: params, grads and
optimizer states all inherit it).  Sequence parallelism shards activation
sequence dims over "model" between blocks (with_sharding_constraint).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig
from . import params as P_


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    fsdp: bool = False            # shard "embed" weight axis over data
    expert_parallel: bool = True  # shard "expert" over model when divisible
    seq_parallel: bool = False    # shard activation seq dim over model
    data_axes: Tuple[str, ...] = ("data",)   # ("pod","data") multi-pod
    # FSDP on embed/lm_head tables: good for training (optimizer sharding),
    # harmful for inference (the token gather cannot shard batch and d over
    # the same "data" axis -> involuntary full rematerialization in GSPMD).
    fsdp_vocab_tables: bool = True

    def table(self, cfg: ModelConfig, mesh: Mesh) -> Dict[str, Optional[object]]:
        model_n = mesh.shape["model"]
        ep_ok = (self.expert_parallel and cfg.n_experts > 0
                 and cfg.n_experts % model_n == 0)
        data_for_fsdp = None
        if self.fsdp:
            data_for_fsdp = ("data",)  # never shard weights across pods (DCI)
        return {
            "vocab": "model",
            "heads": "model",
            # ragged kv-head shards force partial-sum all-reduces: replicate
            # kv projections unless the head count divides the model axis
            "kv_heads": "model" if cfg.n_kv_heads % model_n == 0 else None,
            "mlp": None if ep_ok else "model",
            "expert": "model" if ep_ok else None,
            "embed": data_for_fsdp,
            "kv_lora": None,
            "layers": None,
            None: None,
        }


def tree_pspecs(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules) -> Dict:
    """PartitionSpec tree parallel to the params tree.  A dim is sharded only
    if the rule maps it to a mesh axis whose size divides the dim (e.g. odd
    vocabs like 49155 fall back to replication)."""
    table = rules.table(cfg, mesh)

    def leaf(meta: P_.ParamMeta, n):
        shape = ((n,) + meta.shape) if n else meta.shape
        axes = (("layers",) + meta.axes) if n else meta.axes
        assigned = []
        seen = set()
        is_vocab_table = "vocab" in axes
        for dim, ax in zip(shape, axes):
            mesh_ax = table.get(ax)
            if ax == "embed" and is_vocab_table and not rules.fsdp_vocab_tables:
                mesh_ax = None
            flat = tuple(mesh_ax) if isinstance(mesh_ax, tuple) else (mesh_ax,)
            size = 1
            for a in flat:
                if a is not None:
                    size *= mesh.shape[a]
            if (mesh_ax is None or any(a in seen for a in flat)
                    or dim % size != 0):
                assigned.append(None)
            else:
                assigned.append(mesh_ax)
                seen.update(flat)
        return P(*assigned)

    return P_._finalize(cfg, leaf)


def tree_shardings(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules) -> Dict:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_pspecs(cfg, mesh, rules),
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(rules: ShardingRules) -> P:
    return P(rules.data_axes)


def activation_spec(rules: ShardingRules, with_seq: bool = True) -> P:
    """(B, S, d) activation spec; seq over model when seq_parallel."""
    seq = "model" if (rules.seq_parallel and with_seq) else None
    return P(rules.data_axes, seq, None)


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
