"""Attention: query-chunked GQA (full / sliding-window / mixed), MLA, and
KV-cache decode paths.

The training/prefill path is query-chunked: scores are materialized only for
one (q_chunk x S_kv) tile at a time via lax.scan, bounding activation memory
at long context (the XLA fallback for the Pallas flash kernel, which is
dispatched on TPU backends by kernels.ops).  Softmax statistics are exact
(full row per chunk).  All softmax math runs in fp32.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
          window: jax.Array, kv_len: Optional[jax.Array]) -> jax.Array:
    """(..., Sq, Skv) boolean mask.  window: 0 => unlimited (per-layer scalar,
    traced so local/global layers share one scan body)."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    m = jnp.ones(d.shape, bool)
    if causal:
        m &= d >= 0
    m &= (window <= 0) | (d < window)
    if kv_len is not None:
        m &= k_pos[..., None, :] < kv_len[..., None, None]
    return m


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  q_positions: jax.Array, k_positions: jax.Array,
                  causal: bool = True, window=0,
                  kv_len: Optional[jax.Array] = None,
                  softcap: float = 0.0, q_chunk: int = 1024,
                  kv_chunk: int = 0,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (B,Sq,H,hd); k: (B,Skv,KV,hd); v: (B,Skv,KV,hd_v); positions (B,S*).
    Returns (B,Sq,H,hd_v).  H must be a multiple of KV (GQA groups).
    kv_chunk > 0 selects the online-softmax flash_xla path."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    hd_v = v.shape[3]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    window = jnp.asarray(window, jnp.int32)

    qg = q.reshape(B, Sq, KV, G, hd)

    def chunk_attn(q_c, qpos_c):
        # q_c: (B,qc,KV,G,hd) -> scores (B,KV,G,qc,Skv) in fp32
        s = jnp.einsum("bqkgh,bskh->bkgqs", q_c, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        m = _mask(qpos_c, k_positions, causal, window, kv_len)
        s = jnp.where(m[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
        return o

    if kv_chunk:
        # "flash_xla": online-softmax scan over KV blocks - only (bq, bkv)
        # score tiles ever materialize, cutting attention HBM traffic ~10x
        # vs. chunked-q (EXPERIMENTS.md §Perf).  The checkpointed body makes
        # the backward recompute tiles instead of saving them.
        Skv_p = -(-k.shape[1] // kv_chunk) * kv_chunk
        pad_kv = Skv_p - k.shape[1]
        kp_ = jnp.pad(k_positions, ((0, 0), (0, pad_kv)), mode="edge") \
            if pad_kv else k_positions
        k_ = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else k
        v_ = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else v
        kv_valid = (jnp.arange(Skv_p) < k.shape[1])[None, :]

        def q_block(q_c, qpos_c):
            nk = Skv_p // kv_chunk
            ks = k_.reshape(B, nk, kv_chunk, KV, hd)
            vs = v_.reshape(B, nk, kv_chunk, KV, hd_v)
            kps = kp_.reshape(kp_.shape[0], nk, kv_chunk)
            kvs = kv_valid.reshape(1, nk, kv_chunk)

            def body(carry, xs):
                acc, m, l = carry
                kb, vb, kpb, kvb = xs
                s = jnp.einsum("bqkgh,bskh->bkgqs", q_c, kb,
                               preferred_element_type=jnp.float32) * scale
                if softcap > 0:
                    s = jnp.tanh(s / softcap) * softcap
                msk = _mask(qpos_c, kpb, causal, window, kv_len) & \
                    kvb[:, None, :]
                s = jnp.where(msk[:, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(-1))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s - m_new[..., None])
                l = l * alpha + p.sum(-1)
                acc = acc * alpha[..., None] + jnp.einsum(
                    "bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb)
                return (acc, m_new, l), None

            qc = q_c.shape[1]
            init = (jnp.zeros((B, KV, G, qc, hd_v), jnp.float32),
                    jnp.full((B, KV, G, qc), NEG_INF),
                    jnp.zeros((B, KV, G, qc)))
            xs = (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0),
                  jnp.moveaxis(kps, 1, 0), jnp.moveaxis(kvs, 1, 0))
            (acc, m, l), _ = jax.lax.scan(jax.checkpoint(body), init, xs)
            o = acc / jnp.maximum(l, 1e-30)[..., None]
            return jnp.moveaxis(o, 3, 1)   # (B,qc,KV,G,hd_v)

        pad = (-Sq) % q_chunk
        if pad:
            qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
            q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)),
                                  mode="edge")
        n = (Sq + pad) // q_chunk
        if n == 1:
            out = q_block(qg, q_positions)[:, :Sq]
        else:
            qs = qg.reshape(B, n, q_chunk, KV, G, hd).transpose(
                1, 0, 2, 3, 4, 5)
            ps = q_positions.reshape(q_positions.shape[0], n,
                                     q_chunk).transpose(1, 0, 2)
            out = jax.lax.map(lambda a: q_block(*a), (qs, ps))
            out = out.transpose(1, 0, 2, 3, 4, 5).reshape(
                B, Sq + pad, KV, G, hd_v)[:, :Sq]
        return out.reshape(B, Sq, H, hd_v).astype(q.dtype)

    if Sq <= q_chunk:
        out = chunk_attn(qg, q_positions)
    else:
        pad = (-Sq) % q_chunk   # pad queries up to a chunk multiple
        if pad:
            qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
            q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)),
                                  mode="edge")
        n = (Sq + pad) // q_chunk
        qs = qg.reshape(B, n, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
        ps = q_positions.reshape(B, n, q_chunk).transpose(1, 0, 2)
        out = jax.lax.map(lambda args: chunk_attn(*args), (qs, ps))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq + pad, KV, G, hd_v)
        out = out[:, :Sq]
    return out.reshape(B, Sq, H, hd_v)


# --------------------------------------------------------------------- blocks

def _proj(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def attention_block(blk, x, cfg, *, positions, window, cache=None,
                    cache_pos=None, cross_states=None,
                    prefix: str = "") -> Tuple:
    """Standard GQA attention (or cross-attention onto ``cross_states``).

    cache: None (train) or dict {"k","v"} with layout (B, Smax, KV, hd);
    cache_pos: scalar int32 write offset (decode). Returns (out, new_cache).
    """
    B, S, _ = x.shape
    g = lambda name: blk[prefix + name]
    H, KVh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _proj(x, g("wq"), g("bq") if cfg.qkv_bias and not prefix else None)
    q = q.reshape(B, S, H, hd)
    if cross_states is not None:
        e = cross_states.astype(x.dtype)
        Se = e.shape[1]
        k = _proj(e, g("wk")).reshape(B, Se, KVh, hd)
        v = _proj(e, g("wv")).reshape(B, Se, KVh, hd)
        out = gqa_attention(q, k, v, q_positions=positions,
                            k_positions=jnp.arange(Se)[None, :],
                            causal=False, window=0, q_chunk=cfg.attn_q_chunk)
        return _proj(out.reshape(B, S, H * hd), g("wo")), None

    k = _proj(x, g("wk"), g("bk") if cfg.qkv_bias else None).reshape(B, S, KVh, hd)
    v = _proj(x, g("wv"), g("bv") if cfg.qkv_bias else None).reshape(B, S, KVh, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = gqa_attention(q, k, v, q_positions=positions, k_positions=positions,
                            causal=True, window=window,
                            q_chunk=cfg.attn_q_chunk,
                            kv_chunk=cfg.attn_kv_chunk)
        new_cache = {"k": k, "v": v}
    elif "k_q" in cache:
        # int8 KV cache: halves cache HBM/stream bytes (per-position absmax
        # scales; standard serving-quality quantization) - §Perf
        Smax = cache["k_q"].shape[1]
        kq, ks = quant_kv(k)
        vq, vs = quant_kv(v)
        ckq, cvq = _cache_update(cache["k_q"], cache["v_q"], kq, vq,
                                 cache_pos)
        cks, cvs = _cache_update(cache["k_s"], cache["v_s"], ks, vs,
                                 cache_pos)
        kv_len = (jnp.zeros((B,), jnp.int32) + cache_pos + S).astype(jnp.int32)
        out = gqa_attention(q, dequant_kv(ckq, cks, x.dtype),
                            dequant_kv(cvq, cvs, x.dtype),
                            q_positions=positions,
                            k_positions=jnp.arange(Smax)[None, :],
                            causal=True, window=window, kv_len=kv_len,
                            q_chunk=cfg.attn_q_chunk,
                            kv_chunk=cfg.attn_kv_chunk)
        new_cache = {"k_q": ckq, "v_q": cvq, "k_s": cks, "v_s": cvs}
    else:
        Smax = cache["k"].shape[1]
        ck, cv = _cache_update(cache["k"], cache["v"], k, v, cache_pos)
        kv_len = (jnp.zeros((B,), jnp.int32) + cache_pos + S).astype(jnp.int32)
        out = gqa_attention(q, ck, cv, q_positions=positions,
                            k_positions=jnp.arange(Smax)[None, :],
                            causal=True, window=window, kv_len=kv_len,
                            q_chunk=cfg.attn_q_chunk,
                            kv_chunk=cfg.attn_kv_chunk)
        new_cache = {"k": ck, "v": cv}
    return _proj(out.reshape(B, S, H * hd), g("wo")), new_cache


def _cache_update(ck, cv, k, v, cache_pos):
    """Write new K/V at cache_pos (scalar, or (B,) for continuous batching
    where each slot sits at a different depth)."""
    if jnp.ndim(cache_pos) == 0:
        return (jax.lax.dynamic_update_slice_in_dim(ck, k, cache_pos, axis=1),
                jax.lax.dynamic_update_slice_in_dim(cv, v, cache_pos, axis=1))
    upd = jax.vmap(lambda c, u, p:
                   jax.lax.dynamic_update_slice_in_dim(c, u, p, axis=0))
    return upd(ck, k, cache_pos), upd(cv, v, cache_pos)


# ---------------------------------------------------- int8 KV cache (§Perf)

def quant_kv(x: jax.Array):
    """Per-(position, head) absmax int8 quantization over the last dim."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127,
                 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequant_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _rms(x, scale, eps):
    x32 = x.astype(jnp.float32)
    n = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (n * scale.astype(jnp.float32)).astype(x.dtype)


def mla_attention_block(blk, x, cfg, *, positions, cache=None, cache_pos=None,
                        absorb: bool = False) -> Tuple:
    """DeepSeek-V2 Multi-head Latent Attention.

    Caches only the compressed latent (c_kv || k_rope): (B, Smax, lora+r).
    ``absorb=True`` (decode optimization, §Perf): queries are absorbed through
    W_UK so attention runs in the latent space and W_UV is applied to the
    attended latent - no per-position K/V up-projection over the whole cache.
    """
    B, S, _ = x.shape
    H, hd, r, lora = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    q = _proj(x, blk["wq"]).reshape(B, S, H, hd + r)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    c = _proj(x, blk["w_dkv"])                       # (B,S,lora+r)
    c_kv = _rms(c[..., :lora], blk["kv_norm"], cfg.norm_eps)
    k_rope = rope(c[..., lora:][:, :, None, :], positions, cfg.rope_theta)
    lat = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)

    if cache is not None:
        if jnp.ndim(cache_pos) == 0:
            lat = jax.lax.dynamic_update_slice_in_dim(cache["lat"], lat,
                                                      cache_pos, axis=1)
        else:
            lat = jax.vmap(lambda c, u, p:
                           jax.lax.dynamic_update_slice_in_dim(c, u, p, 0))(
                cache["lat"], lat, cache_pos)
        kv_len = (jnp.zeros((B,), jnp.int32) + cache_pos + S).astype(jnp.int32)
        k_positions = jnp.arange(lat.shape[1])[None, :]
    else:
        kv_len = None
        k_positions = positions
    new_cache = {"lat": lat}
    c_all, krope_all = lat[..., :lora], lat[..., lora:]

    scale = (hd + r) ** -0.5
    wuk = blk["w_uk"].reshape(lora, H, hd).astype(x.dtype)
    wuv = blk["w_uv"].reshape(lora, H, hd).astype(x.dtype)
    if absorb:
        # Absorbed decode: attention entirely in the (lora+r) latent space,
        # a single shared "KV head"; W_UV applied to the attended latent.
        q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, wuk)
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)   # (B,S,H,lora+r)
        ctx = gqa_attention(q_cat, lat[:, :, None, :], c_all[:, :, None, :],
                            q_positions=positions, k_positions=k_positions,
                            causal=True, window=0, kv_len=kv_len,
                            q_chunk=cfg.attn_q_chunk, scale=scale)
        out = jnp.einsum("bqhl,lhd->bqhd", ctx, wuv)
    else:
        # Naive path: up-project K,V for every cached position, then standard
        # MHA with concatenated (nope || rope) key/query of dim hd+r.
        k_nope = jnp.einsum("bsl,lhd->bshd", c_all, wuk)
        v = jnp.einsum("bsl,lhd->bshd", c_all, wuv)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_all[:, :, None, :],
                                      k_nope.shape[:3] + (r,))], axis=-1)
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = gqa_attention(q_cat, k_cat, v, q_positions=positions,
                            k_positions=k_positions, causal=True, window=0,
                            kv_len=kv_len, q_chunk=cfg.attn_q_chunk,
                            scale=scale)
    return _proj(out.reshape(B, S, H * hd), blk["wo"]), new_cache
