"""Unified model configuration spine for all 10 assigned architectures.

One frozen dataclass covers dense GQA decoders, mixed local/global attention
(gemma3), squared-ReLU MLPs (nemotron), QKV bias (qwen), capacity-routed MoE
(granite), MLA + shared-expert MoE (deepseek-v2-lite), encoder-decoder with a
conv-frontend stub (whisper), vision-stub VLM (pixtral), RWKV6 linear
attention (rwkv6), and parallel attention+SSM heads (hymba).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # ---- attention pattern
    attn_kind: str = "full"      # full | sliding | mixed (local + periodic global)
    window: int = 0              # sliding-window size (local layers)
    global_every: int = 0        # mixed: layer i is global iff (i+1) % global_every == 0
    qkv_bias: bool = False
    logit_softcap: float = 0.0

    # ---- MLP
    mlp_act: str = "silu_glu"    # silu_glu | gelu_glu | gelu | relu2

    # ---- MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0       # leading layers with a dense MLP (deepseek)
    dense_d_ff: int = 0          # d_ff of those dense layers
    capacity_factor: float = 1.25

    # ---- MLA (deepseek)
    mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 0       # decoupled RoPE key dim

    # ---- sequence mixers beyond attention
    rwkv: bool = False           # RWKV6: attention-free linear attention
    ssm: bool = False            # hymba: parallel SSM (SSD) heads next to attn
    ssm_state: int = 0

    # ---- topology
    arch_kind: str = "decoder"   # decoder | encdec
    n_enc_layers: int = 0
    frontend: str = "none"       # none | audio_stub | vision_stub
    n_frontend_tokens: int = 0   # patches/frames prepended by the stub

    # ---- numerics / runtime
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    attn_q_chunk: int = 1024     # query-chunked attention (memory-bounded)
    attn_kv_chunk: int = 0       # >0: online-softmax flash_xla path (§Perf)
    kv_cache_int8: bool = False  # int8 KV/latent cache (per-position absmax)
    scan_chunk: int = 64         # rwkv/ssm chunk length

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.rwkv

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_is_global(self, i: int) -> bool:
        if self.attn_kind == "full":
            return True
        if self.attn_kind == "sliding":
            return False
        return (i + 1) % max(self.global_every, 1) == 0

    # ------------------------------------------------------- parameter counts
    def param_count(self) -> int:
        """Exact dense parameter count (embeddings included)."""
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        n_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.mla:
            nope = hd
            n_attn = (d * self.n_heads * (nope + self.rope_head_dim)  # W_Q
                      + d * (self.kv_lora_rank + self.rope_head_dim)  # W_DKV
                      + self.kv_lora_rank * self.n_heads * nope * 2   # W_UK/UV
                      + self.n_heads * nope * d)                      # W_O
        glu = self.mlp_act.endswith("_glu")
        def mlp(dff):
            return d * dff * (3 if glu else 2)
        if self.rwkv:
            n_mix = 4 * d * d + d * d  # r,k,v,g(+decay lora approx) + out
            n_layer = n_mix + mlp(ff)
        elif self.n_experts:
            n_router = d * self.n_experts
            n_exp = self.n_experts * mlp(self.d_expert)
            n_shared = self.n_shared_experts * mlp(self.d_expert)
            n_layer = n_attn + n_router + n_exp + n_shared
        else:
            n_layer = n_attn + mlp(ff)
        if self.ssm:
            P = self.q_dim // max(self.n_heads, 1)
            n_layer += d * self.q_dim + self.q_dim * d \
                + 2 * d * self.ssm_state * self.n_heads + d * self.n_heads
        total = self.n_layers * n_layer
        if self.first_k_dense:
            total += self.first_k_dense * (mlp(self.dense_d_ff or ff)
                                           - (d * self.n_experts
                                              + self.n_experts * mlp(self.d_expert)
                                              + self.n_shared_experts * mlp(self.d_expert)))
        if self.arch_kind == "encdec":
            enc_layer = n_attn + mlp(ff)
            cross = n_attn
            total += self.n_enc_layers * enc_layer + self.n_layers * cross
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        glu = self.mlp_act.endswith("_glu")
        per_expert = self.d_model * self.d_expert * (3 if glu else 2)
        inactive = (self.n_experts - self.top_k) * per_expert * \
            (self.n_layers - self.first_k_dense)
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k only for sub-quadratic archs (DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = ("rwkv6-1.6b", "hymba-1.5b", "gemma3-12b")


def shapes_for(arch_name: str) -> Tuple[str, ...]:
    base = ("train_4k", "prefill_32k", "decode_32k")
    if arch_name in LONG_CONTEXT_ARCHS:
        return base + ("long_500k",)
    return base
