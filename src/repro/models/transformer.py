"""Model forward passes for all 10 architectures.

One scan-over-layers spine (constant compile time in depth) with per-layer
traced window scalars so mixed local/global attention (gemma3, hymba) shares
a single scan body.  Modes: "train" (causal, no cache), "prefill" (returns a
KV cache), "decode" (one token against a cache).  KV caches are stacked along
a leading layer axis so the same scan consumes them.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .attention import _proj, _rms, attention_block, mla_attention_block
from .config import ModelConfig
from .linear_scan import chunked_linear_attention, linear_attention_step
from .moe import _act, moe_block
from .sharding import ShardingRules


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution context threaded through the forward pass."""

    mesh: Optional[Mesh] = None
    rules: ShardingRules = dataclasses.field(default_factory=ShardingRules)
    mla_absorb: bool = False     # §Perf: absorbed-matmul MLA decode
    moe_impl: str = "auto"       # auto | dense

    @property
    def data_axes(self):
        return self.rules.data_axes


# ----------------------------------------------------------------- MLP / norm

def mlp(blk, x, cfg: ModelConfig):
    up = x @ blk["w_in"].astype(x.dtype)
    gate = x @ blk["w_gate"].astype(x.dtype) if "w_gate" in blk else None
    return _act(cfg, gate, up) @ blk["w_out"].astype(x.dtype)


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention window (0 = full attention)."""
    return np.array([0 if cfg.layer_is_global(i) else cfg.window
                     for i in range(cfg.n_layers)], np.int32)


# ------------------------------------------------------------- layer variants

def _rwkv_layer(blk, x, cfg, *, cache, pos):
    B, S, d = x.shape
    H, K = cfg.n_heads, cfg.head_dim
    xn = _rms(x, blk["ln1"], cfg.norm_eps)
    if cache is None:
        prev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = cache["shift_a"][:, None, :].astype(xn.dtype) if S == 1 else \
            jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    lerp = lambda m: xn + (prev - xn) * blk[m].astype(xn.dtype)
    r = _proj(lerp("mix_r"), blk["w_r"]).reshape(B, S, H, K)
    k = _proj(lerp("mix_k"), blk["w_k"]).reshape(B, S, H, K)
    v = _proj(lerp("mix_v"), blk["w_v"]).reshape(B, S, H, K)
    g = jax.nn.silu(_proj(lerp("mix_g"), blk["w_g"]))
    dec = jnp.tanh(_proj(lerp("mix_w"), blk["decay_a"])) @ \
        blk["decay_b"].astype(xn.dtype) + blk["decay_base"].astype(xn.dtype)
    logw = -jnp.exp(dec.astype(jnp.float32)).reshape(B, S, H, K)
    u = blk["bonus_u"].reshape(H, K)
    state0 = cache["state"] if cache is not None else None
    if S == 1 and cache is not None:
        y, state = linear_attention_step(
            r[:, 0], k[:, 0], v[:, 0], logw[:, 0], state0, u=u)
        y = y[:, None]
    else:
        y, state = chunked_linear_attention(
            r, k, v, logw, u=u, post_update=False, chunk=cfg.scan_chunk,
            initial_state=state0)
    # per-head group norm
    y = y.reshape(B, S, H, K)
    y32 = y.astype(jnp.float32)
    y = (y32 * jax.lax.rsqrt(jnp.mean(y32 * y32, -1, keepdims=True)
                             + cfg.norm_eps)).reshape(B, S, H * K)
    y = y * blk["gn_scale"].astype(jnp.float32)
    out = _proj(y.astype(x.dtype) * g, blk["wo"])
    x = x + out

    # channel mix with token shift
    xn2 = _rms(x, blk["ln2"], cfg.norm_eps)
    if cache is None or S > 1:
        prev2 = jnp.pad(xn2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev2 = cache["shift_f"][:, None, :].astype(xn2.dtype)
    xf = xn2 + (prev2 - xn2) * blk["mix_f"].astype(xn2.dtype)
    h = jnp.square(jax.nn.relu(xf @ blk["w_in"].astype(xf.dtype)))
    x = x + h @ blk["w_out"].astype(xf.dtype)
    new_cache = None if cache is None else {
        "state": state, "shift_a": xn[:, -1], "shift_f": xn2[:, -1]}
    return x, new_cache, jnp.zeros((), jnp.float32)


def _ssm_branch(blk, xn, cfg, *, cache):
    B, S, d = xn.shape
    H, N, P = cfg.n_heads, cfg.ssm_state, cfg.head_dim
    xp = _proj(xn, blk["ws_in"]).reshape(B, S, H, P)
    dt = jax.nn.softplus(_proj(xn, blk["ws_dt"]).astype(jnp.float32)
                         + blk["dt_bias"].astype(jnp.float32))      # (B,S,H)
    Bm = _proj(xn, blk["ws_B"]).reshape(B, S, H, N)
    Cm = _proj(xn, blk["ws_C"]).reshape(B, S, H, N)
    A = -jnp.exp(blk["A_log"].astype(jnp.float32))                  # (H,)
    logw = (dt * A)[..., None] * jnp.ones((1, 1, 1, N))             # (B,S,H,N)
    k = Bm.astype(jnp.float32) * dt[..., None]
    state0 = cache["ssm"] if cache is not None else None
    if S == 1 and cache is not None:
        y, state = linear_attention_step(
            Cm[:, 0], k[:, 0], xp[:, 0], logw[:, 0], state0, post_update=True)
        y = y[:, None]
    else:
        y, state = chunked_linear_attention(
            Cm, k, xp, logw, post_update=True, chunk=cfg.scan_chunk,
            initial_state=state0)
    y = y + blk["ssm_D"].astype(jnp.float32)[:, None] * xp.astype(jnp.float32)
    y = y.reshape(B, S, H * P)
    y = _rms(y.astype(xn.dtype), blk["ssm_norm"], cfg.norm_eps)
    return _proj(y, blk["ws_out"]), state


def _std_layer(blk, x, cfg, rt: Runtime, *, positions, window, cache,
               cache_pos, cross_kv):
    """Attention(+SSM branch) + MLP/MoE layer (covers 8 of 10 archs)."""
    xn = _rms(x, blk["ln1"], cfg.norm_eps)
    new_cache = {}
    if cfg.mla:
        attn, c = mla_attention_block(blk, xn, cfg, positions=positions,
                                      cache=cache, cache_pos=cache_pos,
                                      absorb=rt.mla_absorb)
        if cache is not None:
            new_cache.update(c)
    else:
        attn, c = attention_block(blk, xn, cfg, positions=positions,
                                  window=window, cache=cache,
                                  cache_pos=cache_pos)
        if cache is not None:
            new_cache.update({k: v for k, v in c.items()})
    if cfg.ssm:
        ssm_out, s = _ssm_branch(blk, xn, cfg, cache=cache)
        attn = (attn + ssm_out) * 0.5   # hymba: mean-combined parallel heads
        if cache is not None:
            new_cache["ssm"] = s
    x = x + attn
    if cross_kv is not None:
        xx = _rms(x, blk["ln_x"], cfg.norm_eps)
        xo, _ = attention_block(blk, xx, cfg, positions=positions, window=0,
                                cross_states=cross_kv, prefix="x_")
        x = x + xo
    xn2 = _rms(x, blk["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "router" in blk:
        out, aux = moe_block(blk, xn2, cfg, mesh=rt.mesh,
                             data_axes=rt.data_axes,
                             norm_topk=cfg.name != "deepseek-v2-lite-16b",
                             impl=rt.moe_impl)
    else:
        out = mlp(blk, xn2, cfg)
    x = x + out
    return x, (new_cache or None), aux


# -------------------------------------------------------------------- caches

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Dict:
    """Stacked (leading layer axis) decode cache."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    if cfg.rwkv:
        return {
            "state": jnp.zeros((L, batch, cfg.n_heads, cfg.head_dim,
                                cfg.head_dim), jnp.float32),
            "shift_a": jnp.zeros((L, batch, cfg.d_model), dtype),
            "shift_f": jnp.zeros((L, batch, cfg.d_model), dtype),
        }
    if cfg.mla:
        lat = cfg.kv_lora_rank + cfg.rope_head_dim
        return {"lat": jnp.zeros((L, batch, max_len, lat), dtype)}
    kv_shape = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_cache_int8:
        c = {"k_q": jnp.zeros(kv_shape, jnp.int8),
             "v_q": jnp.zeros(kv_shape, jnp.int8),
             "k_s": jnp.ones(kv_shape[:-1] + (1,), jnp.float32),
             "v_s": jnp.ones(kv_shape[:-1] + (1,), jnp.float32)}
    else:
        c = {"k": jnp.zeros(kv_shape, dtype),
             "v": jnp.zeros(kv_shape, dtype)}
    if cfg.ssm:
        c["ssm"] = jnp.zeros((L, batch, cfg.n_heads, cfg.ssm_state,
                              cfg.head_dim), jnp.float32)
    return c


# ------------------------------------------------------------------- forward

def _run_stack(stack_params, x, cfg, rt, *, positions, windows, cache,
               cache_pos, cross_kv, layer_fn):
    """lax.scan over stacked layers; cache (if any) is stacked alongside."""
    use_cache = cache is not None
    sp_sharding = None
    if rt.mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        # batch over data always; seq over model under sequence parallelism.
        # Constraining the scan carry anchors GSPMD propagation for every
        # layer (without it one bad reshard poisons the whole stack).
        seq_ax = "model" if rt.rules.seq_parallel else None
        sp_sharding = NamedSharding(rt.mesh,
                                    P(rt.rules.data_axes, seq_ax, None))

    cdt = jnp.dtype(cfg.dtype)

    def body(h, xs):
        blk, window, csl = xs
        if sp_sharding is not None:
            h = jax.lax.with_sharding_constraint(h, sp_sharding)
        # Cast the LAYER SLICE to compute dtype before any use, then pin the
        # order with a barrier: the convert must run on the local FSDP shard
        # so GSPMD gathers bf16 weights (gathering fp32 masters and
        # converting after doubles the all-gather wire bytes - §Perf).
        blk = jax.tree.map(
            lambda w: w.astype(cdt) if w.ndim >= 2 and
            jnp.issubdtype(w.dtype, jnp.floating) else w, blk)
        blk = jax.lax.optimization_barrier(blk)
        h, new_c, aux = layer_fn(blk, h, cfg, rt, positions=positions,
                                 window=window, cache=csl,
                                 cache_pos=cache_pos, cross_kv=cross_kv)
        return h, (new_c, aux)

    if cfg.remat:
        body = jax.checkpoint(body)
    n = jax.tree.leaves(stack_params)[0].shape[0]
    xs = (stack_params, jnp.asarray(windows[:n]), cache)
    x, (new_cache, auxs) = jax.lax.scan(body, x, xs)
    return x, new_cache if use_cache else None, jnp.sum(auxs)


def forward(params, cfg: ModelConfig, rt: Runtime, tokens: jax.Array, *,
            mode: str = "train", cache: Optional[Dict] = None,
            cache_pos=None, frontend_embeds: Optional[jax.Array] = None,
            enc_embeds: Optional[jax.Array] = None,
            cross_kv: Optional[Tuple] = None):
    """tokens: (B, S) int32.  Returns (logits, new_cache, aux_loss).

    frontend_embeds: (B, n_front, d) vision/audio stub prefix (pixtral).
    enc_embeds: (B, S_enc, d) whisper encoder input (conv-stub frames).
    cross_kv: precomputed encoder K/V for decode steps.
    """
    B, S = tokens.shape
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    if frontend_embeds is not None:   # pixtral: patch embeddings prefix
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
        S = x.shape[1]
    if rt.mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as _P
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(rt.mesh, _P(rt.rules.data_axes, None, None)))
    pos0 = jnp.asarray(cache_pos if cache_pos is not None else 0, jnp.int32)
    if pos0.ndim == 1:
        pos0 = pos0[:, None]   # per-slot depths (continuous batching)
    positions = pos0 + jnp.arange(S, dtype=jnp.int32)[None, :] \
        + jnp.zeros((B, 1), jnp.int32)
    windows = layer_windows(cfg)
    new_cache = dict(cache) if cache else None
    enc_out = None
    if new_cache is not None and "enc_out" in new_cache:
        enc_out = new_cache.pop("enc_out")   # stashed encoder states (decode)
        cross_kv = cross_kv if cross_kv is not None else enc_out
    aux_total = jnp.zeros((), jnp.float32)

    # ---- encoder (whisper): bidirectional stack over stub frame embeddings
    if cfg.arch_kind == "encdec" and enc_embeds is not None:
        e = enc_embeds.astype(x.dtype)
        epos = jnp.arange(e.shape[1], dtype=jnp.int32)[None, :] \
            + jnp.zeros((B, 1), jnp.int32)

        # bidirectional self-attention == cross-attention onto the layer's
        # own normed input (positional signal comes from the frontend stub).
        def enc_layer(blk, h, cfg_, rt_, *, positions, window, cache,
                      cache_pos, cross_kv):
            hn = _rms(h, blk["ln1"], cfg_.norm_eps)
            a, _ = attention_block(blk, hn, cfg_, positions=positions,
                                   window=0, cross_states=hn)
            h = h + a
            hn2 = _rms(h, blk["ln2"], cfg_.norm_eps)
            return h + mlp(blk, hn2, cfg_), None, jnp.zeros((), jnp.float32)

        e, _, _ = _run_stack(params["enc_layers"], e, cfg, rt, positions=epos,
                             windows=np.zeros(cfg.n_enc_layers, np.int32),
                             cache=None, cache_pos=None, cross_kv=None,
                             layer_fn=enc_layer)
        e = _rms(e, params["enc_norm"], cfg.norm_eps)
        cross_kv = e   # decoder layers project per-layer cross K/V from this

    # ---- decoder stack
    layer_fn = _std_layer
    if cfg.rwkv:
        def layer_fn(blk, h, cfg_, rt_, *, positions, window, cache,
                     cache_pos, cross_kv):
            return _rwkv_layer(blk, h, cfg_, cache=cache, pos=positions)

    if cfg.first_k_dense:
        # deepseek: leading dense layers run as their own (short) stack; the
        # stacked cache is split/recombined along the layer axis.
        if new_cache is not None:
            head_c = {k: v[: cfg.first_k_dense] for k, v in new_cache.items()}
            tail_c = {k: v[cfg.first_k_dense:] for k, v in new_cache.items()}
        else:
            head_c = tail_c = None
        x, head_c, aux0 = _run_stack(params["dense_layers"], x, cfg, rt,
                                     positions=positions, windows=windows,
                                     cache=head_c, cache_pos=cache_pos,
                                     cross_kv=None, layer_fn=layer_fn)
        x, tail_c, aux1 = _run_stack(params["layers"], x, cfg, rt,
                                     positions=positions,
                                     windows=windows[cfg.first_k_dense:],
                                     cache=tail_c, cache_pos=cache_pos,
                                     cross_kv=None, layer_fn=layer_fn)
        aux_total += aux0 + aux1
        if new_cache is not None:
            new_cache = {k: jnp.concatenate([head_c[k], tail_c[k]])
                         for k in head_c}
    else:
        xkv = cross_kv if cfg.arch_kind == "encdec" else None
        x, new_cache, aux = _run_stack(params["layers"], x, cfg, rt,
                                       positions=positions, windows=windows,
                                       cache=new_cache, cache_pos=cache_pos,
                                       cross_kv=xkv, layer_fn=layer_fn)
        aux_total += aux

    if mode == "prefill":
        x = x[:, -1:]   # serving only needs next-token logits: never build
        # the (B, S, vocab) tensor (or gather a replicated lm_head) at 32k
    x = _rms(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if new_cache is not None and cfg.arch_kind == "encdec":
        new_cache["enc_out"] = cross_kv if cross_kv is not None else enc_out
    return logits, new_cache, aux_total
