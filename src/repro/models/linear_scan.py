"""Chunked linear-attention recurrences: RWKV6 (per-channel data-dependent
decay + bonus) and SSD-style selective SSM (scalar per-head decay; hymba).

State:  S_t = diag(w_t) S_{t-1} + k_t v_t^T           (S: (K, V) per head)
RWKV6:  y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)       (pre-update + bonus)
SSD:    y_t = C_t S_t                                  (post-update)

TPU adaptation: the sequence is processed in chunks of length L (config
``scan_chunk``).  Cross-chunk flows through a length-S/L ``lax.scan`` of
(K,V) matmul updates; intra-chunk pair terms are MXU matmuls using the
factorized decay  exp(LW_i - LW_j) = exp(LW_i) * exp(-LW_j), which is
numerically safe because per-step log-decay is clamped to >= LOG_DECAY_MIN
and L * |LOG_DECAY_MIN| stays far below fp32 overflow (exp(+-88)).
Sequential depth is L + S/L instead of S (e.g. 272 for 4k at L=16).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

LOG_DECAY_MIN = -4.0   # per-step clamp; chunk<=20 keeps |exponent| < 88


def _chunk_cumsums(logw):
    """logw: (B,N,L,H,K). Returns inclusive cumsum LW and chunk totals."""
    lw = jnp.cumsum(logw, axis=2)
    return lw, lw[:, :, -1]


def chunked_linear_attention(r, k, v, logw, *, u: Optional[jax.Array] = None,
                             post_update: bool = False, chunk: int = 16,
                             initial_state: Optional[jax.Array] = None
                             ) -> Tuple[jax.Array, jax.Array]:
    """r,k: (B,S,H,K); v: (B,S,H,V); logw: (B,S,H,K) (SSD: K-broadcast).

    u: (H,K) bonus (RWKV6).  post_update: SSD semantics (y_t reads S_t).
    Returns (y (B,S,H,V), final_state (B,H,K,V)).  fp32 throughout.
    """
    B, S, H, K = k.shape
    V = v.shape[-1]
    L = min(chunk, S)
    f32 = lambda x: x.astype(jnp.float32)
    r, k, v = f32(r), f32(k), f32(v)
    logw = jnp.clip(f32(logw), LOG_DECAY_MIN, 0.0)
    S_in = S
    pad = (-S) % L
    if pad:   # identity-pad the tail: k=0 and decay=1 leave the state fixed
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        r, k, v, logw = zpad(r), zpad(k), zpad(v), zpad(logw)
        S += pad
    N = S // L

    rs = r.reshape(B, N, L, H, K)
    ks = k.reshape(B, N, L, H, K)
    vs = v.reshape(B, N, L, H, V)
    lws = logw.reshape(B, N, L, H, K)
    lw, lw_tot = _chunk_cumsums(lws)          # inclusive; (B,N,L,H,K),(B,N,H,K)
    lw_exc = lw - lws                          # exclusive (before step t)

    # per-chunk state contribution  U_n = sum_j exp(lw_tot - lw_j) k_j v_j^T
    k_dec = ks * jnp.exp(lw_tot[:, :, None] - lw)
    U = jnp.einsum("bnlhk,bnlhv->bnhkv", k_dec, vs)

    # inter-chunk scan: S_{n+1} = exp(lw_tot_n) * S_n + U_n ; collect starts
    S0 = jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None \
        else f32(initial_state)

    def step(s, xs):
        tot, u_n = xs
        return jnp.exp(tot)[..., None] * s + u_n, s   # ys = state at chunk start

    lw_tot_t = jnp.moveaxis(lw_tot, 1, 0)     # (N,B,H,K)
    U_t = jnp.moveaxis(U, 1, 0)               # (N,B,H,K,V)
    final_state, S_starts = jax.lax.scan(step, S0, (lw_tot_t, U_t))
    S_starts = jnp.moveaxis(S_starts, 0, 1)   # (B,N,H,K,V)

    # query-side cumulative decay: exclusive for RWKV (pre-update output),
    # inclusive for SSD (post-update output)
    lq = lw if post_update else lw_exc

    # cross-chunk term: (r_i * exp(lq_i)) . S_start
    r_dec = rs * jnp.exp(lq)
    y_cross = jnp.einsum("bnlhk,bnhkv->bnlhv", r_dec, S_starts)

    # intra-chunk pair term: A_ij = sum_k r_ik e^{lq_i} * k_jk e^{-lw_j}
    k_idec = ks * jnp.exp(-lw)
    A = jnp.einsum("bnlhk,bnmhk->bnhlm", r_dec, k_idec)  # (B,N,H,L,L)
    i_idx = jnp.arange(L)[:, None]
    j_idx = jnp.arange(L)[None, :]
    mask = (j_idx <= i_idx) if post_update else (j_idx < i_idx)
    A = jnp.where(mask[None, None, None], A, 0.0)
    y_intra = jnp.einsum("bnhlm,bnmhv->bnlhv", A, vs)

    y = y_cross + y_intra
    if u is not None:   # RWKV6 bonus: diagonal term with u instead of decay
        diag = jnp.einsum("bnlhk,hk,bnlhk->bnlh", rs, f32(u), ks)
        y = y + diag[..., None] * vs
    return y.reshape(B, S, H, V)[:, :S_in], final_state


def linear_attention_step(r, k, v, logw, state, *, u=None,
                          post_update: bool = False):
    """Single-token decode.  r,k: (B,H,K); v: (B,H,V); state (B,H,K,V)."""
    f32 = lambda x: x.astype(jnp.float32)
    r, k, v = f32(r), f32(k), f32(v)
    w = jnp.exp(jnp.clip(f32(logw), LOG_DECAY_MIN, 0.0))
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    new_state = w[..., None] * state + kv
    read = new_state if post_update else state
    y = jnp.einsum("bhk,bhkv->bhv", r, read)
    if u is not None:
        y = y + jnp.einsum("bhk,hk->bh", r * k, f32(u))[..., None] * v
    return y, new_state
