"""Parameter templates: one source of truth for shapes, logical axes, init.

``template(cfg)`` returns a pytree of ``ParamMeta`` describing every weight.
``init_params`` materializes it; ``abstract_params`` gives ShapeDtypeStructs
(for the dry-run); ``sharding.tree_pspecs`` maps the logical axes to mesh
PartitionSpecs.  Layer blocks are stacked along a leading "layers" axis so the
stacks are consumed by ``lax.scan`` (constant compile time in depth).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    axes: Axes                 # logical axis names, len == len(shape)
    init: str = "normal"       # normal | zeros | ones
    scale: float = 1.0         # stddev multiplier for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes)


def _norm(d: int) -> ParamMeta:
    return ParamMeta((d,), (None,), "ones")


def _dense(fan_in: int, fan_out: int, axes: Axes, scale: float = 1.0) -> ParamMeta:
    return ParamMeta((fan_in, fan_out), axes, "normal", scale / np.sqrt(fan_in))


def _attention_block(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamMeta]:
    d, hd = cfg.d_model, cfg.head_dim
    q_dim, kv_dim = cfg.q_dim, cfg.kv_dim
    if cfg.mla and not cross:
        lora, r = cfg.kv_lora_rank, cfg.rope_head_dim
        blk = {
            "wq": _dense(d, cfg.n_heads * (hd + r), ("embed", "heads")),
            "w_dkv": _dense(d, lora + r, ("embed", None)),
            "kv_norm": _norm(lora),
            "w_uk": _dense(lora, cfg.n_heads * hd, ("kv_lora", "heads")),
            "w_uv": _dense(lora, cfg.n_heads * hd, ("kv_lora", "heads")),
            "wo": _dense(q_dim, d, ("heads", "embed")),
        }
        return blk
    blk = {
        "wq": _dense(d, q_dim, ("embed", "heads")),
        # kv projections get their own logical axis: replicated when
        # n_kv_heads doesn't divide the model axis (a ragged shard would
        # force GSPMD partial-sum all-reduces over sub-head groups - §Perf)
        "wk": _dense(d, kv_dim, ("embed", "kv_heads")),
        "wv": _dense(d, kv_dim, ("embed", "kv_heads")),
        "wo": _dense(q_dim, d, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        blk["bq"] = ParamMeta((q_dim,), ("heads",), "zeros")
        blk["bk"] = ParamMeta((kv_dim,), ("kv_heads",), "zeros")
        blk["bv"] = ParamMeta((kv_dim,), ("kv_heads",), "zeros")
    return blk


def _mlp_block(cfg: ModelConfig, d_ff: int) -> Dict[str, ParamMeta]:
    d = cfg.d_model
    blk = {"w_in": _dense(d, d_ff, ("embed", "mlp")),
           "w_out": _dense(d_ff, d, ("mlp", "embed"))}
    if cfg.mlp_act.endswith("_glu"):
        blk["w_gate"] = _dense(d, d_ff, ("embed", "mlp"))
    return blk


def _moe_block(cfg: ModelConfig) -> Dict[str, ParamMeta]:
    d, E, fe = cfg.d_model, cfg.n_experts, cfg.d_expert
    glu = cfg.mlp_act.endswith("_glu")
    s = 1.0 / np.sqrt(d)
    blk = {
        "router": _dense(d, E, ("embed", None)),
        "we_in": ParamMeta((E, d, fe), ("expert", "embed", "mlp"), "normal", s),
        "we_out": ParamMeta((E, fe, d), ("expert", "mlp", "embed"), "normal",
                            1.0 / np.sqrt(fe)),
    }
    if glu:
        blk["we_gate"] = ParamMeta((E, d, fe), ("expert", "embed", "mlp"),
                                   "normal", s)
    if cfg.n_shared_experts:
        blk.update({f"shared_{k}": v for k, v in
                    _mlp_block(cfg, cfg.n_shared_experts * fe).items()})
    return blk


def _rwkv_block(cfg: ModelConfig) -> Dict[str, ParamMeta]:
    d, a = cfg.d_model, cfg.q_dim
    blk = {
        "ln1": _norm(d),
        # static token-shift mixing coefficients (RWKV6 uses LoRA-modulated
        # mixing; we keep the decay LoRA data-dependent, mixing static).
        **{f"mix_{n}": ParamMeta((d,), (None,), "zeros") for n in "rkvgw"},
        "w_r": _dense(d, a, ("embed", "heads")),
        "w_k": _dense(d, a, ("embed", "heads")),
        "w_v": _dense(d, a, ("embed", "heads")),
        "w_g": _dense(d, a, ("embed", "heads")),
        "decay_a": _dense(d, 64, ("embed", None)),
        "decay_b": _dense(64, a, (None, "heads")),
        "decay_base": ParamMeta((a,), ("heads",), "zeros"),
        "bonus_u": ParamMeta((a,), ("heads",), "zeros"),
        "gn_scale": ParamMeta((a,), ("heads",), "ones"),
        "wo": _dense(a, d, ("heads", "embed")),
        "ln2": _norm(d),
        "mix_f": ParamMeta((d,), (None,), "zeros"),
        **_mlp_block(cfg, cfg.d_ff),
    }
    return blk


def _ssm_block(cfg: ModelConfig) -> Dict[str, ParamMeta]:
    """SSD-style selective SSM branch (hymba's mamba heads, state N)."""
    d, H, N = cfg.d_model, cfg.n_heads, cfg.ssm_state
    P = cfg.head_dim
    return {
        "ws_in": _dense(d, H * P, ("embed", "heads")),
        "ws_dt": _dense(d, H, ("embed", "heads")),
        "dt_bias": ParamMeta((H,), ("heads",), "zeros"),
        "ws_B": _dense(d, H * N, ("embed", "heads")),
        "ws_C": _dense(d, H * N, ("embed", "heads")),
        "A_log": ParamMeta((H,), ("heads",), "zeros"),
        "ssm_D": ParamMeta((H,), ("heads",), "ones"),
        "ssm_norm": _norm(H * P),
        "ws_out": _dense(H * P, d, ("heads", "embed")),
    }


def _decoder_layer(cfg: ModelConfig, moe: bool) -> Dict[str, ParamMeta]:
    if cfg.rwkv:
        return _rwkv_block(cfg)
    blk = {"ln1": _norm(cfg.d_model), **_attention_block(cfg),
           "ln2": _norm(cfg.d_model)}
    if moe:
        blk.update(_moe_block(cfg))
    else:
        blk.update(_mlp_block(cfg, cfg.dense_d_ff if cfg.first_k_dense and not moe
                              and cfg.n_experts else cfg.d_ff))
    if cfg.ssm:
        blk.update(_ssm_block(cfg))
    if cfg.arch_kind == "encdec":
        blk.update({"ln_x": _norm(cfg.d_model)})
        blk.update({f"x_{k}": v for k, v in
                    _attention_block(cfg, cross=True).items()})
    return blk


def _encoder_layer(cfg: ModelConfig) -> Dict[str, ParamMeta]:
    return {"ln1": _norm(cfg.d_model), **_attention_block(cfg, cross=True),
            "ln2": _norm(cfg.d_model), **_mlp_block(cfg, cfg.d_ff)}


def template(cfg: ModelConfig) -> Dict:
    """Full parameter template.  Layer dicts are *unstacked*; `stacked_axes`
    marks which top-level entries carry a leading layer axis."""
    n_moe_layers = cfg.n_layers - cfg.first_k_dense if cfg.n_experts else 0
    tpl = {
        "embed": ParamMeta((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           "normal", 1.0),
        "final_norm": _norm(cfg.d_model),
        "layers": _decoder_layer(cfg, moe=bool(cfg.n_experts)),
    }
    if not cfg.tie_embeddings:
        tpl["lm_head"] = _dense(cfg.d_model, cfg.vocab, ("embed", "vocab"))
    if cfg.first_k_dense:
        tpl["dense_layers"] = _decoder_layer(cfg, moe=False)
    if cfg.arch_kind == "encdec":
        tpl["enc_layers"] = _encoder_layer(cfg)
        tpl["enc_norm"] = _norm(cfg.d_model)
    return tpl


def stack_counts(cfg: ModelConfig) -> Dict[str, int]:
    out = {"layers": cfg.n_layers - cfg.first_k_dense}
    if cfg.first_k_dense:
        out["dense_layers"] = cfg.first_k_dense
    if cfg.arch_kind == "encdec":
        out["enc_layers"] = cfg.n_enc_layers
    return out


def _finalize(cfg: ModelConfig, leaf_fn) -> Dict:
    """Apply leaf_fn(meta, stacked_n) over the template with layer stacking."""
    tpl = template(cfg)
    stacks = stack_counts(cfg)
    out = {}
    for key, sub in tpl.items():
        n = stacks.get(key)
        if isinstance(sub, dict):
            out[key] = {k: leaf_fn(m, n) for k, m in sub.items()}
        else:
            out[key] = leaf_fn(sub, None)
    return out


def abstract_params(cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)

    def leaf(meta: ParamMeta, n):
        shape = ((n,) + meta.shape) if n else meta.shape
        return jax.ShapeDtypeStruct(shape, dtype)
    return _finalize(cfg, leaf)


def logical_axes(cfg: ModelConfig) -> Dict:
    def leaf(meta: ParamMeta, n):
        return (("layers",) + meta.axes) if n else meta.axes
    return _finalize(cfg, leaf)


def init_params(key: jax.Array, cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    metas, treedef = jax.tree.flatten(
        _finalize(cfg, lambda m, n: (m, n)),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], ParamMeta))
    keys = jax.random.split(key, len(metas))

    def materialize(k, meta_n):
        meta, n = meta_n
        shape = ((n,) + meta.shape) if n else meta.shape
        if meta.init == "zeros":
            return jnp.zeros(shape, dtype)
        if meta.init == "ones":
            return jnp.ones(shape, dtype)
        return (jax.random.normal(k, shape, jnp.float32) * meta.scale).astype(dtype)

    leaves = [materialize(k, m) for k, m in zip(keys, metas)]
    return jax.tree.unflatten(treedef, leaves)
