"""Pad a list of DVBP ``Instance``s into one batched event tensor.

Padding convention (consumed by ``repro.sweep.runner`` and documented for
anyone adding lanes):

  * **Items** are padded to ``n_max = max(n_items)``.  Padded item rows have
    zero size vectors and pdep 0; they never appear in the event stream, so
    they are never placed (their output placement stays ``-1``).
  * **Dimensions** are zero-padded to ``d_max = max(d)``.  ``dmask[b, k]`` is
    1.0 for real dimensions of lane ``b`` and 0.0 for padding.  Zero-size
    padded dims are trivially feasible; the replay's best-fit scores mask
    them out via ``dmask`` so residual norms are computed over real dims only.
  * **Events** are padded to ``2 n_max`` *at the end* with
    ``kind == jaxsim.PAD_KIND`` (-1), item index 0, and a time strictly after
    the lane's last real event.  Pad events are no-ops in the scan (the carry
    passes through), so a short lane finishes its replay and then idles.

Each lane's real event prefix is produced by ``jaxsim.event_sequence`` -
identical ordering semantics (departures before arrivals at equal times) to
the single-instance ``simulate()`` path.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from ..core.jaxsim import PAD_KIND, event_sequence
from ..core.types import Instance

# Per-instance event sequences keyed by content digest: the host-side
# lexsort in ``jaxsim.event_sequence`` is the only O(n log n) step of
# packing, and repeated ``Experiment.run()`` cells (or different suites
# sharing instances) re-sort identical instances otherwise.  This extends
# the per-suite built-suite cache in ``sweep.grid`` one level down - a
# *content* key, so it hits even when the instances arrive via different
# suite specs.  LRU bounded by entry count AND total bytes (uncapped
# azure_trace instances hold ~MBs of event arrays each - an entry-count
# bound alone could pin GBs for the process lifetime).  Hit/miss/byte
# stats live on the obs counter registry (``pack.evseq_hit`` /
# ``pack.evseq_miss`` / ``pack.evseq_bytes``) - the byte gauge doubles as
# the eviction bound, so the counters are the single definition site.
_EVSEQ_CACHE: "OrderedDict[str, Tuple]" = OrderedDict()
_EVSEQ_CACHE_MAX = 4096
_EVSEQ_CACHE_MAX_BYTES = 256 * 1024 * 1024


def _evseq_nbytes(val) -> int:
    return sum(a.nbytes for a in val)


def instance_digest(inst: Instance) -> str:
    """Content digest of one instance (sizes, arrivals, departures)."""
    h = hashlib.blake2b(digest_size=16)
    for a in (inst.sizes, inst.arrivals, inst.departures):
        a = np.ascontiguousarray(a)
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def event_sequence_cached(inst: Instance):
    """``jaxsim.event_sequence`` memoized on the instance content digest."""
    key = instance_digest(inst)
    hit = _EVSEQ_CACHE.get(key)
    if hit is not None:
        _EVSEQ_CACHE.move_to_end(key)
        obs.counter_add("pack.evseq_hit")
        return hit
    obs.counter_add("pack.evseq_miss")
    val = event_sequence(inst)
    _EVSEQ_CACHE[key] = val
    obs.counter_add("pack.evseq_bytes", _evseq_nbytes(val))
    while len(_EVSEQ_CACHE) > _EVSEQ_CACHE_MAX or \
            (obs.counter_get("pack.evseq_bytes") > _EVSEQ_CACHE_MAX_BYTES
             and len(_EVSEQ_CACHE) > 1):
        _, old = _EVSEQ_CACHE.popitem(last=False)
        obs.counter_add("pack.evseq_bytes", -_evseq_nbytes(old))
    return val


@dataclasses.dataclass(frozen=True)
class InstanceBatch:
    """Struct-of-padded-arrays view of ``B`` instances (see module doc)."""

    sizes: np.ndarray     # (B, n_max, d_max) float32-safe
    arrivals: np.ndarray  # (B, n_max)  padded with 0
    pdeps: np.ndarray     # (B, n_max)  real departures; padded with 0
    times: np.ndarray     # (B, 2 n_max)
    kinds: np.ndarray     # (B, 2 n_max) int32: 1 arrival / 0 departure / -1 pad
    items: np.ndarray     # (B, 2 n_max) int32
    dmask: np.ndarray     # (B, d_max) float: 1.0 real dim, 0.0 padding
    n_items: np.ndarray   # (B,) int32 real item counts
    names: tuple          # (B,) instance names

    @property
    def B(self) -> int:
        return self.sizes.shape[0]

    @property
    def n_max(self) -> int:
        return self.sizes.shape[1]

    @property
    def d_max(self) -> int:
        return self.sizes.shape[2]


def pack_instances(instances: Sequence[Instance]) -> InstanceBatch:
    assert len(instances) > 0, "cannot pack an empty instance list"
    with obs.span("pack.instances", B=len(instances)):
        return _pack_instances(instances)


def _pack_instances(instances: Sequence[Instance]) -> InstanceBatch:
    B = len(instances)
    n_max = max(i.n_items for i in instances)
    d_max = max(i.d for i in instances)

    sizes = np.zeros((B, n_max, d_max))
    arrivals = np.zeros((B, n_max))
    pdeps = np.zeros((B, n_max))
    times = np.zeros((B, 2 * n_max))
    kinds = np.full((B, 2 * n_max), PAD_KIND, np.int32)
    items = np.zeros((B, 2 * n_max), np.int32)
    dmask = np.zeros((B, d_max))
    n_items = np.zeros(B, np.int32)

    for b, inst in enumerate(instances):
        n, d = inst.n_items, inst.d
        sizes[b, :n, :d] = inst.sizes
        arrivals[b, :n] = inst.arrivals
        pdeps[b, :n] = inst.departures
        t, k, j = event_sequence_cached(inst)
        times[b, :2 * n] = t
        kinds[b, :2 * n] = k
        items[b, :2 * n] = j
        # pad events idle *after* the lane's replay; finite time avoids
        # inf arithmetic in the (discarded) no-op branches
        times[b, 2 * n:] = (t[-1] if n else 0.0) + 1.0
        dmask[b, :d] = 1.0
        n_items[b] = n
    return InstanceBatch(sizes, arrivals, pdeps, times, kinds, items, dmask,
                         n_items, tuple(i.name for i in instances))


def pad_predictions(batch: InstanceBatch,
                    predicted_durations: Sequence[Optional[np.ndarray]]
                    ) -> np.ndarray:
    """Stack per-lane predicted-duration arrays into pdeps of shape
    ``(B, S, n_max)`` (predicted departure = arrival + predicted duration).

    Each element of ``predicted_durations`` is, for its lane, either
      * ``None`` - clairvoyant/non-clairvoyant: real departures, or
      * ``(n_b,)`` - one prediction set, or
      * ``(S, n_b)`` - one prediction set per seed.
    All lanes must agree on ``S`` (None counts as any S: it broadcasts).
    """
    assert len(predicted_durations) == batch.B
    S = 1
    for p in predicted_durations:
        if p is not None and np.asarray(p).ndim == 2:
            S = max(S, np.asarray(p).shape[0])
    out = np.zeros((batch.B, S, batch.n_max))
    for b, p in enumerate(predicted_durations):
        n = int(batch.n_items[b])
        if p is None:
            out[b, :, :n] = batch.pdeps[b, :n]
            continue
        p = np.asarray(p)
        if p.ndim == 1:
            p = p[None, :]
        assert p.shape[0] in (1, S), \
            f"lane {b}: {p.shape[0]} seed rows, batch has {S}"
        assert p.shape[1] == n, f"lane {b}: {p.shape[1]} != {n} items"
        out[b, :, :n] = batch.arrivals[b, None, :n] + p
    return out


def instances_pdeps(batch: InstanceBatch) -> np.ndarray:
    """Default (B, 1, n_max) pdeps tensor: the real departures."""
    return batch.pdeps[:, None, :]
