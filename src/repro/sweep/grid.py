"""Declarative experiment grids: suites x policies x prediction models x
seeds, expanded to batched runs and aggregated into performance ratios.

A ``SweepSpec`` is a frozen, canonically-hashable description of the whole
grid (the paper's empirical section is one such grid: {Azure-like +
Huawei-like suites} x {policies} x {prediction-noise levels} x {seeds}).
Policies may be any ``jaxsim.SCAN_POLICIES`` name - the score-based family
AND the category-structured families (CBD/CBDT, Hybrid variants, RCP/PPE,
Lifetime Alignment, adaptive) all replay as batched lanes.
``run_sweep`` expands it, drives ``runner.run_batch`` once per
(suite, policy, prediction model), divides per-instance usage by the Eq.(1)
lower bound, and - when given a ``SweepStore`` - skips any (suite, policy,
prediction) group whose records are already persisted, so repeated sweeps
are incremental.

This module is the grid *engine*; the public experiment surface is
``repro.api`` (Workload / Policy / Setting / Experiment), which builds
``SweepSpec``s - suites and prediction models only need the duck shape
used here (``build()`` / ``label()`` / ``n_instances``, resp. ``noisy`` /
``label()`` / ``durations()``) and must be dataclasses so the canonical
spec hash covers them; that is how the api's serving-request and prebuilt
-instance workloads ride the same store with unchanged ``result_key``s.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from ..consolidate import ConsolidationSpec
from ..core import (BoxStats, lognormal_predictions_batch, lower_bound,
                    uniform_predictions_batch)
from ..core.jaxsim import MAX_BINS_CAP, POLICIES, known_policy
from ..core.types import Instance
from ..data import (load_azure_csv, make_azure_like_suite,
                    make_huawei_like_suite)
from .batching import pack_instances, pad_predictions

PRED_KINDS = ("none", "clairvoyant", "lognormal", "uniform")


@dataclasses.dataclass(frozen=True)
class SuiteSpec:
    """One instance family: which generator, how many instances, how big.

    ``family="azure_trace"`` loads the *real* Azure Packing2020 dump from
    ``trace_root`` (see ``data.load_azure_csv``) instead of generating
    synthetic instances: ``n_instances`` caps how many per-PM instances
    enter the suite and ``n_items`` caps items per instance (0 = no cap).
    Building raises ``FileNotFoundError`` when the dump is absent, so real
    -trace suites only enter sweeps when the data is actually present."""

    family: str = "azure"      # "azure" | "huawei" | "azure_trace"
    n_instances: int = 6
    n_items: int = 500
    seed: int = 2026
    trace_root: str = "data/azure"   # only read by family="azure_trace"

    def build(self) -> List[Instance]:
        if self.family == "azure":
            return make_azure_like_suite(self.n_instances, self.n_items,
                                         self.seed)
        if self.family == "huawei":
            return make_huawei_like_suite(self.n_instances, self.n_items,
                                          self.seed)
        if self.family == "azure_trace":
            insts = load_azure_csv(self.trace_root)
            if insts is None:
                raise FileNotFoundError(
                    f"no Azure Packing2020 dump under {self.trace_root!r} "
                    "(expected vmtype.csv + vmrequest.csv)")
            insts = insts[:self.n_instances] if self.n_instances else insts
            if self.n_items:
                insts = [i.subset(np.arange(i.n_items) < self.n_items)
                         for i in insts]
            return insts
        raise ValueError(f"unknown suite family {self.family!r}")

    def label(self) -> str:
        return f"{self.family}-{self.n_instances}x{self.n_items}-s{self.seed}"


@dataclasses.dataclass(frozen=True)
class PredModel:
    """Prediction setting for the grid.

    kind:
      * "none"        - non-clairvoyant replay (score-based policies ignore
                        pdeps; prediction-requiring ones see real departures)
      * "clairvoyant" - perfect predictions (pdur == real duration)
      * "lognormal"   - delta ~ LogNormal(0, param)    (param == sigma)
      * "uniform"     - delta ~ U[1, param], fair coin (param == eps)
    """

    kind: str = "clairvoyant"
    param: float = 0.0

    def __post_init__(self):
        assert self.kind in PRED_KINDS, self.kind

    @property
    def noisy(self) -> bool:
        return self.kind in ("lognormal", "uniform")

    def label(self) -> str:
        if self.kind == "lognormal":
            return f"lognormal{self.param:g}"
        if self.kind == "uniform":
            return f"uniform{self.param:g}"
        return self.kind

    def durations(self, inst: Instance,
                  seeds: Sequence[int]) -> Optional[np.ndarray]:
        """(n_seeds, n_items) predicted durations, or None for the exact
        (real departures) settings."""
        if self.kind == "lognormal":
            return lognormal_predictions_batch(inst, self.param, seeds)
        if self.kind == "uniform":
            return uniform_predictions_batch(inst, self.param, seeds)
        return None


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """The full declarative grid."""

    suites: Tuple[SuiteSpec, ...] = (SuiteSpec(),)
    policies: Tuple[str, ...] = POLICIES
    predictions: Tuple[PredModel, ...] = (PredModel("clairvoyant"),)
    seeds: Tuple[int, ...] = (0,)        # used by noisy prediction models
    max_bins: int = 64                   # initial slot pool per lane
    max_bins_cap: int = 8192             # escalation ladder ceiling
    consolidations: Tuple[ConsolidationSpec, ...] = (ConsolidationSpec(),)

    def __post_init__(self):
        for p in self.policies:
            assert known_policy(p), f"{p!r} is not a jaxsim scan policy"
        assert self.max_bins_cap <= MAX_BINS_CAP

    def canonical(self) -> Dict:
        blob = dataclasses.asdict(self)
        # the consolidation axis enters the hash only when ON: a spec with
        # every consolidation disabled hashes exactly as before the axis
        # existed, so old stores stay addressable
        cons = [c.canonical() for c in self.consolidations if c.enabled]
        if cons:
            blob["consolidations"] = cons
        else:
            blob.pop("consolidations")
        return blob

    def spec_hash(self) -> str:
        blob = json.dumps(self.canonical(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def suites_hash(self) -> str:
        """Hash of the *instances* only.  Results are keyed per
        (instance, policy, pred, seed) and do not depend on the rest of the
        spec (max_bins only sets the escalation start), so specs sharing
        suites share a store file - extending policies/predictions/seeds
        reuses every cached group."""
        blob = json.dumps([dataclasses.asdict(s) for s in self.suites],
                          sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


def result_key(suite: SuiteSpec, instance_name: str, policy: str,
               pred: PredModel, seed: int,
               cons: Optional[ConsolidationSpec] = None) -> str:
    key = (f"{suite.label()}/{instance_name}/{policy}/"
           f"{pred.label()}/seed{seed}")
    if cons is not None and cons.enabled:
        key += f"/{cons.canonical()}"
    return key


def _group_cached(records: Dict[str, Dict], suite: SuiteSpec, policy: str,
                  pred: PredModel, seeds: Sequence[int],
                  cons: ConsolidationSpec = ConsolidationSpec()) -> bool:
    """True when every (instance, seed) record of the group is present -
    checked from record fields so cached suites need not be rebuilt.
    Suites with an uncounted size (n_instances == 0: uncapped trace
    suites) can never be proven complete without building, so they always
    recompute.  Records predating the consolidation axis carry no
    ``consolidate`` field and count as ``"none"``."""
    expected = suite.n_instances * len(seeds)
    if expected <= 0:
        return False
    have = sum(1 for r in records.values()
               if r["suite"] == suite.label() and r["policy"] == policy
               and r["pred"] == pred.label() and r["seed"] in seeds
               and r.get("consolidate", "none") == cons.canonical())
    return have >= expected


def _cell_label(policy: str, cons: ConsolidationSpec) -> str:
    return f"{policy}+{cons.canonical()}" if cons.enabled else policy


# Built suites are deterministic functions of their (hashed) spec, so the
# expensive prep - instance generation / trace load, Eq.(1) lower bounds,
# event-tensor packing - is shared across run_sweep calls in one process
# (the api facade issues one call per Experiment cell).  Bounded so giant
# trace suites do not accumulate.
_SUITE_CACHE: "OrderedDict[str, Tuple]" = OrderedDict()
_SUITE_CACHE_MAX = 4


def _built_suite(suite):
    """(instances, lower bounds, packed batch) for one suite, cached."""
    key = json.dumps(dataclasses.asdict(suite), sort_keys=True)
    if key in _SUITE_CACHE:
        _SUITE_CACHE.move_to_end(key)
        obs.counter_add("sweep.suite_cache_hit")
        return _SUITE_CACHE[key]
    obs.counter_add("sweep.suite_cache_miss")
    with obs.span("suite.build", suite=suite.label()):
        insts = suite.build()
        built = (insts, [lower_bound(i) for i in insts],
                 pack_instances(insts))
    _SUITE_CACHE[key] = built
    while len(_SUITE_CACHE) > _SUITE_CACHE_MAX:
        _SUITE_CACHE.popitem(last=False)
    return built


def run_sweep(spec: SweepSpec, store=None, force: bool = False,
              progress=None, backend: Optional[str] = None,
              shard: str = "auto", block_events: int = 0,
              trace_level: int = 0,
              traces: Optional[Dict] = None,
              checkpoint_dir: Optional[str] = None,
              checkpoint_every: int = 2048,
              host_index: Optional[int] = None,
              host_count: Optional[int] = None) -> Dict[str, Dict]:
    """Expand and run the grid; returns {result_key: record}.

    ``backend`` / ``shard`` / ``block_events`` pick the replay engine, lane
    sharding and event-block size (see ``runner.run_batch``); they affect
    *how* the grid is computed, never the results (the backends are
    bit-identical on fp32-exact instances), so they are execution arguments
    rather than part of the hashed spec - records computed on any backend
    share the store.

    ``trace_level`` >= 1 additionally captures per-event replay decision
    traces (``obs.ReplayTrace``): pass a dict as ``traces`` and it is
    filled with one single-lane trace per ``result_key``.  Traced groups
    always recompute (the trace only exists by replaying), so the cached
    -group skip is bypassed; records still land in the store as usual.

    ``checkpoint_dir`` turns on checkpointed replay: the scan carry is
    snapshotted every ``checkpoint_every`` events
    (``resilience.checkpoint``), so a killed sweep resumed over the same
    spec continues mid-scan bit-identically - the store-group journal
    already makes whole completed groups resumable; checkpoints make the
    *current* group resumable too.  The CLI's ``--resume`` is sugar for
    a checkpoint dir next to the store.

    ``host_index`` / ``host_count`` shard the grid across processes: every
    host enumerates the identical (suite, pred, policy, consolidation)
    cell sequence and runs only cells with ``cell_no % host_count ==
    host_index``.  Each host journals its groups into the shared store
    (``SweepStore`` merges under an exclusive lock), so N partial runs
    converge to exactly the single-process record set - the
    ``python -m repro sweep --hosts N`` launcher is sugar for N such
    processes.  Results are per-cell independent, so the partition never
    changes them.

    record schema (also persisted by SweepStore, see sweep/README.md):
      usage_time, lower_bound, ratio, n_bins_opened, overflowed, max_bins,
      suite, instance, policy, pred, seed
    Consolidating cells (``spec.consolidations`` entries with
    ``enabled``) additionally carry ``consolidate`` (the canonical spec
    string), ``migrations`` and ``migration_cost``; disabled cells keep
    the legacy schema byte-for-byte.
    """
    say = progress or (lambda *_: None)
    from ..resilience import faults
    from ..resilience.checkpoint import ReplayCheckpointer
    from .runner import run_batch   # local import keeps grid importable fast

    ckpt = None
    if checkpoint_dir is not None:
        ckpt = ReplayCheckpointer(checkpoint_dir,
                                  every_events=checkpoint_every)

    if host_count is not None:
        host_count = int(host_count)
        host_index = int(host_index or 0)
        assert 0 <= host_index < host_count, (host_index, host_count)

    records: Dict[str, Dict] = {}
    if store is not None and not force:
        with obs.span("store.load", spec=spec.suites_hash()):
            records.update(store.load(spec))
        obs.counter_add("store.load")

    cell_no = -1   # global cell counter: identical on every host
    for suite in spec.suites:
        insts = lbs = batch = None   # built lazily: cached suites stay free
        for pred in spec.predictions:
            seeds = tuple(spec.seeds) if pred.noisy else (spec.seeds[0],)
            cells = [(p, cons) for p in spec.policies
                     for cons in spec.consolidations]
            mine = []
            for c in cells:
                cell_no += 1
                if host_count is None or cell_no % host_count == host_index:
                    mine.append(c)
            todo = [(p, cons) for p, cons in mine
                    if trace_level
                    or not _group_cached(records, suite, p, pred, seeds,
                                         cons)]
            for p, cons in mine:
                if (p, cons) not in todo:
                    say(f"skip {suite.label()}/{_cell_label(p, cons)}/"
                        f"{pred.label()} (cached)")
                    obs.counter_add("experiment.cache_hit")
            if not todo:
                continue
            if insts is None:
                insts, lbs, batch = _built_suite(suite)
            with obs.span("sweep.pad", suite=suite.label(),
                          pred=pred.label()):
                pdeps = pad_predictions(
                    batch, [pred.durations(i, seeds) for i in insts])
            for policy, cons in todo:
                say(f"run  {suite.label()}/{_cell_label(policy, cons)}/"
                    f"{pred.label()} B={batch.B} S={len(seeds)}")
                obs.counter_add("experiment.cache_miss")
                faults.fire("sweep.group")
                ckpt_key = "-".join(
                    (spec.suites_hash(), suite.label(),
                     _cell_label(policy, cons), pred.label()))
                res = run_batch(batch, policy, pdeps, spec.max_bins,
                                spec.max_bins_cap, backend=backend,
                                shard=shard, block_events=block_events,
                                trace_level=trace_level,
                                checkpoint=ckpt, checkpoint_key=ckpt_key,
                                consolidate=cons if cons.enabled else None)
                if traces is not None and res.trace is not None:
                    S = len(seeds)
                    for bi, inst in enumerate(insts):
                        for si, seed in enumerate(seeds):
                            traces[result_key(suite, inst.name, policy,
                                              pred, seed, cons)] = \
                                res.trace.lane(bi * S + si)
                group_recs = {}
                for bi, inst in enumerate(insts):
                    for si, seed in enumerate(seeds):
                        rec = {
                            "suite": suite.label(),
                            "instance": inst.name,
                            "policy": policy,
                            "pred": pred.label(),
                            "seed": int(seed),
                            "usage_time": float(res.usage_time[bi, si]),
                            "lower_bound": float(lbs[bi]),
                            "ratio": float(res.usage_time[bi, si] / lbs[bi])
                            if lbs[bi] > 0 else float("inf"),
                            "n_bins_opened": int(res.n_bins_opened[bi, si]),
                            "overflowed": bool(res.overflowed[bi, si]),
                            "max_bins": int(res.max_bins[bi]),
                        }
                        if cons.enabled:
                            rec["consolidate"] = cons.canonical()
                            rec["migrations"] = \
                                int(res.migrations[bi, si])
                            rec["migration_cost"] = \
                                float(res.migration_cost[bi, si])
                        group_recs[result_key(suite, inst.name, policy,
                                              pred, seed, cons)] = rec
                records.update(group_recs)
                if store is not None:
                    with obs.span("store.save", spec=spec.suites_hash()):
                        # the group delta is journaled before the main
                        # rewrite, so a crash mid-save loses nothing
                        store.save(spec, records, group_records=group_recs)
                    obs.counter_add("store.save")
    return records


def summarize_sweep(records: Dict[str, Dict]) -> Dict[Tuple[str, str],
                                                      BoxStats]:
    """(policy, pred label) -> BoxStats over per-(instance, seed) ratios.
    Consolidating records summarize under ``policy+consspec`` so the
    consolidated and plain variants of a policy stay separate rows."""
    groups: Dict[Tuple[str, str], List[float]] = {}
    for rec in records.values():
        pol = rec["policy"]
        cons = rec.get("consolidate", "none")
        if cons != "none":
            pol = f"{pol}+{cons}"
        groups.setdefault((pol, rec["pred"]), []).append(rec["ratio"])
    return {k: BoxStats.from_ratios(v) for k, v in sorted(groups.items())}
