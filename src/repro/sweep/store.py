"""Persist sweep results as JSON keyed by the spec's *suites* hash.

One file per instance family set under the store root (default
``experiments/sweeps/``), named ``sweep_<suites_hash>.json``:

    {
      "schema": 1,
      "suites_hash": "<16 hex chars>",
      "spec": { ...canonical spec of the last run that wrote the file... },
      "results": { "<result_key>": { ...record... }, ... }
    }

Results are keyed per (suite, instance, policy, prediction model, seed) and
depend only on the suites, so specs that share suites share a file: an
interrupted sweep resumes, and an *extended* sweep (more policies,
prediction models, or seeds over the same suites) computes only the missing
groups.  ``run_sweep`` loads before running and saves after every completed
(suite, policy, prediction) group.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict

from .grid import SweepSpec

SCHEMA_VERSION = 1


class SweepStore:
    def __init__(self, root: str = "experiments/sweeps"):
        self.root = root

    def path(self, spec: SweepSpec) -> str:
        return os.path.join(self.root, f"sweep_{spec.suites_hash()}.json")

    def load(self, spec: SweepSpec) -> Dict[str, Dict]:
        path = self.path(spec)
        if not os.path.exists(path):
            return {}
        with open(path) as f:
            blob = json.load(f)
        if blob.get("schema") != SCHEMA_VERSION or \
                blob.get("suites_hash") != spec.suites_hash():
            return {}
        return blob.get("results", {})

    def save(self, spec: SweepSpec, results: Dict[str, Dict]) -> str:
        path = self.path(spec)
        os.makedirs(self.root, exist_ok=True)
        blob = {"schema": SCHEMA_VERSION, "suites_hash": spec.suites_hash(),
                "spec": spec.canonical(), "results": results}
        # atomic replace so an interrupted sweep never corrupts the file
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path
