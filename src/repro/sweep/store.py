"""Persist sweep results as JSON keyed by the spec's *suites* hash.

One file per instance family set under the store root (default
``experiments/sweeps/``), named ``sweep_<suites_hash>.json``:

    {
      "schema": 2,
      "suites_hash": "<16 hex chars>",
      "checksum": "<16 hex chars over the results blob>",
      "spec": { ...canonical spec of the last run that wrote the file... },
      "results": { "<result_key>": { ...record... }, ... }
    }

Results are keyed per (suite, instance, policy, prediction model, seed) and
depend only on the suites, so specs that share suites share a file: an
interrupted sweep resumes, and an *extended* sweep (more policies,
prediction models, or seeds over the same suites) computes only the missing
groups.  ``run_sweep`` loads before running and saves after every completed
(suite, policy, prediction) group.

Resilience (this is long-running-job state, so corruption must not lose
the run):

  * the main file is written atomically (tmp + fsync + rename) and carries
    a content checksum; a truncated/corrupted/checksum-mismatched file is
    quarantined to a ``.corrupt`` sidecar (counted ``store.corrupt``, a
    ``RuntimeWarning``) instead of raising - surviving state is rebuilt
    from the journal;
  * every completed group is ALSO appended to a ``.journal.jsonl``
    sidecar (one checksummed line per group delta, fsynced) *before* the
    main rewrite, so a crash mid-rewrite loses nothing: ``load`` unions
    journal records over the main blob, skipping torn tail lines
    (``store.journal_skipped``).

Multi-process safety (the ``--hosts`` launcher runs N sweep processes
against one store): ``save`` holds an exclusive ``flock`` on a ``.lock``
sidecar for the journal-append + main-rewrite critical section, and
rewrites the main blob as *on-disk state merged with this process's
records* rather than this process's view alone - so concurrent hosts
never clobber each other's groups, and the final file equals the
single-process result set.  Readers stay lock-free: the main file is
only ever atomically replaced, and torn journal tails are skipped.

Schema 1 files (no checksum, no journal) still load.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import tempfile
import warnings
from typing import Dict, Optional

try:
    import fcntl
except ImportError:          # non-POSIX: single-process stores still work
    fcntl = None

from .. import obs
from ..resilience import faults
from .grid import SweepSpec

SCHEMA_VERSION = 2


def _records_sha(results: Dict[str, Dict]) -> str:
    """Content checksum of a results mapping.  ``json.dumps`` of re-parsed
    floats is stable (repr round-trips), so the checksum computed on save
    equals the checksum recomputed on load."""
    blob = json.dumps(results, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


class SweepStore:
    def __init__(self, root: str = "experiments/sweeps"):
        self.root = root

    def path(self, spec: SweepSpec) -> str:
        return os.path.join(self.root, f"sweep_{spec.suites_hash()}.json")

    def journal_path(self, spec: SweepSpec) -> str:
        return self.path(spec) + ".journal.jsonl"

    # ------------------------------------------------------------- load

    def _load_main(self, spec: SweepSpec) -> Dict[str, Dict]:
        path = self.path(spec)
        if not os.path.exists(path):
            return {}
        faults.fire("store.load", path=path)
        try:
            with open(path) as f:
                blob = json.load(f)
            if blob.get("schema") not in (1, SCHEMA_VERSION):
                return {}
            if blob.get("suites_hash") != spec.suites_hash():
                return {}
            results = blob.get("results", {})
            if blob.get("schema") >= 2 and \
                    blob.get("checksum") != _records_sha(results):
                raise ValueError("store checksum mismatch")
            return results
        except (json.JSONDecodeError, ValueError, KeyError) as e:
            # torn write / bit rot: quarantine, warn, rebuild from the
            # journal instead of killing the sweep
            side = path + ".corrupt"
            os.replace(path, side)
            obs.counter_add("store.corrupt")
            warnings.warn(
                f"sweep store {path!r} is corrupt ({e}); quarantined to "
                f"{side!r}, rebuilding from the journal", RuntimeWarning,
                stacklevel=3)
            return {}

    def _load_journal(self, spec: SweepSpec) -> Dict[str, Dict]:
        """Union of every intact journal line's records (later lines win).
        A torn tail line (crash mid-append) is skipped, not fatal."""
        jpath = self.journal_path(spec)
        if not os.path.exists(jpath):
            return {}
        out: Dict[str, Dict] = {}
        with open(jpath) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    if rec.get("suites_hash") != spec.suites_hash():
                        continue
                    if rec.get("sha") != _records_sha(rec["records"]):
                        raise ValueError("journal line checksum mismatch")
                    out.update(rec["records"])
                    obs.counter_add("store.journal_records",
                                    len(rec["records"]))
                except (json.JSONDecodeError, ValueError, KeyError,
                        TypeError):
                    obs.counter_add("store.journal_skipped")
        return out

    def load(self, spec: SweepSpec) -> Dict[str, Dict]:
        # journal records are at least as fresh as the main blob (save
        # order is journal first, then main), so they are authoritative
        # when a crash interrupted the main rewrite
        results = self._load_main(spec)
        results.update(self._load_journal(spec))
        return results

    # ------------------------------------------------------------- save

    @contextlib.contextmanager
    def _locked(self, spec: SweepSpec):
        """Exclusive inter-process lock for the save critical section (a
        ``.lock`` sidecar never replaced, so the inode is stable)."""
        os.makedirs(self.root, exist_ok=True)
        if fcntl is None:
            yield
            return
        with open(self.path(spec) + ".lock", "a") as lf:
            fcntl.flock(lf, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf, fcntl.LOCK_UN)

    def _append_journal(self, spec: SweepSpec,
                        group_records: Dict[str, Dict]) -> None:
        jpath = self.journal_path(spec)
        line = json.dumps({"suites_hash": spec.suites_hash(),
                           "sha": _records_sha(group_records),
                           "records": group_records}, sort_keys=True)
        with open(jpath, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())

    def save(self, spec: SweepSpec, results: Dict[str, Dict],
             group_records: Optional[Dict[str, Dict]] = None) -> str:
        path = self.path(spec)
        os.makedirs(self.root, exist_ok=True)
        with self._locked(spec):
            if group_records:
                # journal BEFORE the main rewrite: the delta survives a
                # crash at any point of the rewrite
                self._append_journal(spec, group_records)
            # merge over what is on disk, not over this process's view:
            # concurrent hosts interleave saves, and each must preserve
            # the groups the others have already landed
            merged = self._load_main(spec)
            merged.update(self._load_journal(spec))
            merged.update(results)
            blob = {"schema": SCHEMA_VERSION,
                    "suites_hash": spec.suites_hash(),
                    "checksum": _records_sha(merged),
                    "spec": spec.canonical(), "results": merged}
            # atomic replace so an interrupted sweep never corrupts it
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(blob, f, indent=1, sort_keys=True)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        # seam AFTER the replace: the "truncate" fault kind corrupts the
        # file just written, exactly like a torn write
        faults.fire("store.save", path=path)
        return path
