"""Batched experiment sweeps: evaluate a whole DVBP grid on-device.

Public API:
    pack_instances / pad_predictions / InstanceBatch   (batching)
    run_batch / run_grid / BatchRunResult              (vmapped runner)
    SuiteSpec / PredModel / SweepSpec / run_sweep /
    summarize_sweep / result_key                       (declarative grids)
    SweepStore                                         (incremental JSON store)

CLI: ``python -m repro.sweep --help`` (see sweep/README.md).
"""
from .batching import InstanceBatch, pack_instances, pad_predictions  # noqa: F401
from .runner import BatchRunResult, run_batch, run_grid  # noqa: F401
from .grid import (PredModel, SuiteSpec, SweepSpec, result_key,  # noqa: F401
                   run_sweep, summarize_sweep)
from .store import SweepStore  # noqa: F401
