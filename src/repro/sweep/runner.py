"""Batched DVBP replay: one fused lane-batched scan per (grid, policy).

``run_batch`` evaluates every lane of an ``InstanceBatch`` (and every
prediction-seed row) in a single device computation - the per-instance
``jaxsim.simulate`` loop re-traces and re-dispatches once per (instance,
policy) pair because every instance has its own event-tensor shape; here
the padded batch compiles once per flattened padded geometry
(L = B*S lanes, n_max, d, max_bins -> Np, policy, backend, block_events)
and the scan runs all lanes in lockstep.  The (B, S) -> lane flattening
happens *outside* the jit, so grids that vary which instances or how many
seed rows fill the lanes - but not the padded geometry - share one trace.

Every policy in ``jaxsim.SCAN_POLICIES`` is a lane: the score-based Any Fit
family AND the category-structured families (CBD/CBDT, Hybrid variants,
RCP/PPE, Lifetime Alignment, adaptive) - ``core.jaxsim._replay_batch`` is
the single replay engine, extended with carried category state.  The (B, S)
grid always flattens to one lane axis (lane = b*S + s) and replays as a
*single* scan over the event index.

Backends (``jaxsim.BACKENDS``): with ``backend="jnp"`` the per-step
placement decision is the inline vmapped select on a compact carry; with
"pallas"/"pallas_interpret" it is the fused ``kernels.fitscore`` kernel
with the scan carry held in the kernel's padded layout - zero host round
trips per step.  "auto" resolves to the kernel on TPU, jnp elsewhere.
``block_events=T > 1`` (kernel backends) goes one rung further: the
event-blocked replay megakernel processes whole T-event blocks on-chip
with the carry resident in VMEM, written back to HBM once per block (see
``kernels.fitscore.fitscore_replay_block`` and sweep/README.md).  All
paths are bit-identical on fp32-exact instances (tests/test_sweep.py,
tests/test_sweep_categories.py, tests/test_replay_block.py).

Sharding: when more than one local device is visible, the lane axis is
sharded across them via ``compat.shard_map`` (lanes padded to a device
multiple; each device replays its lane shard independently - the replay has
no cross-lane communication, so the map is embarrassingly parallel).  With
one device the plain single-device path runs, unchanged.

Overflow handling mirrors ``simulate(auto_grow=True)`` but lane-wise: after
a batched run, any lane whose slot pool overflowed (in any seed row) is
gathered into a sub-batch and re-run with ``max_bins`` doubled, repeatedly,
instead of returning garbage for those lanes.  The ladder composes with
sharding (each rung re-pads and re-shards the surviving lanes).  Each rung
costs a re-compile for the (smaller) sub-batch shape; starting ``max_bins``
near the expected peak open-bin count avoids the ladder entirely.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..consolidate import ConsolidationSpec, consolidated_replay
from ..core.jaxsim import (MAX_BINS_CAP, _replay_batch, grow_max_bins,
                           known_policy, resolve_backend)
from ..obs.trace import ReplayTrace, from_scan
from ..resilience import faults, guard
from ..resilience.checkpoint import ReplayCheckpointer, checkpointed_replay
from .batching import InstanceBatch, instances_pdeps


def _flatten_lanes(sizes, times, kinds, items, pdeps, dmask, arrivals,
                   rdeps, n_items):
    """Flatten the (B, S) grid to L = B*S lanes, lane = b*S + s: per-lane
    arrays repeat b-major to match ``pdeps.reshape``'s row order (the single
    source of the lane ordering for both the kernel and sharded paths)."""
    B, S, n_max = pdeps.shape
    rep = (lambda a: jnp.repeat(a, S, axis=0)) if S > 1 else (lambda a: a)
    return (rep(sizes), rep(times), rep(kinds), rep(items),
            pdeps.reshape(B * S, n_max), rep(dmask), rep(arrivals),
            rep(rdeps), rep(n_items))


def lane_device_count() -> int:
    """Local devices available to shard the lane axis over."""
    return jax.local_device_count()


def _simulate_lanes_impl(sizes, times, kinds, items, pdeps, dmask, arrivals,
                         rdeps, n_items, *, policy: str, max_bins: int,
                         backend: str, block_events: int = 0,
                         trace_level: int = 0):
    """Flattened-lane replay: ``pdeps`` is (L, n_max) - exactly one
    prediction row per lane.  This is the shard_map body: a single
    lane-batched scan (nested vmaps inside a shard body trip jax 0.4.x's
    sharding propagation - invalid tile_assignment at HLO verification)."""
    res = _replay_batch(
        sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps, n_items,
        policy=policy, max_bins=max_bins, backend=backend,
        block_events=block_events, trace_level=trace_level)
    usage, opened, _placements, overflow = res[:4]
    if trace_level:
        return usage, opened, overflow, res[4]
    return usage, opened, overflow


# THE jitted replay.  Keyed on the *flattened padded geometry* only -
# (L, n_max, d) input shapes plus the static (policy, max_bins -> Np,
# backend, block_events -> T) - so a grid sweep that varies which
# instances / how many seed rows fill the lanes (but not the padded
# geometry) compiles exactly once per policy
# (tests/test_replay_block.py::test_one_trace_across_grid).  The (B, S) ->
# lane flattening happens OUTSIDE the jit: jitting at (B, S) granularity
# used to retrace a 6x2 grid and a 12x1 grid separately even though they
# run the identical flattened computation.
_simulate_lanes = jax.jit(_simulate_lanes_impl,
                          static_argnames=("policy", "max_bins", "backend",
                                           "block_events", "trace_level"))


def _jit_cache_entries() -> int:
    """Total compiled-trace count across the jitted replay entry points -
    the source of the ``sweep.jit_trace`` counter (the PR-5 "one trace per
    geometry" fix as a monitored invariant, not just a regression test)."""
    return int(_simulate_lanes._cache_size() +
               _simulate_batch_sharded._cache_size())


def _simulate_batch(sizes, times, kinds, items, pdeps, dmask, arrivals,
                    rdeps, n_items, *, policy: str, max_bins: int,
                    backend: str = "jnp", block_events: int = 0,
                    trace_level: int = 0):
    """pdeps: (B, S, n_max); everything else (B, ...).  Returns
    (usage (B,S), opened (B,S), overflow (B,S), trace) - placements are
    dead-code eliminated to keep device->host transfers small.  ``trace``
    is None unless ``trace_level >= 1``, else the per-event series dict
    with flat-lane leading axes (L = B*S, E, ...)."""
    B, S, _ = pdeps.shape
    out = _simulate_lanes(
        *_flatten_lanes(sizes, times, kinds, items, pdeps, dmask, arrivals,
                        rdeps, n_items),
        policy=policy, max_bins=max_bins, backend=backend,
        block_events=block_events, trace_level=trace_level)
    usage, opened, overflow = out[:3]
    return (usage.reshape(B, S), opened.reshape(B, S),
            overflow.reshape(B, S), out[3] if trace_level else None)


@partial(jax.jit, static_argnames=("policy", "max_bins", "backend", "ndev",
                                   "block_events"))
def _simulate_batch_sharded(sizes, times, kinds, items, pdeps, dmask,
                            arrivals, rdeps, n_items, *, policy: str,
                            max_bins: int, backend: str, ndev: int,
                            block_events: int = 0):
    """Shard the flattened lane axis over ``ndev`` local devices.  L must
    be a multiple of ndev (``_run_arrays`` pads); each shard replays its
    lanes with the plain single-device computation - no collectives."""
    from jax.sharding import Mesh, PartitionSpec as P

    from ..compat import shard_map
    mesh = Mesh(np.asarray(jax.local_devices()[:ndev]), ("lanes",))
    f = shard_map(
        partial(_simulate_lanes_impl, policy=policy, max_bins=max_bins,
                backend=backend, block_events=block_events),
        mesh=mesh, in_specs=P("lanes"), out_specs=P("lanes"),
        check_vma=False)
    return f(sizes, times, kinds, items, pdeps, dmask, arrivals, rdeps,
             n_items)


def _run_arrays(arrays, *, policy: str, max_bins: int, backend: str,
                ndev: int, block_events: int = 0, trace_level: int = 0):
    """One batched run, sharded over lanes when ndev > 1.

    The sharded path flattens the (B, S) grid to L = B*S lanes (so seed
    rows balance across devices too), pads L to a device multiple by
    replicating existing lanes - wrapping around when fewer than ``pad``
    lanes exist - and drops the padding rows on the way out.  Trace-level
    replay forces the single-device path: the stacked (L, E, ...) trace
    outputs don't earn a re-shard and traces are a debugging/figure mode,
    not a throughput mode."""
    faults.fire("sweep.scan")
    if ndev <= 1 or trace_level:
        return _simulate_batch(*arrays, policy=policy, max_bins=max_bins,
                               backend=backend, block_events=block_events,
                               trace_level=trace_level)
    B, S, _ = arrays[4].shape
    flat = _flatten_lanes(*arrays)
    L = B * S
    pad = (-L) % ndev
    if pad:
        # wrap-around replication: tile whole copies of the lane axis up
        # to the padded length, then slice - exact even when the device
        # count dwarfs the lane count (pad > L needs ceil(total/L) > 2
        # copies; tests/test_stream.py pins ndev > 2L)
        total = L + pad
        reps = -(-total // L)
        flat = tuple(jnp.concatenate([a] * reps, axis=0)[:total]
                     for a in flat)
    u, o, ov = _simulate_batch_sharded(*flat, policy=policy,
                                       max_bins=max_bins, backend=backend,
                                       ndev=ndev, block_events=block_events)
    return (u[:L].reshape(B, S), o[:L].reshape(B, S),
            ov[:L].reshape(B, S), None)


def _dispatch(arrays, *, policy: str, max_bins: int, backend: str,
              ndev: int, block_events: int = 0, trace_level: int = 0):
    """One batched run behind the resilience ladder: transient device
    failures retry with backoff, persistent ones degrade blocked ->
    per-event -> jnp / sharded -> single-device (``guard.replay_rungs``).
    Every rung replays identical decisions, so the results of a degraded
    dispatch are bit-identical to the requested plan - just slower.  Blocks
    on the device results so execution-time failures surface inside the
    ladder, not at the caller's first ``np.asarray``."""
    rungs = guard.replay_rungs(backend, block_events, ndev)

    def attempt(rung):
        out = _run_arrays(arrays, policy=policy, max_bins=max_bins,
                          backend=rung.backend, ndev=rung.ndev,
                          block_events=rung.block_events,
                          trace_level=trace_level)
        jax.block_until_ready(out[:3])
        return out

    rung, out = guard.run_ladder(attempt, rungs, site="sweep.scan")
    if rung is not rungs[0]:
        obs.annotate(degraded_to=rung.label)
    return out


def _run_checkpointed(arrays, *, policy: str, max_bins: int, backend: str,
                      block_events: int, ckpt: ReplayCheckpointer,
                      key: str):
    """One batched run through the segmented checkpointed replay (single
    device by construction; ``resilience.checkpoint``).  Same outputs as
    ``_run_arrays`` minus traces."""
    faults.fire("sweep.scan")
    B, S, _ = arrays[4].shape
    flat = _flatten_lanes(*arrays)
    u, o, _placements, ov = checkpointed_replay(
        flat, policy=policy, max_bins=max_bins, backend=backend,
        block_events=block_events, ckpt=ckpt, key=key)
    return (np.asarray(u).reshape(B, S), np.asarray(o).reshape(B, S),
            np.asarray(ov).reshape(B, S), None)


def _run_consolidated(arrays, *, policy: str, max_bins: int, backend: str,
                      block_events: int, spec: ConsolidationSpec):
    """One batched run through the consolidating chunked driver
    (``consolidate.consolidated_replay``; single device, no traces - the
    planner needs the carry on the host between chunks anyway).  Returns
    the ``_run_arrays`` triple plus the per-cell churn arrays."""
    faults.fire("sweep.scan")
    B, S, _ = arrays[4].shape
    flat = _flatten_lanes(*arrays)
    u, o, _placements, ov, stats = consolidated_replay(
        *flat, policy=policy, max_bins=max_bins, backend=backend,
        block_events=block_events, spec=spec)
    churn = {"migrations":
             np.asarray(stats["migrations"]).reshape(B, S),
             "migration_cost":
             np.asarray(stats["migration_cost"]).reshape(B, S)}
    return (np.asarray(u).reshape(B, S), np.asarray(o).reshape(B, S),
            np.asarray(ov).reshape(B, S), churn)


@dataclasses.dataclass
class BatchRunResult:
    usage_time: np.ndarray     # (B, S) float
    n_bins_opened: np.ndarray  # (B, S) int
    overflowed: np.ndarray     # (B, S) bool (True only if the cap was hit)
    max_bins: np.ndarray       # (B,) slot-pool size that produced each lane
    trace: Optional[ReplayTrace] = None  # trace_level >= 1 only
    migrations: Optional[np.ndarray] = None      # (B, S), consolidate only
    migration_cost: Optional[np.ndarray] = None  # (B, S), consolidate only

    @property
    def S(self) -> int:
        return self.usage_time.shape[1]


def run_batch(batch: InstanceBatch, policy: str,
              pdeps: Optional[np.ndarray] = None, max_bins: int = 64,
              max_bins_cap: int = MAX_BINS_CAP,
              auto_grow: bool = True, backend: Optional[str] = None,
              shard: str = "auto", block_events: int = 0,
              trace_level: int = 0,
              checkpoint: Optional[ReplayCheckpointer] = None,
              checkpoint_key: str = "",
              consolidate: Optional[ConsolidationSpec] = None
              ) -> BatchRunResult:
    """Replay every lane of ``batch`` under ``policy`` (any
    ``jaxsim.SCAN_POLICIES`` name, category-structured policies included).

    ``pdeps``: (B, S, n_max) predicted departure times (see
    ``batching.pad_predictions``); defaults to the real departures
    (clairvoyant / non-clairvoyant replay).

    ``backend``: scoring engine (``jaxsim.BACKENDS``; None == "auto" ==
    Pallas kernel on TPU, inline jnp elsewhere).  ``shard``: "auto" shards
    the lane axis over all local devices when more than one is visible;
    "never" forces the single-device path; "always" asserts multi-device.
    ``block_events`` > 1 (kernel backends only) runs the event-blocked
    replay megakernel: blocks of that many events per invocation with the
    carry resident on-chip.  All three are execution arguments - they
    never change the replayed decisions.

    ``trace_level`` >= 1 also returns the per-event decision series as
    ``result.trace`` (an ``obs.ReplayTrace``; level >= 2 adds the per-slot
    alive mask).  Tracing never changes decisions, but it does change the
    execution plan: per-event replay (the blocked megakernel is bypassed)
    on a single device.  ``trace_level=0`` runs exactly today's code path.

    ``checkpoint`` (a ``resilience.ReplayCheckpointer``) replays in
    checkpointed segments so a killed run resumes bit-identically
    (single-device, no traces); ``checkpoint_key`` names the snapshot
    file.  Without it, dispatch runs behind the resilience ladder
    (``_dispatch``): transient device failures retry, persistent ones
    degrade blocked -> per-event -> jnp / sharded -> single-device with
    identical results.

    ``consolidate`` (an enabled ``ConsolidationSpec``) routes the replay
    through the chunked consolidating driver: scan chunks alternate with
    host planning and MIGRATE chunks (``consolidate.consolidated_replay``)
    and the result gains per-cell ``migrations`` / ``migration_cost``
    arrays.  The consolidating path is single-device and untraced and
    bypasses checkpointing; ``None`` (or a disabled spec is rejected by
    the driver) runs exactly the paths above, bit-identically to a build
    without the consolidation axis.
    """
    assert known_policy(policy), f"{policy!r} is not a scan policy"
    assert shard in ("auto", "never", "always"), shard
    backend = resolve_backend(backend)
    if pdeps is None:
        pdeps = instances_pdeps(batch)
    B, S, _ = pdeps.shape
    assert B == batch.B
    ndev = 1 if shard == "never" else lane_device_count()
    if shard == "always":
        assert ndev > 1, "shard='always' requires multiple local devices"

    usage = np.zeros((B, S))
    opened = np.zeros((B, S), np.int64)
    over = np.ones((B, S), bool)
    mb_used = np.full(B, max_bins, np.int64)
    migrations = migration_cost = None
    if consolidate is not None:
        assert consolidate.enabled, \
            "pass consolidate=None for non-consolidating runs"
        migrations = np.zeros((B, S), np.int64)
        migration_cost = np.zeros((B, S))
    lanes = np.arange(B)
    mb = max_bins
    arrays = (batch.sizes, batch.times, batch.kinds, batch.items, pdeps,
              batch.dmask, batch.arrivals, batch.pdeps, batch.n_items)
    trace_np = None
    with obs.span("sweep.run_batch", policy=policy, backend=backend,
                  B=B, S=S) as rb_span:
        rungs = 0
        while True:
            with obs.span("sweep.flatten"):
                sub = tuple(jnp.asarray(a[lanes]) for a in arrays)
            obs.counter_add("sweep.device_transfer_bytes",
                            sum(int(x.nbytes) for x in sub))
            c0 = _jit_cache_entries()
            with obs.span("sweep.scan", policy=policy, max_bins=mb,
                          lanes=int(lanes.size) * S) as sc, \
                    obs.jax_profile():
                if consolidate is not None:
                    u, o, ov, churn = _run_consolidated(
                        sub, policy=policy, max_bins=mb, backend=backend,
                        block_events=block_events, spec=consolidate)
                    tr = None
                    migrations[lanes] = churn["migrations"]
                    migration_cost[lanes] = churn["migration_cost"]
                elif checkpoint is not None and not trace_level:
                    u, o, ov, tr = _run_checkpointed(
                        sub, policy=policy, max_bins=mb, backend=backend,
                        block_events=block_events, ckpt=checkpoint,
                        key=f"{checkpoint_key or policy}-mb{mb}")
                else:
                    u, o, ov, tr = _dispatch(sub, policy=policy,
                                             max_bins=mb, backend=backend,
                                             ndev=ndev,
                                             block_events=block_events,
                                             trace_level=trace_level)
                usage[lanes] = np.asarray(u)   # blocks on device results
                opened[lanes] = np.asarray(o)
                over[lanes] = np.asarray(ov)
            retraced = _jit_cache_entries() - c0
            if retraced:
                obs.counter_add("sweep.jit_trace", retraced)
                sc.set(retraced=retraced)
            else:
                obs.counter_add("sweep.jit_cache_hit")
            obs.counter_add("sweep.scan_calls")
            mb_used[lanes] = mb
            if tr is not None:
                tr = {k: np.asarray(v) for k, v in tr.items()}
                if trace_np is None:
                    trace_np = {k: np.zeros((B * S,) + v.shape[1:],
                                            v.dtype)
                                for k, v in tr.items()}
                rows = (lanes[:, None] * S + np.arange(S)).ravel()
                for k, v in tr.items():
                    trace_np[k][rows] = v
            lanes = lanes[np.asarray(ov).any(axis=1)]
            if lanes.size == 0 or not auto_grow or mb >= max_bins_cap:
                break
            mb = grow_max_bins(mb, max_bins_cap)
            rungs += 1
            obs.counter_add("sweep.overflow_rungs")
        if rungs:
            rb_span.set(overflow_rungs=rungs)
    trace = None if trace_np is None else from_scan(
        trace_np, batch.times, batch.kinds, batch.items, policy=policy,
        S=S)
    return BatchRunResult(usage, opened, over, mb_used, trace,
                          migrations=migrations,
                          migration_cost=migration_cost)


def run_grid(batch: InstanceBatch, policies: Sequence[str],
             pdeps: Optional[np.ndarray] = None, max_bins: int = 64,
             max_bins_cap: int = MAX_BINS_CAP,
             backend: Optional[str] = None, shard: str = "auto",
             block_events: int = 0,
             trace_level: int = 0) -> Dict[str, BatchRunResult]:
    """One batched run per policy over the same instance batch."""
    return {p: run_batch(batch, p, pdeps, max_bins, max_bins_cap,
                         backend=backend, shard=shard,
                         block_events=block_events,
                         trace_level=trace_level)
            for p in policies}
