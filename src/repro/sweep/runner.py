"""Batched DVBP replay: one fused vmapped scan per (grid, policy).

``run_batch`` evaluates every lane of an ``InstanceBatch`` (and every
prediction-seed row) in a single device computation - the per-instance
``jaxsim.simulate`` loop re-traces and re-dispatches once per (instance,
policy) pair because every instance has its own event-tensor shape; here the
padded batch compiles once per (B, S, max_bins, policy) and the scan runs all
lanes in lockstep.

Overflow handling mirrors ``simulate(auto_grow=True)`` but lane-wise: after a
batched run, any lane whose slot pool overflowed (in any seed row) is
gathered into a sub-batch and re-run with ``max_bins`` doubled, repeatedly,
instead of returning garbage for those lanes.  Each escalation rung costs a
re-compile for the (smaller) sub-batch shape; starting ``max_bins`` near the
expected peak open-bin count avoids the ladder entirely.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.jaxsim import (MAX_BINS_CAP, POLICIES, _replay, grow_max_bins)
from .batching import InstanceBatch, instances_pdeps


@partial(jax.jit, static_argnames=("policy", "max_bins"))
def _simulate_batch(sizes, times, kinds, items, pdeps, dmask, *,
                    policy: str, max_bins: int):
    """pdeps: (B, S, n_max); everything else (B, ...).  Returns
    (usage (B,S), opened (B,S), overflow (B,S)) - placements are dead-code
    eliminated to keep device->host transfers small."""

    def lane(sz, t, k, it, pd_rows, dm):
        def one(p):
            usage, opened, _placements, overflow = _replay(
                sz, t, k, it, p, dm, policy=policy, max_bins=max_bins)
            return usage, opened, overflow
        return jax.vmap(one)(pd_rows)

    return jax.vmap(lane)(sizes, times, kinds, items, pdeps, dmask)


@dataclasses.dataclass
class BatchRunResult:
    usage_time: np.ndarray     # (B, S) float
    n_bins_opened: np.ndarray  # (B, S) int
    overflowed: np.ndarray     # (B, S) bool (True only if the cap was hit)
    max_bins: np.ndarray       # (B,) slot-pool size that produced each lane

    @property
    def S(self) -> int:
        return self.usage_time.shape[1]


def run_batch(batch: InstanceBatch, policy: str,
              pdeps: Optional[np.ndarray] = None, max_bins: int = 64,
              max_bins_cap: int = MAX_BINS_CAP,
              auto_grow: bool = True) -> BatchRunResult:
    """Replay every lane of ``batch`` under ``policy``.

    ``pdeps``: (B, S, n_max) predicted departure times (see
    ``batching.pad_predictions``); defaults to the real departures
    (clairvoyant / non-clairvoyant replay).
    """
    assert policy in POLICIES, policy
    if pdeps is None:
        pdeps = instances_pdeps(batch)
    B, S, _ = pdeps.shape
    assert B == batch.B

    usage = np.zeros((B, S))
    opened = np.zeros((B, S), np.int64)
    over = np.ones((B, S), bool)
    mb_used = np.full(B, max_bins, np.int64)
    lanes = np.arange(B)
    mb = max_bins
    arrays = (batch.sizes, batch.times, batch.kinds, batch.items, pdeps,
              batch.dmask)
    while True:
        sub = tuple(jnp.asarray(a[lanes]) for a in arrays)
        u, o, ov = _simulate_batch(*sub, policy=policy, max_bins=mb)
        usage[lanes] = np.asarray(u)
        opened[lanes] = np.asarray(o)
        over[lanes] = np.asarray(ov)
        mb_used[lanes] = mb
        lanes = lanes[np.asarray(ov).any(axis=1)]
        if lanes.size == 0 or not auto_grow or mb >= max_bins_cap:
            break
        mb = grow_max_bins(mb, max_bins_cap)
    return BatchRunResult(usage, opened, over, mb_used)


def run_grid(batch: InstanceBatch, policies: Sequence[str],
             pdeps: Optional[np.ndarray] = None, max_bins: int = 64,
             max_bins_cap: int = MAX_BINS_CAP) -> Dict[str, BatchRunResult]:
    """One batched run per policy over the same instance batch."""
    return {p: run_batch(batch, p, pdeps, max_bins, max_bins_cap)
            for p in policies}
