"""CLI for batched experiment sweeps.

Promoted to ``python -m repro sweep`` (same flags); this module remains
the legacy ``python -m repro.sweep`` entry and forwards unchanged.

Examples:
    # clairvoyant azure grid, all on-device policies, results persisted
    PYTHONPATH=src python -m repro sweep --suites azure --n-instances 12

    # prediction-noise sweep over three sigmas, five seeds
    PYTHONPATH=src python -m repro sweep --preds clairvoyant \
        lognormal:0.5 lognormal:2.0 --seeds 0,1,2,3,4

    # incremental: re-running the same spec only computes missing groups
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

from .grid import PredModel, SuiteSpec, SweepSpec, run_sweep, summarize_sweep
from .store import SweepStore
from ..consolidate import ConsolidationSpec
from ..core.jaxsim import SCAN_POLICIES

SUITE_DEFAULT_SEED = {"azure": 2026, "huawei": 77, "azure_trace": 0}


def _pred(token: str) -> PredModel:
    kind, _, param = token.partition(":")
    if kind in ("lognormal", "uniform") and not param:
        unit = "SIGMA" if kind == "lognormal" else "EPS"
        raise SystemExit(f"--preds {kind} needs a parameter: {kind}:{unit}")
    return PredModel(kind, float(param) if param else 0.0)


def main(argv=None, prog: str = "python -m repro sweep") -> None:
    ap = argparse.ArgumentParser(
        prog=prog,
        description="Evaluate a DVBP experiment grid in batched device runs.")
    ap.add_argument("--suites", nargs="+", default=["azure"],
                    choices=["azure", "huawei", "azure_trace"])
    ap.add_argument("--n-instances", type=int, default=6)
    ap.add_argument("--n-items", type=int, default=500)
    ap.add_argument("--suite-seed", type=int, default=None,
                    help="instance-generator seed (default: family-specific)")
    ap.add_argument("--trace-root", default="data/azure",
                    help="Azure Packing2020 dump directory (azure_trace)")
    ap.add_argument("--policies", default="all",
                    help=f"comma list from {','.join(SCAN_POLICIES)} "
                         "or 'all' (parametric names like cbd_beta4 / "
                         "cbdt_rho3600 parse too)")
    ap.add_argument("--preds", nargs="+", default=["clairvoyant"],
                    help="prediction models: none | clairvoyant | "
                         "lognormal:SIGMA | uniform:EPS")
    ap.add_argument("--seeds", default="0",
                    help="comma list of seeds for noisy prediction models")
    ap.add_argument("--max-bins", type=int, default=64)
    ap.add_argument("--max-bins-cap", type=int, default=8192)
    ap.add_argument("--consolidate", nargs="+", default=["none"],
                    help="consolidation scenario axis: none | "
                         "underload[:THRESHOLD[:BUDGET]] | "
                         "periodic:DT[:THRESHOLD[:BUDGET]] (tagged knobs "
                         "t/b/e/c/dt accepted, e.g. underload:t0.25:b64); "
                         "each value adds a grid column")
    ap.add_argument("--store", default="experiments/sweeps",
                    help="result-store directory")
    ap.add_argument("--no-store", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute even if the store has results")
    ap.add_argument("--backend", default=None,
                    choices=["auto", "jnp", "pallas", "pallas_interpret"],
                    help="scoring engine (default auto: Pallas kernel on "
                         "TPU, inline jnp elsewhere)")
    ap.add_argument("--shard", default="auto",
                    choices=["auto", "never", "always"],
                    help="shard the lane axis over local devices")
    ap.add_argument("--block-events", type=int, default=0,
                    help="kernel backends: events per megakernel "
                         "invocation (0/1 = per-event replay); execution "
                         "knob only, never changes results")
    ap.add_argument("--resume", action="store_true",
                    help="checkpoint every replay under "
                         "STORE/checkpoints and resume a killed sweep "
                         "bit-identically (sugar for --checkpoint-dir "
                         "STORE/checkpoints)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="snapshot the scan carry here at event-block "
                         "boundaries; a rerun resumes from the last "
                         "checkpoint")
    ap.add_argument("--checkpoint-every", type=int, default=2048,
                    help="events between checkpoint snapshots")
    ap.add_argument("--hosts", type=int, default=0,
                    help="launch N worker processes, each running a "
                         "1/N slice of the (suite, pred, policy, "
                         "consolidation) grid against the shared store "
                         "(journal-merged; the final records equal a "
                         "single-process run)")
    ap.add_argument("--host-index", type=int, default=None,
                    help="run only this host's grid slice (normally set "
                         "by --hosts, or via REPRO_HOST_INDEX)")
    ap.add_argument("--host-count", type=int, default=None,
                    help="total hosts sharding the grid (with "
                         "--host-index, or via REPRO_HOST_COUNT)")
    args = ap.parse_args(argv)

    if args.hosts and args.hosts > 1:
        # process-per-host launcher: re-exec this CLI once per slice with
        # the slice pinned via environment, then let the store's
        # journal+lock merging produce the single combined record set
        if args.no_store:
            raise SystemExit("--hosts needs a store to merge results into")
        base, skip = [], False
        for a in (argv if argv is not None else sys.argv[1:]):
            if skip:
                skip = False
                continue
            if a == "--hosts":
                skip = True
                continue
            if a.startswith("--hosts="):
                continue
            base.append(a)
        procs = []
        for i in range(args.hosts):
            env = dict(os.environ, REPRO_HOST_INDEX=str(i),
                       REPRO_HOST_COUNT=str(args.hosts))
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro", "sweep"] + base, env=env))
        rcs = [p.wait() for p in procs]
        if any(rcs):
            raise SystemExit(f"worker processes failed: rc={rcs}")
        # fall through host-free: every group is now cached, so the parent
        # re-runs the grid as pure store reads and prints the merged
        # summary (workers already honored --force on their slices)
        args = ap.parse_args(base)
        args.force = False

    host_index = args.host_index if args.host_index is not None else \
        int(os.environ.get("REPRO_HOST_INDEX", "0"))
    host_count = args.host_count if args.host_count is not None else \
        (int(os.environ["REPRO_HOST_COUNT"])
         if "REPRO_HOST_COUNT" in os.environ else None)

    policies = tuple(SCAN_POLICIES) if args.policies == "all" else \
        tuple(args.policies.split(","))
    suites = tuple(
        SuiteSpec(fam, args.n_instances, args.n_items,
                  args.suite_seed if args.suite_seed is not None
                  else SUITE_DEFAULT_SEED[fam], trace_root=args.trace_root)
        for fam in args.suites)
    spec = SweepSpec(
        suites=suites, policies=policies,
        predictions=tuple(_pred(t) for t in args.preds),
        seeds=tuple(int(s) for s in args.seeds.split(",")),
        max_bins=args.max_bins, max_bins_cap=args.max_bins_cap,
        consolidations=tuple(ConsolidationSpec.parse(t)
                             for t in args.consolidate))

    store = None if args.no_store else SweepStore(args.store)
    ckpt_dir = args.checkpoint_dir
    if args.resume and ckpt_dir is None:
        ckpt_dir = os.path.join(args.store, "checkpoints")
    who = f" host {host_index}/{host_count}" if host_count else ""
    print(f"# sweep {spec.spec_hash()}{who} -> "
          f"{store.path(spec) if store else '(not stored)'}")
    records = run_sweep(spec, store=store, force=args.force,
                        progress=lambda m: print(f"# {m}", flush=True),
                        backend=args.backend, shard=args.shard,
                        block_events=args.block_events,
                        checkpoint_dir=ckpt_dir,
                        checkpoint_every=args.checkpoint_every,
                        host_index=host_index, host_count=host_count)

    print(f"{'policy':<18} {'pred':<14} {'n':>4} {'mean':>8} {'median':>8} "
          f"{'q1':>8} {'q3':>8}")
    for (policy, pred), st in summarize_sweep(records).items():
        print(f"{policy:<18} {pred:<14} {st.n:>4} {st.mean:>8.4f} "
              f"{st.median:>8.4f} {st.q1:>8.4f} {st.q3:>8.4f}")


if __name__ == "__main__":
    from ..api._migration import warn_legacy
    warn_legacy("python -m repro.sweep", "python -m repro sweep")
    main(prog="python -m repro.sweep")
