"""Sharded, atomic, async checkpointing with retention GC.

Layout:  <root>/step_<N>/arrays.npz + tree.json  (one file per host in a
real multi-host run; addressable shards are gathered per-leaf here).
Writes go to a temp dir + atomic rename, so a crash mid-save never corrupts
the latest checkpoint; ``restore`` always loads the newest complete step.
Async mode hands the (host-copied) state to a writer thread so the train
loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(state) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state) -> None:
        # device -> host copy happens here so the caller can keep training
        leaves, treedef = _flatten(state)
        host = [np.asarray(x) for x in leaves]
        tree_repr = jax.tree_util.tree_structure(state)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, str(tree_repr)))
            self._thread.start()
        else:
            self._write(step, host, str(tree_repr))

    def _write(self, step: int, host, tree_repr: str) -> None:
        final = os.path.join(self.root, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(host),
                       "tree": tree_repr}, f)
        if os.path.exists(final):    # re-save of the same step
            shutil.rmtree(final)
        os.replace(tmp, final)       # atomic: readers never see partial state
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.root)):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.root, name, "meta.json")):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None) -> Tuple[int, Any]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  Optionally device_put with ``shardings``."""
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        path = os.path.join(self.root, f"step_{step:010d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves, treedef = _flatten(like)
        assert len(data.files) == len(leaves), "checkpoint/tree mismatch"
        host = [data[f"leaf_{i}"] for i in range(len(leaves))]
        if shardings is not None:
            sh_leaves = jax.tree.leaves(shardings)
            host = [jax.device_put(a, s) for a, s in zip(host, sh_leaves)]
        else:
            host = [jax.numpy.asarray(a) for a in host]
        return step, jax.tree.unflatten(treedef, host)
