"""int8 error-feedback gradient compression for the cross-pod axis.

The multi-pod mesh is pure data parallelism across "pod": the only traffic
on the (slow) inter-pod DCI is the gradient all-reduce.  This module
implements the standard 1-bit-Adam-family trick at 8 bits: quantize the
pod-local gradient with per-row absmax scales, all-reduce (psum) the int8
payload's dequantized values over "pod" only, and feed the quantization
error back into the next step's gradient (error feedback keeps convergence).

Used via shard_map over the "pod" axis; intra-pod reduction stays fp32.
Wire cost on the DCI drops 4x vs fp32 (2x vs bf16).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from ..compat import shard_map


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_psum_pod(grads, errors, mesh: Mesh):
    """All-reduce grads over the "pod" mesh axis with int8 error feedback.

    grads/errors: pytrees with identical structure (errors carried in the
    train state, initialized to zeros).  Returns (reduced grads, new errors).
    Leaves keep their original sharding over data/model; only the pod axis
    is reduced here.
    """
    npod = mesh.shape["pod"]

    def leaf(g, e):
        x = g.astype(jnp.float32) + e
        q, s = _quant(x)
        deq = q.astype(jnp.float32) * s
        new_e = x - deq
        red = jax.lax.psum(deq, "pod") / npod
        return red.astype(g.dtype), new_e

    def local(g_tree, e_tree):
        return jax.tree.map(leaf, g_tree, e_tree,
                            is_leaf=lambda x: isinstance(x, jax.Array))

    spec = jax.tree.map(lambda _: P(), grads)   # per-shard local views
    return shard_map(local, mesh=mesh,
                     in_specs=(spec, spec), out_specs=(spec, spec),
                     check_vma=False)(grads, errors)
