"""AdamW with optionally int8-quantized moments (blockwise dynamic scales).

The 8-bit option (bitsandbytes-style, per-row absmax scales) cuts optimizer
state from 8 to 2 bytes/param - the difference between nemotron-4-340b
fitting a 256x16GB pod or not (EXPERIMENTS.md §Dry-run).  All state inherits
the parameter PartitionSpecs (ZeRO-3 via the FSDP rules).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # float32 | int8
    warmup_steps: int = 100
    total_steps: int = 10000


def schedule(opt: OptConfig, step):
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / max(opt.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - opt.warmup_steps)
                    / max(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0)
    return opt.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


# ---------------------------------------------------------- int8 quantization

def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row (last-axis) absmax int8 quantization."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_opt_state(params, opt: OptConfig) -> Dict[str, Any]:
    def zeros_like_state(p):
        if opt.state_dtype == "int8":
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "s": jnp.zeros(p.shape[:-1] + (1,), jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros_like_state, params),
            "v": jax.tree.map(zeros_like_state, params),
            "step": jnp.zeros((), jnp.int32)}


def _read(s, opt: OptConfig):
    return _dequant(s["q"], s["s"]) if opt.state_dtype == "int8" else s


def _write(x, opt: OptConfig):
    if opt.state_dtype == "int8":
        q, s = _quant(x)
        return {"q": q, "s": s}
    return x


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, opt: OptConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-9))
    lr = schedule(opt, step)
    bc1 = 1.0 - opt.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - opt.b2 ** step.astype(jnp.float32)

    def _update(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32, v32 = _read(m, opt), _read(v, opt)
        m32 = opt.b1 * m32 + (1 - opt.b1) * g
        v32 = opt.b2 * v32 + (1 - opt.b2) * g * g
        upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + opt.eps)
        p32 = p.astype(jnp.float32)
        decay = opt.weight_decay if p.ndim >= 2 else 0.0
        new_p = p32 - lr * (upd + decay * p32)
        return new_p.astype(p.dtype), _write(m32, opt), _write(v32, opt)

    def leaf(p, g, m, v):
        if p.ndim >= 3:
            # layer-stacked weights: lax.map over the stack axis bounds the
            # fp32 dequant/update transients to one layer slice (vs. the
            # whole 96-layer stack for 340B-class models).
            return jax.lax.map(lambda a: _update(*a), (p, g, m, v))
        return _update(p, g, m, v)

    is_state_leaf = (lambda x: isinstance(x, dict) and set(x) == {"q", "s"}) \
        if opt.state_dtype == "int8" else None
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_state_leaf)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_state_leaf)
    out = [leaf(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


def opt_state_pspecs(param_pspecs, opt: OptConfig):
    """Optimizer-state PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P

    def leaf(spec):
        if opt.state_dtype == "int8":
            return {"q": spec, "s": P(*spec[:-1], None) if len(spec) else spec}
        return spec
    is_spec = lambda x: isinstance(x, P)
    return {"m": jax.tree.map(leaf, param_pspecs, is_leaf=is_spec),
            "v": jax.tree.map(leaf, param_pspecs, is_leaf=is_spec),
            "step": P()}
