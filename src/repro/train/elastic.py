"""Elastic training: failure detection -> mesh rebuild -> exact resume.

On a real fleet this wraps jax.distributed + the cluster scheduler
(cluster.placement); the mechanism is identical on the host-device mesh used
in tests: the trainer checkpoints every ``ckpt_every`` steps, and when a
"failure" removes devices, it rebuilds a smaller mesh from the survivors,
re-jits the step with the new shardings, restores the latest checkpoint and
replays the data stream from that step (the pipeline is seekable: batch =
pure_fn(step), so recovery is bit-exact).

Straggler mitigation lives at two levels: the data pipeline prefetches from
backup hosts (data.tokens), and the cluster scheduler re-dispatches
timed-out shards (cluster.placement); both are exercised in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .checkpoint import CheckpointManager


@dataclasses.dataclass
class ElasticConfig:
    ckpt_every: int = 10
    keep: int = 2


class ElasticTrainer:
    """Runs train steps with checkpoint/restart across mesh changes.

    make_step(mesh) must return (step_fn, shardings) where step_fn maps
    (state, batch) -> (state, metrics) already jitted for that mesh, and
    batch_fn(step) deterministically produces the global batch.
    """

    def __init__(self, make_state: Callable, make_step: Callable[[Mesh], tuple],
                 batch_fn: Callable[[int], dict], ckpt_dir: str,
                 cfg: ElasticConfig = ElasticConfig()):
        self.make_state = make_state
        self.make_step = make_step
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.ckpt = CheckpointManager(ckpt_dir, keep=cfg.keep,
                                      async_save=False)
        self.step = 0
        self.state = None
        self.mesh: Optional[Mesh] = None
        self._fn = None

    def attach(self, mesh: Mesh) -> None:
        """(Re)build for a device set: restore newest checkpoint if any."""
        self.mesh = mesh
        self._fn, shardings = self.make_step(mesh)
        if self.ckpt.latest_step() is not None:
            like = jax.eval_shape(self.make_state)
            self.step, self.state = self.ckpt.restore(like,
                                                      shardings=shardings)
        else:
            self.state = self.make_state()
            if shardings is not None:
                self.state = jax.device_put(self.state, shardings)
            self.step = 0

    def run(self, n_steps: int, fail_at: Optional[int] = None):
        """Run steps; simulate a failure by raising at ``fail_at``."""
        metrics = None
        target = self.step + n_steps
        while self.step < target:
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"simulated node failure at {self.step}")
            batch = self.batch_fn(self.step)
            self.state, metrics = self._fn(self.state, batch)
            self.step += 1
            if self.step % self.cfg.ckpt_every == 0:
                self.ckpt.save(self.step, self.state)
        self.ckpt.save(self.step, self.state)
        return metrics
