"""Sharded training step: CE loss, microbatched gradient accumulation
(lax.scan), clipping, AdamW, mixed precision (bf16 compute / fp32 updates).

The global batch is reshaped to (microbatches, micro, S); gradients
accumulate in fp32 across the scan so activation memory is bounded by one
microbatch (the knob that fits nemotron-4-340b on a 16GB chip).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import Runtime, forward
from .optimizer import OptConfig, adamw_update

Z_LOSS = 1e-4
AUX_LOSS = 1e-2


def batch_keys(cfg: ModelConfig):
    keys = ["tokens", "labels"]
    if cfg.frontend == "audio_stub":
        keys.append("enc_embeds")
    if cfg.frontend == "vision_stub":
        keys.append("frontend_embeds")
    return keys


def loss_fn(params, cfg: ModelConfig, rt: Runtime, batch: Dict):
    # Mixed precision: params are stored fp32 (master) and cast to bf16 at
    # each use site (models/*._proj), so FSDP all-gathers run in bf16 on the
    # per-layer slice - no persistent whole-model bf16 copy.
    extras = {}
    if "enc_embeds" in batch:
        extras["enc_embeds"] = batch["enc_embeds"]
    if "frontend_embeds" in batch:
        extras["frontend_embeds"] = batch["frontend_embeds"]
    logits, _, aux = forward(params, cfg, rt, batch["tokens"], mode="train",
                             **extras)
    if "frontend_embeds" in batch:   # loss only on the text suffix
        logits = logits[:, batch["frontend_embeds"].shape[1]:]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    labels = batch["labels"]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    ce = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    zl = jnp.sum(jnp.square(lse) * mask) / jnp.maximum(mask.sum(), 1.0)
    loss = ce + Z_LOSS * zl + AUX_LOSS * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, rt: Runtime, opt: OptConfig,
                    microbatches: int = 1, accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  batch leaves have leading dim == global_batch.

    accum_dtype: gradient-accumulator precision.  bf16 halves the dominant
    persistent buffer + reduction wire bytes for 340B-class models (the
    Megatron "grad-reduce-in-bf16" trade-off); fp32 is the default.
    """

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, rt, b), has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, parts), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def split(x):
                m = microbatches
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            constrain = None
            if rt.mesh is not None:
                # Anchor the gradient accumulator to the parameter shardings:
                # without this GSPMD all-reduces FULL per-layer gradients and
                # slices afterwards (2x the wire of a reduce-scatter into the
                # sharded accumulator) - measured 1.85TB/dev -> §Perf.
                from jax.sharding import NamedSharding, PartitionSpec
                from ..models.sharding import tree_pspecs
                shardings = jax.tree.map(
                    lambda s: NamedSharding(rt.mesh, s),
                    tree_pspecs(cfg, rt.mesh, rt.rules),
                    is_leaf=lambda x: isinstance(x, PartitionSpec))
                constrain = lambda t: jax.tree.map(
                    jax.lax.with_sharding_constraint, t, shardings)

            def acc(carry, mb):
                g_acc, l_acc = carry
                # optimization_barrier keeps the per-microbatch bf16 weight
                # converts/gathers *inside* the loop body: XLA's while-loop
                # invariant code motion would otherwise hoist them and
                # materialize every layer's gathered weights at once.
                params_l = jax.lax.optimization_barrier(params)
                (l, parts), g = grad_fn(params_l, mb)
                if constrain is not None:
                    g = constrain(g)
                g_acc = jax.tree.map(
                    lambda a, b: a + (b / microbatches).astype(a.dtype),
                    g_acc, g)
                return (g_acc, l_acc + l), parts

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              params)
            if constrain is not None:
                g0 = constrain(g0)
            (grads, loss), parts = jax.lax.scan(acc, (g0, 0.0), micro)
            loss = loss / microbatches
            parts = jax.tree.map(lambda x: x.mean(), parts)
        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return params, opt_state, metrics

    return train_step
