"""Fleet-level job->host placement: the literal paper problem at the
cluster layer.

Jobs (training pods, batch inference, dev sandboxes) demand
<chips, HBM, host-RAM, NIC> fractions of a host; hosts are unit bins; the
minimized objective is host-occupancy seconds (energy/lease cost).  Faults
re-enter a job as a new item (its checkpoint restart), which is exactly the
dynamic arrival/departure model of the paper.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from ..core.bins import BinPool
from ..core.types import Arrival
from ..core.algorithms import get_algorithm


@dataclasses.dataclass
class Job:
    jid: int
    submit: float
    runtime: float                  # remaining runtime (shrinks on failures)
    demand: np.ndarray              # (4,): chips, hbm, host-ram, nic
    predicted_runtime: Optional[float] = None
    checkpoint_period: float = 600.0
    progress: float = 0.0


@dataclasses.dataclass
class ClusterStats:
    host_seconds: float = 0.0
    hosts_opened: int = 0
    peak_hosts: int = 0
    failures_recovered: int = 0
    lost_work: float = 0.0


class ClusterScheduler:
    """Online gang placement with failure re-entry and checkpoint restart."""

    def __init__(self, policy: str = "first_fit",
                 policy_kwargs: Optional[Dict] = None):
        self.pool = BinPool(d=4)
        self.alg = get_algorithm(policy, **(policy_kwargs or {}))

        class _Inst:
            durations = np.array([1.0])
        self.alg.bind(self.pool, _Inst())
        self.stats = ClusterStats()
        self._open_at: Dict[int, float] = {}
        self._placed: Dict[int, tuple] = {}

    def place(self, job: Job, now: float) -> int:
        pdep = None if job.predicted_runtime is None else \
            now + job.predicted_runtime
        arr = Arrival(job.jid, job.demand, now, pdep)
        idx = self.alg.select_bin(arr)
        opened = idx < 0
        if opened:
            idx = self.pool.open_bin(now)
            self._open_at[idx] = now
            self.stats.hosts_opened += 1
        self.pool.place(idx, job.demand, pdep if pdep else now, now)
        self.alg.on_placed(arr, idx, opened)
        self._placed[job.jid] = (idx, job.demand)
        self.stats.peak_hosts = max(self.stats.peak_hosts,
                                    len(self.pool._open_list))
        return idx

    def release(self, jid: int, now: float) -> None:
        idx, demand = self._placed.pop(jid)
        self.pool.remove(idx, demand)
        self.alg.on_departed(jid, idx, now, demand)
        if self.pool.n_active[idx] == 0:
            self.stats.host_seconds += now - self._open_at.pop(idx)
            self.pool.close_bin(idx)
            self.alg.on_closed(idx, now)

    def host_of(self, jid: int) -> int:
        return self._placed[jid][0]


def simulate_cluster(jobs: List[Job], policy: str = "first_fit", *,
                     mtbf: Optional[float] = None, seed: int = 0) -> Dict:
    """Event-driven cluster replay with host failures.

    A failing host kills its jobs; each loses work back to its last
    checkpoint and re-enters the queue immediately (restart) - item
    departure + new arrival in DVBP terms.
    """
    rng = np.random.default_rng(seed)
    sched = ClusterScheduler(policy)
    heap = []   # (time, kind, ident) kind: 0 finish, 1 failure
    for j in jobs:
        heapq.heappush(heap, (j.submit, 2, j.jid))
    by_id = {j.jid: j for j in jobs}
    running: Dict[int, float] = {}     # jid -> started at
    next_fail = rng.exponential(mtbf) if mtbf else np.inf
    now = 0.0
    while heap:
        now, kind, ident = heapq.heappop(heap)
        while mtbf and next_fail < now and sched.pool._open_list:
            # fail a random open host at time next_fail
            tf = next_fail
            hosts = list(sched.pool._open_list)
            victim = hosts[rng.integers(len(hosts))]
            victims = [jid for jid, (idx, _) in sched._placed.items()
                       if idx == victim and jid in running]
            for jid in victims:
                job = by_id[jid]
                ran = tf - running.pop(jid)
                ckpt = (ran // job.checkpoint_period) * job.checkpoint_period
                sched.stats.lost_work += ran - ckpt
                sched.stats.failures_recovered += 1
                job.runtime -= ckpt
                sched.release(jid, tf)
                heapq.heappush(heap, (tf, 2, jid))    # restart immediately
            next_fail = tf + rng.exponential(mtbf)
        if kind == 2:   # submit / resubmit
            job = by_id[ident]
            sched.place(job, now)
            running[ident] = now
            heapq.heappush(heap, (now + job.runtime, 0, ident))
        elif kind == 0 and ident in running:   # finish (if not failed since)
            started = running.pop(ident)
            if abs((started + by_id[ident].runtime) - now) < 1e-9:
                sched.release(ident, now)
            else:   # stale finish event from a pre-failure schedule
                heapq.heappush(heap, (started + by_id[ident].runtime, 0,
                                      ident))
                running[ident] = started
    s = sched.stats
    return {"policy": policy, "host_seconds": s.host_seconds,
            "hosts_opened": s.hosts_opened, "peak_hosts": s.peak_hosts,
            "failures_recovered": s.failures_recovered,
            "lost_work": s.lost_work}
