"""Spans + counters: the process-wide observability collector.

Two tiers, tuned so instrumentation can stay in every hot path:

  * **Counters** are always on.  ``counter_add`` is one dict upsert - cheap
    enough to live inside ``pack_instances``, the run_batch escalation
    ladder and the serving select path unconditionally.  They are the
    single definition site for stats that used to hide in module privates
    (``batching._EVSEQ_STATS``, ``runner._simulate_lanes._cache_size``).
  * **Spans** record wall-clock intervals only while recording is enabled
    (``obs.enable()`` / ``obs.recording()`` / env ``REPRO_OBS=1``).  When
    disabled, ``span()`` returns a shared no-op object: the cost of an
    instrumented-but-disabled call site is one flag check and one function
    call (the <2% overhead budget is asserted by
    ``benchmarks/perf.py::obs_overhead``).

Spans must never be opened *inside* a jitted/vmapped/shard_mapped
computation - a traced function body runs once at trace time, so a span
there would time tracing, not execution.  Host-side call sites wrap the
dispatch (and block on results when they want execution time); per-event
device-side data rides out of the scan as stacked outputs instead (see
``obs.trace.ReplayTrace``).

Span events use the Chrome ``trace_event`` complete-event shape
(``ph: "X"``, microsecond ``ts``/``dur``) so export is a passthrough.
The span *stack* is thread-local (``annotate()`` targets the innermost
open span of the calling thread); the finished-event buffer and the
counter registry are process-global behind a lock.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import statistics
import threading
import time
from typing import Any, Callable, Dict, List, Optional

_T0 = time.perf_counter()
_LOCK = threading.Lock()
_EVENTS: List[dict] = []
_COUNTERS: Dict[str, float] = {}
_COUNTER_OPS = 0
_ENABLED = os.environ.get("REPRO_OBS", "") not in ("", "0")


class _Tls(threading.local):
    def __init__(self):
        self.stack: List["Span"] = []


_TLS = _Tls()


# ---------------------------------------------------------------- counters

def counter_add(name: str, n: float = 1) -> None:
    """Increment (or, with ``n < 0``, decrement) a named counter.  Always
    on; names are dotted ``<subsystem>.<what>`` (glossary in
    ``sweep/README.md``)."""
    global _COUNTER_OPS
    _COUNTER_OPS += 1
    with _LOCK:
        _COUNTERS[name] = _COUNTERS.get(name, 0) + n


def counter_get(name: str, default: float = 0) -> float:
    return _COUNTERS.get(name, default)


def counters() -> Dict[str, float]:
    """Snapshot of every counter (copy - safe to diff against later)."""
    with _LOCK:
        return dict(_COUNTERS)


def counter_ops() -> int:
    """Total ``counter_add`` calls so far (overhead accounting)."""
    return _COUNTER_OPS


def counter_deltas(before: Dict[str, float]) -> Dict[str, float]:
    """Counters that moved since a ``counters()`` snapshot."""
    now = counters()
    return {k: v - before.get(k, 0) for k, v in now.items()
            if v != before.get(k, 0)}


# Default histogram bucket bounds: powers of two, sized for the serving
# dispatch counters (batch sizes / queue depths up to the fixed-T ceiling).
HIST_BOUNDS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


def counter_hist(name: str, value: float, bounds=HIST_BOUNDS) -> None:
    """Histogram-style counter: one observation lands in ``<name>.le_<b>``
    for the smallest bound >= value (``<name>.le_inf`` above the last),
    plus ``<name>.count`` / ``<name>.sum``.  Built from plain counters so
    histograms ride everything counters already ride - ``Results.metrics``
    counter deltas, the bench JSON snapshot and the JSONL run log - with
    no new export machinery."""
    for b in bounds:
        if value <= b:
            counter_add(f"{name}.le_{b}")
            break
    else:
        counter_add(f"{name}.le_inf")
    counter_add(f"{name}.count")
    counter_add(f"{name}.sum", value)


# ------------------------------------------------------------------- spans

class _NullSpan:
    """Shared no-op span: the entire disabled-mode cost."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        return self


_NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("name", "cat", "args", "_t0")

    def __init__(self, name: str, cat: str, args: dict):
        self.name, self.cat, self.args = name, cat, args

    def __enter__(self):
        _TLS.stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def set(self, **kw):
        """Attach attributes discovered mid-span (e.g. the backend that
        actually served a request)."""
        self.args.update(kw)
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        _TLS.stack.pop()
        ev = {"name": self.name, "cat": self.cat, "ph": "X",
              "ts": (self._t0 - _T0) * 1e6, "dur": (t1 - self._t0) * 1e6,
              "tid": threading.get_ident() % 0xFFFF}
        if self.args:
            ev["args"] = self.args
        with _LOCK:
            _EVENTS.append(ev)
        return False


def span(name: str, cat: Optional[str] = None, **args):
    """Context manager timing a host-side region.  ``name`` is dotted
    ``<category>.<what>``; the category defaults to the first component.
    Returns the shared no-op span when recording is disabled."""
    if not _ENABLED:
        return _NULL_SPAN
    return Span(name, cat or name.split(".", 1)[0], args)


def instant(name: str, **args) -> None:
    """Record a zero-duration point event (Chrome ``ph: "i"``) - "a thing
    happened here": an injected fault, a retry, a degradation step, a
    checkpoint resume.  Gated like spans (the matching counter is the
    always-on record; the instant adds the *when* and the context when
    recording is enabled)."""
    if not _ENABLED:
        return
    ev = {"name": name, "cat": name.split(".", 1)[0], "ph": "i",
          "ts": (time.perf_counter() - _T0) * 1e6, "dur": 0.0,
          "tid": threading.get_ident() % 0xFFFF}
    if args:
        ev["args"] = args
    with _LOCK:
        _EVENTS.append(ev)


def annotate(**kw) -> None:
    """Attach attributes to the calling thread's innermost open span
    (no-op when disabled or outside any span)."""
    if _TLS.stack:
        _TLS.stack[-1].set(**kw)


def traced(name: Optional[str] = None, cat: Optional[str] = None):
    """Decorator flavor of ``span`` (span name defaults to the qualname)."""
    def deco(fn: Callable) -> Callable:
        nm = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            with span(nm, cat):
                return fn(*a, **kw)
        return wrapper
    return deco


# ----------------------------------------------------------- global state

def enabled() -> bool:
    return _ENABLED


def enable(on: bool = True) -> None:
    global _ENABLED
    _ENABLED = bool(on)


def disable() -> None:
    enable(False)


def events() -> List[dict]:
    """Snapshot of every finished span event (copy)."""
    with _LOCK:
        return list(_EVENTS)


def reset(counters_too: bool = True) -> None:
    """Drop recorded span events (and, by default, zero the counters)."""
    with _LOCK:
        _EVENTS.clear()
        if counters_too:
            _COUNTERS.clear()


class _Recording:
    def __init__(self, clear: bool):
        self.clear = clear

    def __enter__(self):
        self._prev = _ENABLED
        if self.clear:
            reset(counters_too=False)
        enable()
        return self

    def __exit__(self, *exc):
        enable(self._prev)
        return False


def recording(clear: bool = True) -> _Recording:
    """``with obs.recording(): ...`` - enable spans for the block (and by
    default start from an empty event buffer)."""
    return _Recording(clear)


# ------------------------------------------------------------------ timeit

@dataclasses.dataclass(frozen=True)
class TimingStats:
    """Per-rep wall-clock stats from ``obs.timeit`` (seconds)."""
    reps: tuple

    @property
    def n(self) -> int:
        return len(self.reps)

    @property
    def best(self) -> float:
        return min(self.reps)

    @property
    def median(self) -> float:
        return statistics.median(self.reps)

    @property
    def mean(self) -> float:
        return statistics.fmean(self.reps)

    @property
    def stdev(self) -> float:
        return statistics.stdev(self.reps) if len(self.reps) > 1 else 0.0

    def row(self, name: str, derived, scale: float = 1.0) -> str:
        """A ``benchmarks`` CSV row carrying the spread as a structured
        comment (parsed into the bench JSON by ``benchmarks/run.py``).
        ``scale`` converts per-call times to the row's unit (e.g. 1/E for
        a per-event row)."""
        s = scale * 1e6
        return (f"{name},{self.best * s:.1f},{derived}"
                f"  # med={self.median * s:.1f}us"
                f" sd={self.stdev * s:.1f}us n={self.n}")


def timeit(fn: Callable, *args, n: int = 5, warmup: int = 1,
           **kw) -> TimingStats:
    """Time ``fn(*args, **kw)`` with ``perf_counter``, blocking on device
    results (``jax.block_until_ready`` over whatever it returns) so the
    measurement covers execution, not dispatch.  ``warmup`` reps first
    (compile + cache warm), then ``n`` measured reps; returns min / median
    / stdev instead of a single best-of-N wall-clock sample."""
    import jax
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args, **kw))
    reps = []
    for _ in range(max(1, n)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        reps.append(time.perf_counter() - t0)
    return TimingStats(tuple(reps))
