"""Replay decision traces: the per-event time series the scan computes.

The batched replay already tracks open bins, aggregate loads and running
usage per event - it just discards everything but the final scalars.  With
``trace_level >= 1`` the scan step also *emits* its post-event state as a
stacked output (``jax.lax.scan``'s ``ys``), which lands here as a
``ReplayTrace``: paper-style usage/open-bin time series for every lane,
and decision-for-decision comparisons via ``diff_traces`` (parity
debugging becomes "which event diverged first" instead of bisection).

Everything in this module is host-side numpy; the device only pays for the
stacked outputs (see the cost model in ``sweep/README.md``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

# Event kinds, mirroring ``kernels.fitscore`` (values are pinned by the
# event-tensor format; tests/test_obs.py asserts they stay in sync).
ARRIVAL_KIND = 1
DEPARTURE_KIND = 0
PAD_KIND = -1

# Comparison order for diff_traces: a slot disagreement is the decision
# divergence itself; the rest are downstream symptoms.
TRACE_FIELDS = ("slot", "tag", "open_bins", "load", "usage")


@dataclasses.dataclass(frozen=True)
class ReplayTrace:
    """Per-event decision series for ``L`` replay lanes of ``E`` events.

    Lane order matches the runner's flattening: lane ``b * S + s`` is
    instance ``b``, prediction-seed row ``s``.  Event columns follow the
    padded event tensor (real events first, ``PAD_KIND`` filler after).
    """

    times: np.ndarray      # (L, E) event times
    kinds: np.ndarray      # (L, E) 1 arrival / 0 departure / -1 pad
    items: np.ndarray      # (L, E) item index per event
    slot: np.ndarray       # (L, E) slot chosen (arrival) / freed
                           #        (departure); -1 on pad events
    open_bins: np.ndarray  # (L, E) open-bin count after the event
    load: np.ndarray       # (L, E, d) aggregate open-bin load after
    tag: np.ndarray        # (L, E) category tag of the touched slot
                           #        (-1: untagged policy family / pad)
    usage: np.ndarray      # (L, E) running usage total after the event
    policy: str = ""
    S: int = 1             # seed rows per instance (lane = b * S + s)
    alive: Optional[np.ndarray] = None  # (L, E, Np) trace_level >= 2 only

    @property
    def L(self) -> int:
        return self.slot.shape[0]

    @property
    def E(self) -> int:
        return self.slot.shape[1]

    def lane(self, i: int) -> "ReplayTrace":
        """Single-lane view (L == 1), keeping every series aligned."""
        pick = lambda a: None if a is None else a[i:i + 1]
        return dataclasses.replace(
            self, times=self.times[i:i + 1], kinds=self.kinds[i:i + 1],
            items=self.items[i:i + 1], slot=self.slot[i:i + 1],
            open_bins=self.open_bins[i:i + 1], load=self.load[i:i + 1],
            tag=self.tag[i:i + 1], usage=self.usage[i:i + 1],
            alive=pick(self.alive), S=1)

    def series(self, lane: int = 0) -> Dict[str, np.ndarray]:
        """One lane's real-event series (pad events dropped): the
        paper-style ``time -> open_bins / load / usage`` curves."""
        m = self.kinds[lane] != PAD_KIND
        out = {"time": self.times[lane][m], "kind": self.kinds[lane][m],
               "item": self.items[lane][m]}
        for f in TRACE_FIELDS:
            out[f] = getattr(self, f)[lane][m]
        return out


def from_scan(ys: Dict[str, Any], times, kinds, items, policy: str = "",
              S: int = 1) -> ReplayTrace:
    """Wrap the scan's stacked trace outputs (each ``(L, E, ...)``) plus
    the event tensor into a host-side ``ReplayTrace``."""
    rep = lambda a: np.repeat(np.asarray(a), S, axis=0) if S > 1 \
        else np.asarray(a)
    return ReplayTrace(times=rep(times), kinds=rep(kinds), items=rep(items),
                       slot=np.asarray(ys["slot"]),
                       open_bins=np.asarray(ys["open_bins"]),
                       load=np.asarray(ys["load"]),
                       tag=np.asarray(ys["tag"]),
                       usage=np.asarray(ys["usage"]),
                       alive=None if "alive" not in ys
                       else np.asarray(ys["alive"]),
                       policy=policy, S=S)


@dataclasses.dataclass(frozen=True)
class TraceDivergence:
    """First event where two traces disagree."""
    lane: int
    event: int
    field: str        # "kind"/"time"/"item" (structural) or a TRACE_FIELDS
    a_value: Any
    b_value: Any
    time: float       # event time in trace ``a``
    kind: int         # event kind in trace ``a``
    item: int

    def __str__(self):
        what = {ARRIVAL_KIND: "arrival", DEPARTURE_KIND: "departure",
                PAD_KIND: "pad"}.get(int(self.kind), "?")
        return (f"lane {self.lane} event {self.event} "
                f"(t={self.time:g}, {what} of item {self.item}): "
                f"{self.field} {self.a_value!r} != {self.b_value!r}")


def diff_traces(a: ReplayTrace, b: ReplayTrace) -> Optional[TraceDivergence]:
    """Pinpoint the first diverging event between two replay traces.

    Returns ``None`` when the traces agree on every field of every event,
    else the earliest (event, then lane) disagreement with the field
    chosen by decision priority (``slot`` before downstream aggregates).
    Structural mismatches (different event tensors) are reported as
    ``kind`` / ``time`` / ``item`` divergences.
    """
    assert a.slot.shape == b.slot.shape, \
        f"trace shapes differ: {a.slot.shape} vs {b.slot.shape}"
    fields = ("kind", "time", "item") + TRACE_FIELDS
    arrays = {"kind": (a.kinds, b.kinds), "time": (a.times, b.times),
              "item": (a.items, b.items)}
    arrays.update({f: (getattr(a, f), getattr(b, f))
                   for f in TRACE_FIELDS})
    neq = {}
    any_neq = np.zeros(a.slot.shape, bool)
    for f, (xa, xb) in arrays.items():
        d = xa != xb
        if d.ndim == 3:          # per-dim load: any component differs
            d = d.any(axis=2)
        neq[f] = d
        any_neq |= d
    if not any_neq.any():
        return None
    # earliest diverging event across all lanes; lowest lane breaks ties
    ev_first = np.where(any_neq.any(axis=0))[0][0]
    lane = np.where(any_neq[:, ev_first])[0][0]
    for f in fields:
        if neq[f][lane, ev_first]:
            xa, xb = arrays[f]
            return TraceDivergence(
                lane=int(lane), event=int(ev_first), field=f,
                a_value=xa[lane, ev_first], b_value=xb[lane, ev_first],
                time=float(a.times[lane, ev_first]),
                kind=int(a.kinds[lane, ev_first]),
                item=int(a.items[lane, ev_first]))
    raise AssertionError("unreachable: any_neq set but no field differs")
