"""``python -m repro obs`` - summarize or convert an obs run log.

    python -m repro obs run.obs.jsonl                 # text summary
    python -m repro obs run.obs.jsonl --perfetto t.json   # trace_event JSON
"""
from __future__ import annotations

import argparse

from .export import export_perfetto, read_jsonl, summarize


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro obs",
        description="Summarize a JSONL obs run log (spans + counters), "
                    "optionally converting it to Chrome/Perfetto "
                    "trace_event JSON.")
    ap.add_argument("log", help="JSONL run log (obs.export_jsonl)")
    ap.add_argument("--perfetto", metavar="OUT.json", default=None,
                    help="also write the spans as Chrome trace_event JSON")
    args = ap.parse_args(argv)
    events, counters, meta = read_jsonl(args.log)
    if meta:
        keys = ", ".join(f"{k}={v}" for k, v in sorted(meta.items())
                         if k not in ("schema", "type"))
        if keys:
            print(f"# {keys}")
    print(summarize(events, counters))
    if args.perfetto:
        path = export_perfetto(args.perfetto, events, counters)
        print(f"\nwrote {path} (open in https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
