"""Exporters for the obs collector: JSONL run logs, Chrome/Perfetto
``trace_event`` JSON, a text summary, and the ``jax.profiler`` hook.

A *run log* is line-delimited JSON: one ``{"type": "meta", ...}`` header,
one line per span event, and a final ``{"type": "counters", ...}``
snapshot - append-friendly, grep-friendly, and the per-SHA CI artifact
format.  The Perfetto export is the same span events in the Chrome
``trace_event`` envelope ({"traceEvents": [...]}), which
https://ui.perfetto.dev and chrome://tracing open directly.
"""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

from . import collector


def _pick(events, counters_):
    if events is None:
        events = collector.events()
    if counters_ is None:
        counters_ = collector.counters()
    return events, counters_


def export_jsonl(path: str, events: Optional[List[dict]] = None,
                 counters: Optional[Dict[str, float]] = None,
                 meta: Optional[dict] = None) -> str:
    """Write a JSONL run log (spans + final counter snapshot)."""
    events, counters = _pick(events, counters)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        f.write(json.dumps({"type": "meta", "schema": 1,
                            "unix_time": time.time(),
                            **(meta or {})}) + "\n")
        for ev in events:
            f.write(json.dumps({"type": "span", **ev}) + "\n")
        f.write(json.dumps({"type": "counters", "counters": counters})
                + "\n")
    return path


def read_jsonl(path: str) -> Tuple[List[dict], Dict[str, float], dict]:
    """Load a run log back into (span events, counters, meta)."""
    events, counters, meta = [], {}, {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            t = rec.pop("type", "span")
            if t == "span":
                events.append(rec)
            elif t == "counters":
                counters.update(rec.get("counters", {}))
            elif t == "meta":
                meta.update(rec)
    return events, counters, meta


def chrome_trace_events(events: Optional[List[dict]] = None,
                        counters: Optional[Dict[str, float]] = None) -> dict:
    """The Chrome ``trace_event`` JSON object for recorded spans (counters
    ride along as ``otherData`` so they survive the round trip)."""
    events, counters = _pick(events, counters)
    pid = os.getpid()
    out = [{"pid": pid, "tid": ev.get("tid", 0), "ph": ev.get("ph", "X"),
            "name": ev["name"], "cat": ev.get("cat", ""),
            "ts": ev["ts"], "dur": ev["dur"],
            "args": ev.get("args", {})} for ev in events]
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"counters": counters}}


def export_perfetto(path: str, events: Optional[List[dict]] = None,
                    counters: Optional[Dict[str, float]] = None) -> str:
    """Write spans as Chrome/Perfetto ``trace_event`` JSON."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace_events(events, counters), f, indent=1)
    return path


def summarize(events: Optional[List[dict]] = None,
              counters: Optional[Dict[str, float]] = None) -> str:
    """Text summary: per-span-name call counts and total/mean/max wall
    time, then every counter - what ``python -m repro obs`` prints."""
    events, counters = _pick(events, counters)
    agg: Dict[str, list] = {}
    for ev in events:
        agg.setdefault(ev["name"], []).append(ev["dur"])
    lines = [f"{'span':<28}{'calls':>7}{'total_ms':>10}{'mean_us':>10}"
             f"{'max_us':>10}"]
    for name in sorted(agg, key=lambda n: -sum(agg[n])):
        durs = agg[name]
        lines.append(f"{name:<28}{len(durs):>7}"
                     f"{sum(durs) / 1e3:>10.2f}"
                     f"{sum(durs) / len(durs):>10.0f}"
                     f"{max(durs):>10.0f}")
    if not agg:
        lines.append("(no spans recorded)")
    lines.append("")
    lines.append(f"{'counter':<40}{'value':>14}")
    for name in sorted(counters):
        v = counters[name]
        lines.append(f"{name:<40}{v:>14g}")
    if not counters:
        lines.append("(no counters)")
    return "\n".join(lines)


@contextmanager
def jax_profile(logdir: Optional[str] = None):
    """``jax.profiler`` start/stop around a block, recorded as a span so
    native TPU/XLA profiles attach to the same span tree.  Active only
    when a log dir is given (or env ``REPRO_OBS_PROFILE`` names one);
    otherwise a no-op, so it can wrap the scan dispatch unconditionally."""
    logdir = logdir or os.environ.get("REPRO_OBS_PROFILE", "")
    if not logdir:
        yield None
        return
    import jax
    with collector.span("profiler.jax_trace", cat="profiler",
                        logdir=logdir):
        jax.profiler.start_trace(logdir)
        try:
            yield logdir
        finally:
            jax.profiler.stop_trace()
