"""repro.obs - spans, counters and replay decision traces.

One observability layer for every execution path: host-side **spans**
(wall-clock intervals, Chrome ``trace_event`` shaped) and always-on
**counters** from the collector; per-event **replay traces** emitted by
the batched scan itself (``trace_level`` on ``run_batch`` /
``Experiment.run``); JSONL / Perfetto **exporters** plus the
``jax.profiler`` hook; and ``python -m repro obs`` to summarize a run log.

Span-name and counter glossaries live in ``sweep/README.md``.  The rules:
counters are always on (single dict upsert); spans are recorded only
under ``obs.enable()`` / ``obs.recording()`` / env ``REPRO_OBS=1`` and
must stay outside jitted computations (a traced body runs once, at trace
time).  Per-event device data never goes through the collector - it rides
out of the scan as stacked outputs (``ReplayTrace``).
"""
from .collector import (HIST_BOUNDS, Span, TimingStats, annotate,
                        counter_add, counter_deltas, counter_get,
                        counter_hist, counter_ops, counters, disable, enable,
                        enabled, events, instant, recording, reset, span,
                        timeit, traced)
from .export import (chrome_trace_events, export_jsonl, export_perfetto,
                     jax_profile, read_jsonl, summarize)
from .trace import (ReplayTrace, TraceDivergence, diff_traces, from_scan)

__all__ = [
    "HIST_BOUNDS", "Span", "TimingStats", "annotate", "counter_add",
    "counter_deltas", "counter_get", "counter_hist", "counter_ops",
    "counters", "disable", "enable", "enabled", "events", "instant",
    "recording", "reset", "span", "timeit", "traced",
    "chrome_trace_events", "export_jsonl", "export_perfetto", "jax_profile",
    "read_jsonl", "summarize",
    "ReplayTrace", "TraceDivergence", "diff_traces", "from_scan",
]
