"""End-to-end serving driver: real model replicas + DVBP placement.

Boots a fleet of reduced-config ReplicaEngines (real forward passes,
continuous batching), schedules a Poisson request stream with the paper's
Greedy policy, and reports replica-occupancy seconds against the fleet
simulation baselines.

    PYTHONPATH=src python examples/serve_dvbp.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--requests", "200", "--policy", "nrt_prioritized",
          "--sigma", "0.5"])
    main(["--arch", "qwen2.5-14b", "--requests", "10", "--real",
          "--policy", "greedy"])
