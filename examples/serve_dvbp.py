"""End-to-end serving: DVBP capacity planning through the experiment API,
plus real model replicas.

Part 1 plans a Poisson request fleet with the batched replay engine:
``api.serving_requests`` converts the request stream into DVBP instance
lanes, so the same ``Experiment`` facade (and sweep store) that runs the
paper's grids prices replica-occupancy seconds per policy, next to the
host ``simulate_fleet`` baselines.

Part 2 boots reduced-config ReplicaEngines (real forward passes,
continuous batching) behind the DVBP scheduler.

    PYTHONPATH=src python examples/serve_dvbp.py
"""
from repro import api
from repro.launch.serve import main
from repro.serving.fleet import (attach_predictions, simulate_fleet,
                                 synth_requests)


def plan_capacity():
    reqs = attach_predictions(synth_requests(2000, seed=7), sigma=0.5,
                              seed=7)
    wl = api.serving_requests(reqs, name="poisson2000")
    res = api.Experiment(
        wl,
        policies=("first_fit", "best_fit_linf", "greedy",
                  "nrt_prioritized"),
        settings=(api.Setting.predicted(),),   # the attached predictions
    ).run()
    print("batched capacity planning (replica-occupancy seconds):")
    for r in res.rows():
        print(f"  {r['policy']:18s} replica_s={r['usage_time']:10.1f} "
              f"opened={r['n_bins_opened']:3d} ratio={r['ratio']:.3f}")
    rr = simulate_fleet(reqs, "round_robin")
    print(f"  {'round_robin':18s} replica_s="
          f"{rr['replica_seconds']:10.1f} "
          f"opened={rr['replicas_opened']:3d} (host baseline)")


if __name__ == "__main__":
    plan_capacity()
    main(["--arch", "qwen2.5-14b", "--requests", "10", "--real",
          "--policy", "greedy"])
