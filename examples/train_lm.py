"""Train a language model end-to-end on host devices.

Default: a ~10M-param qwen-family model for 200 steps (CPU-friendly).
--big switches to a ~100M-param config (use on real accelerators).

    PYTHONPATH=src python examples/train_lm.py [--big]
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")
from repro.models.config import ModelConfig  # noqa: E402
from repro.models import params as P_  # noqa: E402
from repro.models.transformer import Runtime  # noqa: E402
from repro.train.optimizer import OptConfig, init_opt_state  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402
from repro.train.checkpoint import CheckpointManager  # noqa: E402
from repro.data.tokens import TokenStream  # noqa: E402

SMALL = ModelConfig(name="lm-10m", family="dense", n_layers=4, d_model=256,
                    n_heads=8, n_kv_heads=4, head_dim=32, d_ff=1024,
                    vocab=8192, mlp_act="silu_glu", dtype="float32",
                    attn_q_chunk=128)
BIG = ModelConfig(name="lm-100m", family="dense", n_layers=12, d_model=768,
                  n_heads=12, n_kv_heads=4, head_dim=64, d_ff=3072,
                  vocab=32768, mlp_act="silu_glu", attn_q_chunk=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    cfg = BIG if args.big else SMALL
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    rt = Runtime(mesh=None)
    opt = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, rt, opt, microbatches=2),
                      donate_argnums=(0, 1))
    params = P_.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt_state = init_opt_state(params, opt)
    stream = TokenStream(cfg.vocab, args.seq, args.batch)
    ckpt = CheckpointManager("/tmp/repro_train_lm", keep=2)
    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        batch = jax.tree.map(jnp.asarray, stream.batch(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={loss:.4f} "
                  f"({(time.time()-t0):.0f}s)", flush=True)
        if step % 100 == 0 and step:
            ckpt.save(step, (params, opt_state))
    ckpt.save(args.steps, (params, opt_state))
    ckpt.wait()
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
