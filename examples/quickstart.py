"""Quickstart: the paper in one minute.

Packs a synthetic Azure-like DVBP instance with algorithms from all three
settings and prints performance ratios vs. the Eq.(1) lower bound.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (get_algorithm, lognormal_predictions, lower_bound,
                        run)
from repro.data import make_azure_like_suite


def main():
    inst = make_azure_like_suite(n_instances=1, n_items=4000)[0]
    lb = lower_bound(inst)
    print(f"instance {inst.name}: {inst.n_items} VMs, d={inst.d}, "
          f"mu={inst.mu:.0f}, LB={lb:.0f} bin-seconds\n")

    print("non-clairvoyant (durations unknown):")
    for name in ["first_fit", "mru", "next_fit", "rr_next_fit"]:
        r = run(inst, get_algorithm(name))
        print(f"  {r.algorithm:22s} ratio={r.ratio(lb):.3f}")
    r = run(inst, get_algorithm("best_fit", norm="linf"))
    print(f"  {r.algorithm:22s} ratio={r.ratio(lb):.3f}")

    print("clairvoyant (durations known):")
    for name, kw in [("nrt_prioritized", {}), ("greedy", {}),
                     ("cbdt", {"rho": 21600.0}), ("reduced_hybrid", {})]:
        r = run(inst, get_algorithm(name, **kw))
        print(f"  {r.algorithm:22s} ratio={r.ratio(lb):.3f}")

    print("learning-augmented (predicted durations, sigma=1):")
    pdur = lognormal_predictions(inst, sigma=1.0, seed=1)
    for name in ["ppe_modified", "greedy", "nrt_prioritized"]:
        r = run(inst, get_algorithm(name), predicted_durations=pdur)
        print(f"  {r.algorithm:22s} ratio={r.ratio(lb):.3f}")
    for mode in ["binary", "geometric"]:
        r = run(inst, get_algorithm("lifetime_alignment", mode=mode),
                predicted_durations=pdur)
        print(f"  {r.algorithm:22s} ratio={r.ratio(lb):.3f}")


if __name__ == "__main__":
    main()
