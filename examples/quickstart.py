"""Quickstart: the paper in one minute, through the one experiment API.

One workload x one policy x one information setting -> one usage-time
ratio vs. the Eq. (1) lower bound.  ``repro.api`` runs every cell of that
matrix as batched scan lanes: Workload (what gets packed), Policy (how),
Setting (what the policy is told about durations), Experiment (run it).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro import api


def main():
    wl = api.synthetic("azure", n_instances=2, n_items=800)

    # Policies are first-class values, not strings: parse/str round-trip,
    # structured params, capability flags, registry introspection.
    cbd = api.Policy.parse("cbd_beta2")
    assert str(cbd) == "cbd_beta2" and cbd.beta == 2.0 and cbd.category
    n_scan = sum(p.scan for p in api.policies())
    print(f"{n_scan} batched policies registered; e.g. {cbd.name}: "
          f"family={cbd.family} device_select={cbd.device_select}\n")

    cells = [
        (api.Setting.nonclairvoyant(),
         ("first_fit", "mru", "best_fit_linf")),
        (api.Setting.clairvoyant(),
         ("nrt_prioritized", "greedy", "cbdt_rho21600", "reduced_hybrid")),
        (api.Setting.predicted("lognormal", 1.0),
         ("ppe_modified", "greedy", "nrt_prioritized", "la_binary",
          "la_geometric")),
    ]
    for setting, policies in cells:
        print(f"{setting.label()}:")
        res = api.Experiment(wl, policies=policies,
                             settings=(setting,), seeds=(1,)).run()
        for (w, policy, s), st in res.summary().items():
            print(f"  {policy:22s} ratio={st.mean:.3f}")
        print()

    # Host-only extras (no batched lane) still run on the oracle engine:
    from repro.core import get_algorithm, lower_bound, run
    from repro.data import make_azure_like_suite
    inst = make_azure_like_suite(n_instances=1, n_items=800)[0]
    r = run(inst, get_algorithm("next_fit"))
    print("host-only (oracle engine):")
    print(f"  {r.algorithm:22s} ratio={r.ratio(lower_bound(inst)):.3f}")


if __name__ == "__main__":
    main()
