"""Fault tolerance demo: train -> simulated node failure -> elastic resume.

The trainer checkpoints periodically; a failure kills the run mid-stream;
a new trainer attaches to the (possibly reshaped) surviving mesh, restores
the newest checkpoint and continues - and because the data pipeline is
seekable (batch = f(step)), the recovered run is bit-identical to an
uninterrupted one.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")
from repro.models.config import ModelConfig  # noqa: E402
from repro.models import params as P_  # noqa: E402
from repro.models.transformer import Runtime  # noqa: E402
from repro.train.optimizer import OptConfig, init_opt_state  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402
from repro.train.elastic import ElasticConfig, ElasticTrainer  # noqa: E402
from repro.data.tokens import TokenStream  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
                  dtype="float32", attn_q_chunk=64)


def main():
    opt = OptConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    stream = TokenStream(CFG.vocab, 64, 4)

    def make_state():
        p = P_.init_params(jax.random.PRNGKey(0), CFG, dtype=jnp.float32)
        return (p, init_opt_state(p, opt))

    def make_step(mesh):
        rt = Runtime(mesh=None)
        fn = make_train_step(CFG, rt, opt, microbatches=1)

        @jax.jit
        def step(state, batch):
            p, o = state
            p, o, m = fn(p, o, batch)
            return (p, o), m
        return step, None

    def batch_fn(step):
        return jax.tree.map(jnp.asarray, stream.batch(step))

    import shutil
    shutil.rmtree("/tmp/repro_elastic", ignore_errors=True)
    shutil.rmtree("/tmp/repro_elastic_a", ignore_errors=True)

    # ---- run A: train 40 steps uninterrupted
    a = ElasticTrainer(make_state, make_step, batch_fn, "/tmp/repro_elastic_a",
                       ElasticConfig(ckpt_every=10))
    a.attach(make_host_mesh())
    ma = a.run(40)
    ref = float(ma["loss"])

    # ---- run B: fail at step 23, re-attach, resume from step 20
    b = ElasticTrainer(make_state, make_step, batch_fn, "/tmp/repro_elastic",
                       ElasticConfig(ckpt_every=10))
    b.attach(make_host_mesh())
    try:
        b.run(40, fail_at=23)
    except RuntimeError as e:
        print(f"!! {e}; re-attaching surviving mesh and resuming")
    b2 = ElasticTrainer(make_state, make_step, batch_fn, "/tmp/repro_elastic",
                        ElasticConfig(ckpt_every=10))
    b2.attach(make_host_mesh())
    print(f"restored at step {b2.step}")
    mb = b2.run(40 - b2.step)
    got = float(mb["loss"])
    print(f"uninterrupted loss@40={ref:.6f}  recovered loss@40={got:.6f}")
    assert abs(ref - got) < 1e-5, "recovery must be bit-identical"
    print("recovery is exact")


if __name__ == "__main__":
    main()
