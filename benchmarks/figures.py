"""One function per paper figure/table (Figs. 2-14).

Each returns CSV rows "name,us_per_call,derived" where derived is the mean
performance ratio across the instance suite (the paper's y-axis).
"""
from __future__ import annotations

from typing import List

from .common import REPEATS, alg, box_row, evaluate

SIGMAS = [0.0, 0.5, 1.0, 2.0, 4.0]
SEEDS = tuple(range(REPEATS))


def fig2_bestfit_norms() -> List[str]:
    out = []
    for norm in ["l1", "l2", "linf"]:
        r, s = evaluate(alg("best_fit", norm=norm))
        out.append(box_row(f"fig2/best_fit_{norm}", r, s))
    return out


def fig3_nonclairvoyant() -> List[str]:
    out = []
    for name in ["first_fit", "mru", "next_fit", "rr_next_fit"]:
        r, s = evaluate(alg(name))
        out.append(box_row(f"fig3/{name}", r, s))
    r, s = evaluate(alg("best_fit", norm="linf"))
    out.append(box_row("fig3/best_fit_linf", r, s))
    return out


def fig4_cbdt_rho() -> List[str]:
    out = []
    for rho_days in [0.05, 0.1, 0.25, 0.5, 1.0, 2.0]:
        r, s = evaluate(alg("cbdt", rho=rho_days * 86400.0))
        out.append(box_row(f"fig4/cbdt_rho{rho_days}d", r, s))
    return out


def fig5_nrt() -> List[str]:
    out = []
    for name in ["nrt_standard", "nrt_prioritized"]:
        r, s = evaluate(alg(name))
        out.append(box_row(f"fig5/{name}", r, s))
    return out


def fig6_cbd_beta() -> List[str]:
    out = []
    for beta in [1.5, 2.0, 4.0, 8.0, 16.0]:
        r, s = evaluate(alg("cbd", beta=beta))
        out.append(box_row(f"fig6/cbd_beta{beta:g}", r, s))
    return out


def fig7_hybrid() -> List[str]:
    out = []
    for name in ["hybrid", "reduced_hybrid", "hybrid_direct_sum",
                 "reduced_hybrid_direct_sum"]:
        r, s = evaluate(alg(name))
        out.append(box_row(f"fig7/{name}", r, s))
    return out


def fig8_clairvoyant() -> List[str]:
    out = []
    cases = [("cbdt_rho0.25d", alg("cbdt", rho=0.25 * 86400)),
             ("nrt_prioritized", alg("nrt_prioritized")),
             ("greedy", alg("greedy")),
             ("cbd_beta2", alg("cbd", beta=2.0)),
             ("reduced_hybrid", alg("reduced_hybrid")),
             ("first_fit", alg("first_fit"))]
    for name, f in cases:
        r, s = evaluate(f)
        out.append(box_row(f"fig8/{name}", r, s))
    return out


def fig9_classify_error() -> List[str]:
    out = []
    for sigma in SIGMAS:
        for name, f in [("cbdt_rho0.25d", alg("cbdt", rho=0.25 * 86400)),
                        ("cbd_beta2", alg("cbd", beta=2.0))]:
            r, s = evaluate(f, sigma=sigma, seeds=SEEDS)
            out.append(box_row(f"fig9/{name}/sigma{sigma:g}", r, s))
    r, s = evaluate(alg("first_fit"))
    out.append(box_row("fig9/first_fit/flat", r, s))
    return out


def fig10_rcp_ppe() -> List[str]:
    out = []
    for sigma in SIGMAS:
        for name in ["rcp", "ppe", "rcp_modified", "ppe_modified"]:
            r, s = evaluate(alg(name), sigma=sigma, seeds=SEEDS)
            out.append(box_row(f"fig10/{name}/sigma{sigma:g}", r, s))
    return out


def fig11_lifetime_alignment() -> List[str]:
    out = []
    for sigma in SIGMAS:
        cases = [("la_binary", alg("lifetime_alignment", mode="binary")),
                 ("la_geometric", alg("lifetime_alignment", mode="geometric")),
                 ("cbd_beta2", alg("cbd", beta=2.0)),
                 ("reduced_hybrid", alg("reduced_hybrid"))]
        for name, f in cases:
            r, s = evaluate(f, sigma=sigma, seeds=SEEDS)
            out.append(box_row(f"fig11/{name}/sigma{sigma:g}", r, s))
    return out


def fig12_overall() -> List[str]:
    out = []
    for sigma in SIGMAS:
        cases = [("nrt_prioritized", alg("nrt_prioritized")),
                 ("greedy", alg("greedy")),
                 ("ppe_modified", alg("ppe_modified")),
                 ("la_binary", alg("lifetime_alignment", mode="binary"))]
        for name, f in cases:
            r, s = evaluate(f, sigma=sigma, seeds=SEEDS)
            out.append(box_row(f"fig12/{name}/sigma{sigma:g}", r, s))
    r, s = evaluate(alg("first_fit"))
    out.append(box_row("fig12/first_fit/flat", r, s))
    return out


def fig13_huawei() -> List[str]:
    out = []
    cases = [("first_fit", alg("first_fit")),
             ("best_fit_l2", alg("best_fit", norm="l2")),
             ("rr_next_fit", alg("rr_next_fit")),
             ("nrt_prioritized", alg("nrt_prioritized")),
             ("greedy", alg("greedy")),
             ("reduced_hybrid", alg("reduced_hybrid"))]
    for name, f in cases:
        r, s = evaluate(f, suite="huawei")
        out.append(box_row(f"fig13/{name}", r, s))
    return out


def fig14_uniform_errors() -> List[str]:
    out = []
    for eps in [1.0, 4.0, 16.0, 100.0, 10000.0]:
        for name, f in [("nrt_prioritized", alg("nrt_prioritized")),
                        ("greedy", alg("greedy")),
                        ("ppe_modified", alg("ppe_modified")),
                        ("la_binary", alg("lifetime_alignment",
                                          mode="binary"))]:
            r, s = evaluate(f, eps=eps, seeds=SEEDS)
            out.append(box_row(f"fig14/{name}/eps{eps:g}", r, s))
    return out


def fig15_adaptive() -> List[str]:
    """BEYOND-PAPER: the paper's future-work item (1) - adaptive switching
    between NRT/Greedy/FF on the observed error signal."""
    out = []
    for sigma in SIGMAS:
        for name, f in [("adaptive", alg("adaptive")),
                        ("nrt_prioritized", alg("nrt_prioritized")),
                        ("greedy", alg("greedy"))]:
            r, s = evaluate(f, sigma=sigma, seeds=SEEDS)
            out.append(box_row(f"fig15/{name}/sigma{sigma:g}", r, s))
    r, s = evaluate(alg("first_fit"))
    out.append(box_row("fig15/first_fit/flat", r, s))
    return out


def fig16_consolidation() -> List[str]:
    """BEYOND-PAPER: threshold-triggered consolidation as a scenario axis.

    Every scan policy replays the suite twice through the api facade -
    the paper's placement-only setting and its consolidating twin
    (underload drain, threshold 0.25, 32-event planning cadence) - so the
    figure shows which families leave drainable bins behind and how much
    usage-time the bounded-recourse repack buys back.  Rows come in
    ``fig16/<policy>/base`` / ``fig16/<policy>/cons`` pairs (same mean
    performance-ratio metric as every other figure)."""
    import time

    from repro.api import Experiment, SCAN_POLICIES, Setting, instances
    from .common import azure_suite
    base = Setting.clairvoyant()
    cons = base.with_consolidation("underload:t0.25:e32")
    exp = Experiment(instances(list(azure_suite()), name="fig16"),
                     policies=SCAN_POLICIES, settings=(base, cons))
    t0 = time.time()
    res = exp.run()
    secs = (time.time() - t0) / max(len(res.rows()), 1)
    out = []
    for policy in SCAN_POLICIES:
        for setting, tag in ((base, "base"), (cons, "cons")):
            ratios = res.ratios(policy=policy, setting=setting.label())
            out.append(box_row(f"fig16/{policy}/{tag}", ratios, secs))
    return out


ALL_FIGURES = [fig2_bestfit_norms, fig3_nonclairvoyant, fig4_cbdt_rho,
               fig5_nrt, fig6_cbd_beta, fig7_hybrid, fig8_clairvoyant,
               fig9_classify_error, fig10_rcp_ppe, fig11_lifetime_alignment,
               fig12_overall, fig13_huawei, fig14_uniform_errors,
               fig15_adaptive, fig16_consolidation]
