"""Micro/throughput benchmarks beyond the paper figures:

  * Pallas kernels (interpret mode on CPU; native on TPU) vs jnp references
  * core.jaxsim trace replay vs the Python oracle engine
  * serving fleet placement throughput
  * roofline summary rows from the dry-run artifacts (experiments/dryrun)
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, n: int = 5) -> float:
    fn(*args)   # compile/warm
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n


def kernels() -> List[str]:
    import repro.kernels.ops as ops
    rows = []
    impl = "auto" if jax.default_backend() == "tpu" else "ref"
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (4, 256, 8, 64), jnp.float32)
    k = jax.random.normal(key, (4, 256, 2, 64), jnp.float32)
    v = jax.random.normal(key, (4, 256, 2, 64), jnp.float32)
    t = _timeit(lambda: ops.flash_attention(q, k, v, impl=impl))
    flops = 4 * 256 * 256 * 8 * 64 * 2 * 2 / 2
    rows.append(f"perf/flash_attention_{impl},{t*1e6:.0f},{flops/t/1e9:.1f}")

    qd = jax.random.normal(key, (8, 8, 64))
    kd = jax.random.normal(key, (8, 4096, 2, 64))
    vd = jax.random.normal(key, (8, 4096, 2, 64))
    kl = jnp.full((8,), 4096, jnp.int32)
    t = _timeit(lambda: ops.decode_attention(qd, kd, vd, kl, impl=impl))
    gb = 8 * 4096 * 2 * 64 * 4 * 2 / 1e9
    rows.append(f"perf/decode_attention_{impl},{t*1e6:.0f},{gb/t:.1f}")

    rem = jnp.asarray(np.random.default_rng(0).random((4096, 5)))
    alive = jnp.ones(4096, bool)
    item = jnp.asarray(np.random.default_rng(1).random(5) * 0.3)
    t = _timeit(lambda: ops.fitscore(rem, alive, item, impl=impl))
    rows.append(f"perf/fitscore_4096bins_{impl},{t*1e6:.0f},{4096/t/1e6:.2f}")
    return rows


def fitscore_step(lanes: int = 8, n_slots: int = 4096,
                  d: int = 5) -> List[str]:
    """The sweep scan's placement step in isolation: the inline vmapped jnp
    select vs the fused lane-batched Pallas kernel (interpret mode on CPU,
    native on TPU).  Derived column: scored slots per microsecond."""
    from functools import partial

    from repro.core.jaxsim import _select_slot
    from repro.kernels.fitscore import fitscore_select_batch
    rng = np.random.default_rng(0)
    loads = jnp.asarray(rng.random((lanes, n_slots, d)) * 0.5, jnp.float32)
    counts = jnp.asarray((rng.random((lanes, n_slots)) > 0.3)
                         .astype(np.int32))
    alive = counts > 0
    oseq = jnp.asarray(np.tile(rng.permutation(n_slots), (lanes, 1))
                       .astype(np.int32))
    closes = jnp.asarray(rng.random((lanes, n_slots)) * 1e4, jnp.float32)
    size = jnp.asarray(rng.random((lanes, d)) * 0.3, jnp.float32)
    pdep = jnp.asarray(rng.random(lanes) * 1e4, jnp.float32)
    now = jnp.asarray(rng.random(lanes) * 1e3, jnp.float32)
    dmask = jnp.ones((lanes, d))
    args = (loads, counts, alive, oseq, oseq, closes, size, pdep, now, dmask)
    policy = "best_fit_linf"

    jnp_fn = jax.jit(lambda *a: jax.vmap(partial(_select_slot, policy))(*a))
    t_j = _timeit(lambda: jnp_fn(*args))
    interpret = jax.default_backend() != "tpu"
    pal_fn = jax.jit(lambda *a: fitscore_select_batch(
        *a, policy=policy, interpret=interpret))
    t_p = _timeit(lambda: pal_fn(*args))
    per_us = lanes * n_slots / 1e6
    return [f"perf/fitscore_step_jnp,{t_j*1e6:.0f},{per_us/t_j:.2f}",
            f"perf/fitscore_step_pallas,{t_p*1e6:.0f},{per_us/t_p:.2f}"]


_SHARDED_BENCH = """
import time
import jax, numpy as np
from repro.data import make_azure_like_suite
from repro.sweep import pack_instances, run_batch
insts = make_azure_like_suite(n_instances=28, n_items=250, seed=11)
batch = pack_instances(insts)
policies = ("first_fit", "best_fit_l2", "greedy", "nrt_prioritized")
for shard in ("never", "always"):
    t0 = time.time()
    usage = sum(float(run_batch(batch, p, max_bins=64, shard=shard)
                      .usage_time.sum()) for p in policies)
    print(f"{shard},{time.time() - t0},{usage}")
"""


def sweep_sharded(ndev: int = 4) -> List[str]:
    """The 28x4 sweep grid with the lane axis sharded over ``ndev`` forced
    host devices vs the single-device path, in a subprocess (device count is
    fixed at jax init).  On one physical CPU the shards share cores, so the
    derived speedup ratio is the honest lower bound; on a real multi-chip
    host each shard gets its own chip."""
    import subprocess
    import sys
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARDED_BENCH], env=env,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-500:])
    times, usages = {}, {}
    for line in proc.stdout.strip().splitlines():
        shard, t, usage = line.split(",")
        times[shard] = float(t)
        usages[shard] = float(usage)
    assert usages["never"] == usages["always"], \
        f"sharded results diverged: {usages}"
    n_runs = 28 * 4
    return [f"perf/sweep_sharded_28x4,{times['always']/n_runs*1e6:.0f},"
            f"{times['never']/times['always']:.2f}"]


def jaxsim_vs_oracle() -> List[str]:
    from repro.core import get_algorithm, run
    from repro.core.jaxsim import simulate
    from repro.data import make_azure_like_suite
    inst = make_azure_like_suite(n_instances=1, n_items=2000)[0]
    t0 = time.time()
    r = run(inst, get_algorithm("first_fit"))
    t_or = time.time() - t0
    simulate(inst, "first_fit", max_bins=r.peak_open_bins + 8)   # compile
    t0 = time.time()
    j = simulate(inst, "first_fit", max_bins=r.peak_open_bins + 8)
    t_jx = time.time() - t0
    rows = [f"perf/oracle_engine_2k_items,{t_or*1e6:.0f},{r.usage_time:.0f}",
            f"perf/jaxsim_2k_items,{t_jx*1e6:.0f},{j.usage_time:.0f}"]
    return rows


def sweep_grid(n_instances: int = 28, n_items: int = 250,
               policies=("first_fit", "best_fit_l2", "greedy",
                         "nrt_prioritized")) -> List[str]:
    """Batched sweep runner vs the per-instance simulate() loop on an
    n_instances x len(policies) grid.  The loop path re-traces per instance
    (every instance has its own event-tensor shape); the batched path
    compiles once per policy.  Wall clock includes compilation for both -
    that is the real cost of evaluating a fresh grid."""
    from repro.core.jaxsim import simulate
    from repro.data import make_azure_like_suite
    from repro.sweep import pack_instances, run_batch
    insts = make_azure_like_suite(n_instances=n_instances, n_items=n_items,
                                  seed=11)
    grid = n_runs = n_instances * len(policies)

    t0 = time.time()
    loop_usage = 0.0
    for p in policies:
        for inst in insts:
            loop_usage += simulate(inst, p, max_bins=64).usage_time
    t_loop = time.time() - t0

    t0 = time.time()
    batch = pack_instances(insts)
    batch_usage = 0.0
    for p in policies:
        batch_usage += float(run_batch(batch, p, max_bins=64)
                             .usage_time.sum())
    t_batch = time.time() - t0

    tag = f"{n_instances}x{len(policies)}"
    return [f"perf/sweep_loop_{tag},{t_loop/n_runs*1e6:.0f},{loop_usage:.0f}",
            f"perf/sweep_batched_{tag},{t_batch/n_runs*1e6:.0f},"
            f"{batch_usage:.0f}",
            f"perf/sweep_speedup_{tag},{t_batch*1e6:.0f},"
            f"{t_loop/t_batch:.2f}"]


def serving_fleet() -> List[str]:
    from repro.serving.fleet import attach_predictions, simulate_fleet, \
        synth_requests
    reqs = attach_predictions(synth_requests(2000), sigma=0.5)
    rows = []
    for pol in ["round_robin", "first_fit", "greedy", "nrt_prioritized"]:
        t0 = time.time()
        r = simulate_fleet(reqs, pol)
        rows.append(f"perf/fleet_{pol},{(time.time()-t0)*1e6:.0f},"
                    f"{r['replica_seconds']:.0f}")
    return rows


def roofline_summary() -> List[str]:
    rows = []
    for path in sorted(glob.glob("experiments/dryrun/*_16x16.json")):
        with open(path) as f:
            rec = json.load(f)
        r = rec["roofline"]
        dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom_s if dom_s else 0.0
        rows.append(f"roofline/{rec['arch']}/{rec['shape']},"
                    f"{dom_s*1e6:.0f},{frac:.3f}  "
                    f"# dominant={r['dominant']} useful={r['useful_ratio']:.2f}")
    return rows
